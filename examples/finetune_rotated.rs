//! Fine-tuning under distribution shift (paper Table 2 scenario):
//! pretrain LeNet-5 with BP on clean SynthMNIST, rotate the world by
//! 45°, watch accuracy collapse, then recover it with ElasticZO
//! fine-tuning — BP touching only the last FC layer, ZO for the rest,
//! at inference-level memory.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_rotated
//! ```

use elasticzo::coordinator::{checkpoint, trainer, Method, Model, ParamSet};
use elasticzo::data::{self, rotate, DatasetKind};
use elasticzo::exp::{build_engine, fp32_train_config};

fn main() -> anyhow::Result<()> {
    let kind = DatasetKind::SynthMnist;
    let (train_d, test_d) = data::generate(kind, 2048, 1024, 11, 0);

    // --- pretrain with Full BP on the clean data --------------------
    let mut engine = build_engine(Model::LeNet, 32, elasticzo::coordinator::EngineKind::Xla);
    let mut params = ParamSet::init(Model::LeNet, 11);
    let pre_cfg = fp32_train_config(Method::FullBp, 8, 32, 11);
    let r = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &pre_cfg)?;
    println!("pretrained (clean): {:.2}%", r.history.best_test_acc() * 100.0);

    // checkpoint roundtrip, as a real deployment would
    let ckpt = std::env::temp_dir().join("elasticzo_pretrained.ckpt");
    checkpoint::save_params(&ckpt, &params)?;

    // --- the world rotates by 45° -----------------------------------
    let ft_train = rotate::rotate_dataset(&train_d.split_at(1024).0, 45.0);
    let ft_test = rotate::rotate_dataset(&test_d, 45.0);
    let (_, acc_before) = trainer::evaluate(engine.as_mut(), &params, &ft_test, 32)?;
    println!("w/o fine-tuning on rotated data: {:.2}%", acc_before * 100.0);

    // --- ElasticZO fine-tuning (Cls1) --------------------------------
    let mut params_ft = ParamSet::init(Model::LeNet, 0);
    checkpoint::load_params(&ckpt, &mut params_ft)?;
    let ft_cfg = fp32_train_config(Method::Cls1, 10, 32, 12);
    let r = trainer::train(engine.as_mut(), &mut params_ft, &ft_train, &ft_test, &ft_cfg)?;
    let acc_after = r.history.best_test_acc();
    println!("after ElasticZO-Cls1 fine-tuning: {:.2}%", acc_after * 100.0);

    assert!(
        acc_after > acc_before,
        "fine-tuning must recover accuracy ({acc_before} -> {acc_after})"
    );
    println!(
        "\nrecovered {:.1} accuracy points with near-inference memory",
        (acc_after - acc_before) * 100.0
    );
    std::fs::remove_file(ckpt).ok();
    Ok(())
}
