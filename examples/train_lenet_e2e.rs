//! End-to-end validation driver (DESIGN.md §6, EXPERIMENTS.md §E2E):
//! trains LeNet-5 with ALL FOUR methods (Full ZO / ZO-Feat-Cls2 /
//! ZO-Feat-Cls1 / Full BP) for ~1.4k steps each on the synthetic corpus
//! through the full three-layer stack (rust coordinator → PJRT → AOT
//! HLO from JAX+Pallas), logs every loss curve, and asserts the paper's
//! headline ordering:
//!
//!   acc(Full ZO) < acc(Cls2) <= acc(Cls1) ≲ acc(Full BP)
//!
//! ```bash
//! make artifacts && cargo run --release --example train_lenet_e2e
//! ```

use elasticzo::coordinator::{trainer, Method, Model, ParamSet};
use elasticzo::data;
use elasticzo::exp::{build_engine, fp32_train_config};

fn main() -> anyhow::Result<()> {
    let (train_d, test_d) = data::generate(data::DatasetKind::SynthMnist, 3072, 1024, 1, 0);
    let epochs = 15; // 96 steps/epoch x 15 = 1440 steps (2 fwd each for ZO)

    let mut results: Vec<(Method, f32)> = Vec::new();
    for method in [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp] {
        let mut engine =
            build_engine(Model::LeNet, 32, elasticzo::coordinator::EngineKind::Xla);
        let mut params = ParamSet::init(Model::LeNet, 0xE2E);
        let cfg = fp32_train_config(method, epochs, 32, 0xE2E);
        let t0 = std::time::Instant::now();
        let r = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &cfg)?;
        println!("\n=== {} ({:?}) ===", method.label(), t0.elapsed());
        for row in r.history.curve_rows() {
            println!("  {row}");
        }
        results.push((method, r.history.best_test_acc()));
    }

    println!("\n=== summary (paper Table 1 ordering check) ===");
    for (m, acc) in &results {
        println!("  {:<14} {:.2}%", m.label(), acc * 100.0);
    }
    let acc = |m: Method| results.iter().find(|(mm, _)| *mm == m).unwrap().1;
    assert!(
        acc(Method::FullZo) < acc(Method::Cls1),
        "ElasticZO-Cls1 must beat Full ZO"
    );
    assert!(
        acc(Method::FullZo) < acc(Method::Cls2),
        "ElasticZO-Cls2 must beat Full ZO"
    );
    println!("\nheadline ordering holds: Full ZO < ElasticZO (Cls2, Cls1)");
    Ok(())
}
