//! Quickstart: train LeNet-5 with ElasticZO (ZO body + BP on the last
//! two FC layers — the paper's ZO-Feat-Cls1) on the synthetic MNIST
//! stand-in, using the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use elasticzo::coordinator::{trainer, Method, Model, ParamSet, TrainConfig};
use elasticzo::data;
use elasticzo::exp::build_engine;

fn main() -> anyhow::Result<()> {
    // 1. data: deterministic, procedurally generated (no downloads)
    let (train_d, test_d) =
        data::generate(data::DatasetKind::SynthMnist, 1024, 512, /*seed=*/ 7, 0);
    println!("dataset: {} train / {} test samples", train_d.len(), test_d.len());

    // 2. engine: AOT XLA artifacts via PJRT (falls back to the native
    //    rust engine if artifacts/ hasn't been built)
    let mut engine =
        build_engine(Model::LeNet, /*batch=*/ 32, elasticzo::coordinator::EngineKind::Xla);

    // 3. parameters + ElasticZO training configuration
    let mut params = ParamSet::init(Model::LeNet, 42);
    let method = Method::Cls1; // ZO-Feat-Cls1: BP on the last two FC layers
    println!(
        "model: LeNet-5, {} params ({} trained by ZO, {} by BP)",
        params.num_params(),
        params.zo_param_count(method.bp_layers()),
        params.num_params() - params.zo_param_count(method.bp_layers()),
    );
    let cfg = TrainConfig {
        method,
        epochs: 8,
        batch: 32,
        lr0: 2e-3,
        eps: 1e-2,
        g_clip: 5.0,
        seed: 42,
        eval_every: 1,
        verbose: true,
    };

    // 4. train and report
    let result = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &cfg)?;
    println!(
        "\nbest test accuracy: {:.2}% (engine: {})",
        result.history.best_test_acc() * 100.0,
        engine.name()
    );
    println!("{}", result.timer.report("phase breakdown"));
    Ok(())
}
