//! Memory-model explorer: prints the paper's Eqs. 2–5 / 13–15 for any
//! model, batch size, precision and optimizer — the numbers behind
//! Figs. 4–6 — and checks the paper's headline ratios.
//!
//! ```bash
//! cargo run --release --example memory_report
//! ```

use elasticzo::coordinator::Method;
use elasticzo::memory::{self, models};
use elasticzo::util::table::{bytes, Table};

fn main() {
    // LeNet FP32, the Fig. 4 sweep
    for batch in [32usize, 256] {
        let layers = models::lenet_layers();
        let mut t = Table::new(
            &format!("LeNet-5 FP32, B={batch} (paper Fig. 4)"),
            &["method", "total", "vs Full ZO", "vs inference"],
        );
        let zo = memory::fp32(&layers, batch, Method::FullZo.memory_method(), false).total();
        for m in [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp] {
            let b = memory::fp32(&layers, batch, m.memory_method(), false).total();
            t.row(&[
                m.label().to_string(),
                bytes(b),
                format!("{:+.2}%", 100.0 * (b as f64 - zo as f64) / zo as f64),
                format!("{:.2}x", b as f64 / zo as f64),
            ]);
        }
        t.print();
    }

    // INT8 savings (paper: 1.46-1.60x, NOT 4x — int32 scratch)
    let fp = models::lenet_layers();
    let i8l = models::lenet_int8_layers();
    println!("## INT8 savings vs FP32 (paper: 1.46-1.60x)");
    for m in [Method::FullZo, Method::Cls2, Method::Cls1] {
        for batch in [32usize, 256] {
            let f = memory::fp32(&fp, batch, m.memory_method(), false).total();
            let i = memory::int8(&i8l, batch, m.memory_method()).total();
            println!("  {:<13} B={batch:<4} {:.2}x", m.label(), f as f64 / i as f64);
        }
    }

    // Adam tax (paper Eq. 5)
    println!("\n## Optimizer-state tax (paper Eq. 5, Full BP LeNet B=32)");
    let layers = models::lenet_layers();
    let sgd = memory::fp32(&layers, 32, Method::FullBp.memory_method(), false).total();
    let adam = memory::fp32(&layers, 32, Method::FullBp.memory_method(), true).total();
    println!("  SGD  {}", bytes(sgd));
    println!("  Adam {} (+{})", bytes(adam), bytes(adam - sgd));

    // PointNet (Fig. 6)
    let pn = models::pointnet_layers(1024, 40);
    println!("\n## PointNet FP32, B=32, N=1024 (paper Fig. 6)");
    for m in [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp] {
        let b = memory::fp32(&pn, 32, m.memory_method(), false);
        println!(
            "  {:<13} total {}  (acts+errors {:.2}%)",
            m.label(),
            bytes(b.total()),
            100.0 * (b.acts + b.errors) as f64 / b.total() as f64
        );
    }
}
