//! PointNet on the synthetic ModelNet40 stand-in (paper Table 1, last
//! column; Fig. 6 memory): 40-way 3-D point-cloud classification where
//! Full ZO fails from scratch but ElasticZO trains the 800k-parameter
//! model with only the 2-layer head on BP.
//!
//! ```bash
//! make artifacts && cargo run --release --example pointnet_modelnet
//! ```

use elasticzo::coordinator::{trainer, Method, Model, ParamSet};
use elasticzo::data;
use elasticzo::exp::{build_engine, fp32_train_config};
use elasticzo::memory;
use elasticzo::util::table::bytes;

fn main() -> anyhow::Result<()> {
    let model = Model::PointNet { npoints: 128, ncls: 40 };
    let (train_d, test_d) =
        data::generate(data::DatasetKind::SynthModelNet, 1600, 640, 21, 128);
    println!(
        "dataset: {} train / {} test clouds, 40 classes, 128 points each",
        train_d.len(),
        test_d.len()
    );

    // paper Fig. 6: memory at the paper's full scale (N=1024, B=32)
    let layers = memory::models::pointnet_layers(1024, 40);
    for m in [Method::FullZo, Method::Cls2, Method::FullBp] {
        let b = memory::fp32(&layers, 32, m.memory_method(), false);
        println!("  memory[{:<13}] = {}", m.label(), bytes(b.total()));
    }

    let mut results = Vec::new();
    for method in [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp] {
        let mut engine =
            build_engine(model, 16, elasticzo::coordinator::EngineKind::Xla);
        let mut params = ParamSet::init(model, 21);
        let cfg = fp32_train_config(method, 12, 16, 21);
        let r = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &cfg)?;
        println!(
            "{:<14} best acc {:.2}%",
            method.label(),
            r.history.best_test_acc() * 100.0
        );
        results.push((method, r.history.best_test_acc()));
    }

    let acc = |m: Method| results.iter().find(|(mm, _)| *mm == m).unwrap().1;
    // paper: Full ZO fails on PointNet from scratch; ElasticZO works
    assert!(acc(Method::Cls1) > acc(Method::FullZo));
    println!("\nElasticZO rescues PointNet where Full ZO stalls — as in the paper");
    Ok(())
}
