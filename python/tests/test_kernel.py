"""Pallas kernels (interpret=True) vs pure-jnp oracles — the CORE
correctness signal for L1.

Hypothesis sweeps shapes (deliberately non-tile-aligned) and value
ranges; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import conv2d as conv_k
from compile.kernels import int8_matmul as imk
from compile.kernels import matmul as mk
from compile.kernels import ref
from compile.kernels import softmax_ce as ce_k

DIM = st.integers(min_value=1, max_value=200)
SMALL = st.integers(min_value=1, max_value=48)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# f32 matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    y = r.standard_normal((k, n), dtype=np.float32)
    out = np.array(mk.matmul(jnp.array(x), jnp.array(y)))
    expect = np.array(ref.matmul(jnp.array(x), jnp.array(y)))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 1), (8, 8, 8), (128, 128, 128),
                                   (129, 257, 65), (37, 784, 120)])
def test_matmul_shapes(shape):
    m, k, n = shape
    r = rng(0)
    x = r.standard_normal((m, k), dtype=np.float32)
    y = r.standard_normal((k, n), dtype=np.float32)
    out = np.array(mk.matmul(jnp.array(x), jnp.array(y)))
    np.testing.assert_allclose(out, x @ y, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
def test_matmul_tile_sweep(bm, bn, bk):
    """Block-shape sweep: every tiling computes the same product."""
    r = rng(1)
    x = r.standard_normal((50, 70), dtype=np.float32)
    y = r.standard_normal((70, 30), dtype=np.float32)
    out = np.array(mk.matmul(jnp.array(x), jnp.array(y), bm=bm, bn=bn, bk=bk))
    np.testing.assert_allclose(out, x @ y, rtol=1e-4, atol=1e-4)


def test_matmul_bias_relu():
    r = rng(2)
    x = r.standard_normal((33, 20), dtype=np.float32)
    w = r.standard_normal((20, 11), dtype=np.float32)
    b = r.standard_normal((11,), dtype=np.float32)
    out = np.array(mk.matmul_bias_act(jnp.array(x), jnp.array(w), jnp.array(b), act="relu"))
    expect = np.maximum(x @ w + b, 0.0)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)
    assert (out >= 0).all()


# ---------------------------------------------------------------------------
# int8 matmul — exact integer arithmetic
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_int8_matmul_exact(m, k, n, seed):
    r = rng(seed)
    x = r.integers(-128, 128, (m, k), dtype=np.int8)
    y = r.integers(-128, 128, (k, n), dtype=np.int8)
    out = np.array(imk.int8_matmul(jnp.array(x), jnp.array(y)))
    expect = x.astype(np.int32) @ y.astype(np.int32)
    np.testing.assert_array_equal(out, expect)
    assert out.dtype == np.int32


def test_int8_matmul_extremes():
    """Saturated operands: |acc| up to 128*127*K must not overflow int32."""
    k = 512
    x = np.full((4, k), -128, dtype=np.int8)
    y = np.full((k, 4), 127, dtype=np.int8)
    out = np.array(imk.int8_matmul(jnp.array(x), jnp.array(y)))
    np.testing.assert_array_equal(out, np.full((4, 4), -128 * 127 * k, dtype=np.int32))


# ---------------------------------------------------------------------------
# conv2d (im2col + pallas matmul)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 8),
    cin=st.integers(1, 6),
    cout=st.integers(1, 16),
    hw=st.integers(5, 28),
    ksz=st.sampled_from([3, 5]),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, cin, cout, hw, ksz, pad, seed):
    r = rng(seed)
    x = r.standard_normal((b, cin, hw, hw), dtype=np.float32)
    w = r.standard_normal((cout, cin, ksz, ksz), dtype=np.float32)
    bias = r.standard_normal((cout,), dtype=np.float32)
    out = np.array(conv_k.conv2d(jnp.array(x), jnp.array(w), jnp.array(bias), pad))
    expect = np.array(ref.conv2d(jnp.array(x), jnp.array(w), jnp.array(bias), pad))
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_conv2d_int8_exact():
    r = rng(7)
    x = r.integers(-128, 128, (4, 6, 14, 14), dtype=np.int8)
    w = r.integers(-128, 128, (16, 6, 5, 5), dtype=np.int8)
    out = np.array(conv_k.conv2d_int8(jnp.array(x), jnp.array(w), pad=2))
    # int32 exact reference via the float path on widened ints
    expect = np.array(
        ref.conv2d(
            jnp.array(x, dtype=jnp.float32),
            jnp.array(w, dtype=jnp.float32),
            jnp.zeros((16,), dtype=jnp.float32),
            pad=2,
        )
    ).astype(np.int64)
    np.testing.assert_array_equal(out.astype(np.int64), expect)


def test_lenet_conv_shapes():
    """The exact LeNet-5 shapes flowing through the conv kernel."""
    r = rng(3)
    x = r.standard_normal((32, 1, 28, 28), dtype=np.float32)
    w = r.standard_normal((6, 1, 5, 5), dtype=np.float32)
    b = np.zeros(6, dtype=np.float32)
    out = conv_k.conv2d(jnp.array(x), jnp.array(w), jnp.array(b), pad=2)
    assert out.shape == (32, 6, 28, 28)


# ---------------------------------------------------------------------------
# fused softmax cross-entropy
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 300),
    n=st.sampled_from([10, 40]),
    scale=st.floats(0.1, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_ce_matches_ref(b, n, scale, seed):
    r = rng(seed)
    logits = (r.standard_normal((b, n)) * scale).astype(np.float32)
    onehot = np.eye(n, dtype=np.float32)[r.integers(0, n, b)]
    out = float(ce_k.softmax_cross_entropy(jnp.array(logits), jnp.array(onehot)))
    expect = float(ref.softmax_cross_entropy(jnp.array(logits), jnp.array(onehot)))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_softmax_ce_uniform_logits():
    """Zero logits -> loss is exactly log(NCLASS)."""
    logits = np.zeros((16, 10), dtype=np.float32)
    onehot = np.eye(10, dtype=np.float32)[np.arange(16) % 10]
    out = float(ce_k.softmax_cross_entropy(jnp.array(logits), jnp.array(onehot)))
    np.testing.assert_allclose(out, np.log(10.0), rtol=1e-6)


def test_softmax_ce_large_logits_stable():
    """Numerical stability: huge logits must not produce inf/nan."""
    logits = np.array([[1000.0, 0.0], [-1000.0, 0.0]], dtype=np.float32)
    onehot = np.array([[1.0, 0.0], [0.0, 1.0]], dtype=np.float32)
    out = float(ce_k.softmax_cross_entropy(jnp.array(logits), jnp.array(onehot)))
    assert np.isfinite(out)
