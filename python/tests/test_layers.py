"""L2 layer wrappers vs plain-jnp behaviour (pooling, point-shared FC),
plus INT8 graph/fast-graph agreement at the model level."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import layers, model


def rng(seed=0):
    return np.random.default_rng(seed)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 4), c=st.integers(1, 8), hw=st.sampled_from([4, 8, 14, 28]),
       seed=st.integers(0, 2**31 - 1))
def test_maxpool2_matches_numpy(b, c, hw, seed):
    x = rng(seed).standard_normal((b, c, hw, hw)).astype(np.float32)
    out = np.array(layers.maxpool2(jnp.array(x)))
    expect = x.reshape(b, c, hw // 2, 2, hw // 2, 2).max(axis=(3, 5))
    np.testing.assert_allclose(out, expect)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), n=st.integers(1, 16), cin=st.integers(1, 8),
       cout=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_linear_points_equals_per_point_linear(b, n, cin, cout, seed):
    r = rng(seed)
    x = r.standard_normal((b, n, cin)).astype(np.float32)
    w = r.standard_normal((cin, cout)).astype(np.float32)
    bias = r.standard_normal((cout,)).astype(np.float32)
    out = np.array(layers.linear_points(jnp.array(x), jnp.array(w), jnp.array(bias), act="relu"))
    expect = np.maximum(x @ w + bias, 0.0)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_global_maxpool_points():
    x = rng(1).standard_normal((2, 5, 7)).astype(np.float32)
    out = np.array(layers.global_maxpool_points(jnp.array(x)))
    np.testing.assert_allclose(out, x.max(axis=1))


def test_lenet_fast_variant_is_pallas_variant():
    """The `_fast` artifact lowers the SAME math as the Pallas one —
    the contract behind the rust engine's default forward."""
    r = rng(2)
    params = [jnp.array(r.standard_normal(s).astype(np.float32) * 0.1)
              for _, s in model.LENET_PARAMS]
    x = jnp.array(r.standard_normal((4, 1, 28, 28)).astype(np.float32))
    y = jnp.array(np.eye(10, dtype=np.float32)[r.integers(0, 10, 4)])
    outs_p = model.lenet_fwd(params, x, y, use_pallas=True)
    outs_f = model.lenet_fwd(params, x, y, use_pallas=False)
    for a, b in zip(outs_p, outs_f):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-3, atol=1e-4)


def test_pointnet_fast_variant_matches():
    r = rng(3)
    params = [jnp.array(r.standard_normal(s).astype(np.float32) * 0.05)
              for _, s in model.pointnet_params(40)]
    x = jnp.array(r.standard_normal((2, 16, 3)).astype(np.float32))
    y = jnp.array(np.eye(40, dtype=np.float32)[r.integers(0, 40, 2)])
    outs_p = model.pointnet_fwd(params, x, y, use_pallas=True)
    outs_f = model.pointnet_fwd(params, x, y, use_pallas=False)
    for a, b in zip(outs_p, outs_f):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-3, atol=1e-4)
