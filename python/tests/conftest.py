import os
import sys

# Allow running `pytest python/tests/` from the repo root: the test
# modules import `compile.*`, which lives in python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
