"""NITI INT8 graph: exact-arithmetic properties of bitwidth/rshift_round/
requantize (hypothesis), full int8 forward sanity, and a numpy NITI
mini-reference parity check."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import int8_model


# ---------------------------------------------------------------------------
# bitwidth — exact integer log2
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(v=st.integers(0, 2**31 - 1))
def test_bitwidth_exact(v):
    expect = 0 if v == 0 else int(v).bit_length()
    got = int(int8_model.bitwidth(jnp.int32(v)))
    assert got == expect


@pytest.mark.parametrize("v,b", [(0, 0), (1, 1), (2, 2), (3, 2), (127, 7),
                                 (128, 8), (255, 8), (256, 9), (2**30, 31)])
def test_bitwidth_boundaries(v, b):
    assert int(int8_model.bitwidth(jnp.int32(v))) == b


# ---------------------------------------------------------------------------
# rshift_round — round-to-nearest, ties away from zero, sign-symmetric
# ---------------------------------------------------------------------------


def py_rshift_round(v: int, k: int) -> int:
    if k == 0:
        return v
    a = abs(v)
    r = (a + (1 << (k - 1))) >> k
    return -r if v < 0 else r


@settings(max_examples=200, deadline=None)
@given(v=st.integers(-(2**24), 2**24), k=st.integers(0, 20))
def test_rshift_round_matches_python_model(v, k):
    got = int(int8_model.rshift_round(jnp.int32(v), jnp.int32(k)))
    assert got == py_rshift_round(v, k)


@settings(max_examples=100, deadline=None)
@given(v=st.integers(0, 2**24), k=st.integers(0, 20))
def test_rshift_round_sign_symmetric(v, k):
    plus = int(int8_model.rshift_round(jnp.int32(v), jnp.int32(k)))
    minus = int(int8_model.rshift_round(jnp.int32(-v), jnp.int32(k)))
    assert plus == -minus


@settings(max_examples=100, deadline=None)
@given(v=st.integers(-(2**24), 2**24), k=st.integers(1, 20))
def test_rshift_round_error_bound(v, k):
    """|round(v / 2^k) - v/2^k| <= 1/2."""
    got = int(int8_model.rshift_round(jnp.int32(v), jnp.int32(k)))
    assert abs(got - v / 2**k) <= 0.5 + 1e-9


# ---------------------------------------------------------------------------
# requantize
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1, 100, 10_000, 1_000_000]))
def test_requantize_range_and_exponent(seed, scale):
    r = np.random.default_rng(seed)
    acc = (r.standard_normal((4, 16)) * scale).astype(np.int32)
    out, s = int8_model.requantize(jnp.array(acc), jnp.int32(3))
    out = np.array(out)
    assert out.dtype == np.int8
    assert np.abs(out.astype(np.int32)).max() <= 127
    # exponent conservation: out * 2^(s-3) ~= acc within rounding
    shift = int(s) - 3
    approx = out.astype(np.int64) << shift
    err = np.abs(approx - acc.astype(np.int64)).max()
    assert err <= (1 << max(shift - 1, 0)) + 1


def test_requantize_small_values_identity():
    """|acc| <= 127 -> no shift, exponent unchanged."""
    acc = jnp.array(np.arange(-127, 128, dtype=np.int32).reshape(5, 51))
    out, s = int8_model.requantize(acc, jnp.int32(7))
    np.testing.assert_array_equal(np.array(out), np.array(acc, dtype=np.int8))
    assert int(s) == 7


def test_requantize_zero_tensor():
    out, s = int8_model.requantize(jnp.zeros((3, 3), jnp.int32), jnp.int32(2))
    assert np.array(out).sum() == 0 and int(s) == 2


# ---------------------------------------------------------------------------
# full INT8 forward
# ---------------------------------------------------------------------------


def int8_params(seed=0, rmax=32):
    r = np.random.default_rng(seed)
    ws = [
        jnp.array(r.integers(-rmax, rmax + 1, s, dtype=np.int8))
        for _, s in int8_model.LENET_INT8_PARAMS
    ]
    exps = [jnp.int32(-7) for _ in ws]
    return ws, exps


def test_lenet_int8_fwd_shapes_and_range():
    ws, exps = int8_params()
    r = np.random.default_rng(1)
    x = jnp.array(r.integers(-127, 128, (8, 1, 28, 28), dtype=np.int8))
    logits, s = int8_model.lenet_int8_fwd(ws, exps, x, jnp.int32(-7))
    assert logits.shape == (8, 10)
    assert logits.dtype == jnp.int8
    assert np.abs(np.array(logits, dtype=np.int32)).max() <= 127
    assert np.isfinite(int(s))


def test_lenet_int8_fwd_deterministic():
    ws, exps = int8_params()
    r = np.random.default_rng(2)
    x = jnp.array(r.integers(-127, 128, (4, 1, 28, 28), dtype=np.int8))
    l1, s1 = int8_model.lenet_int8_fwd(ws, exps, x, jnp.int32(-7))
    l2, s2 = int8_model.lenet_int8_fwd(ws, exps, x, jnp.int32(-7))
    np.testing.assert_array_equal(np.array(l1), np.array(l2))
    assert int(s1) == int(s2)


def test_lenet_int8_fwd_perturbation_changes_logits():
    """An int8 weight perturbation (the ZO probe) must reach the logits."""
    ws, exps = int8_params()
    r = np.random.default_rng(3)
    x = jnp.array(r.integers(-127, 128, (4, 1, 28, 28), dtype=np.int8))
    l1, _ = int8_model.lenet_int8_fwd(ws, exps, x, jnp.int32(-7))
    ws2 = list(ws)
    pert = r.integers(-15, 16, ws[0].shape, dtype=np.int8)
    ws2[0] = jnp.array(
        np.clip(np.array(ws[0], dtype=np.int32) + pert, -127, 127).astype(np.int8)
    )
    l2, _ = int8_model.lenet_int8_fwd(ws2, exps, x, jnp.int32(-7))
    assert not np.array_equal(np.array(l1), np.array(l2))


# ---------------------------------------------------------------------------
# numpy NITI mini-reference parity (one FC layer)
# ---------------------------------------------------------------------------


def numpy_niti_fc(x, w, s_in, s_w):
    acc = x.astype(np.int32) @ w.astype(np.int32)
    maxabs = int(np.abs(acc).max())
    b = maxabs.bit_length()
    shift = max(b - 7, 0)
    out = np.array([py_rshift_round(int(v), shift) for v in acc.ravel()]).reshape(acc.shape)
    out = np.clip(out, -127, 127).astype(np.int8)
    return out, s_in + s_w + shift


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_fc_matches_numpy_niti(seed):
    from compile.kernels import int8_matmul as imk

    r = np.random.default_rng(seed)
    x = r.integers(-127, 128, (4, 24), dtype=np.int8)
    w = r.integers(-127, 128, (24, 10), dtype=np.int8)
    acc = imk.int8_matmul(jnp.array(x), jnp.array(w))
    out, s = int8_model.requantize(acc, jnp.int32(-7) + jnp.int32(-7))
    expect, s_ref = numpy_niti_fc(x, w, -7, -7)
    np.testing.assert_array_equal(np.array(out), expect)
    assert int(s) == s_ref
