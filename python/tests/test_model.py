"""L2 model correctness: Pallas forward == reference forward, hand-written
tail-BP == jax.grad, full-BP step decreases the loss."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


def rng(seed=0):
    return np.random.default_rng(seed)


def lenet_init(seed=0, scale=0.1):
    r = rng(seed)
    return [
        jnp.array(r.standard_normal(s, dtype=np.float32) * scale)
        for _, s in model.LENET_PARAMS
    ]


def pointnet_init(seed=0, scale=0.05, ncls=40):
    r = rng(seed)
    return [
        jnp.array(r.standard_normal(s, dtype=np.float32) * scale)
        for _, s in model.pointnet_params(ncls)
    ]


def batch_lenet(bsz=8, seed=1):
    r = rng(seed)
    x = jnp.array(r.standard_normal((bsz, 1, 28, 28), dtype=np.float32))
    y = jnp.array(np.eye(10, dtype=np.float32)[r.integers(0, 10, bsz)])
    return x, y


def batch_pointnet(bsz=4, n=32, ncls=40, seed=1):
    r = rng(seed)
    x = jnp.array(r.standard_normal((bsz, n, 3), dtype=np.float32))
    y = jnp.array(np.eye(ncls, dtype=np.float32)[r.integers(0, ncls, bsz)])
    return x, y


# ---------------------------------------------------------------------------
# parameter-count sanity (the paper's exact LeNet variant)
# ---------------------------------------------------------------------------


def test_lenet_param_count_matches_paper():
    total = sum(int(np.prod(s)) for _, s in model.LENET_PARAMS)
    assert total == 107_786  # paper Sec. 5.1.1
    # ZO-Feat-Cls1: all but fc3 trained by ZO -> 106,936
    zo1 = total - (84 * 10 + 10)
    assert zo1 == 106_936
    # ZO-Feat-Cls2: all but fc2+fc3 -> 96,772
    zo2 = zo1 - (120 * 84 + 84)
    assert zo2 == 96_772


def test_pointnet_param_count_near_paper():
    total = sum(int(np.prod(s)) for _, s in model.pointnet_params(40))
    # paper: 816,744 (vanilla PointNet, incl. whatever small extras); our
    # no-T-net variant must land within 0.5%.
    assert abs(total - 816_744) / 816_744 < 0.005
    # the BP-tail sizes ARE exact:
    assert 256 * 40 + 40 == 10_280  # Cls1 tail
    assert 512 * 256 + 256 + 10_280 == 141_608  # Cls2 tail


# ---------------------------------------------------------------------------
# pallas forward == reference forward
# ---------------------------------------------------------------------------


def test_lenet_pallas_vs_ref_forward():
    params = lenet_init()
    x, y = batch_lenet()
    lp, gp, a1p, a2p = model.lenet_fwd(params, x, y, use_pallas=True)
    lr_, gr, a1r, a2r = model.lenet_fwd(params, x, y, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-4)
    np.testing.assert_allclose(np.array(gp), np.array(gr), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(a1p), np.array(a1r), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(a2p), np.array(a2r), rtol=1e-3, atol=1e-4)


def test_pointnet_pallas_vs_ref_forward():
    params = pointnet_init()
    x, y = batch_pointnet()
    lp, gp, h1p, h2p = model.pointnet_fwd(params, x, y, use_pallas=True)
    lr_, gr, h1r, h2r = model.pointnet_fwd(params, x, y, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-4)
    np.testing.assert_allclose(np.array(gp), np.array(gr), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(h1p), np.array(h1r), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(h2p), np.array(h2r), rtol=1e-3, atol=1e-4)


def test_lenet_fwd_shapes():
    params = lenet_init()
    x, y = batch_lenet(bsz=8)
    loss, logits, a1, a2 = model.lenet_fwd(params, x, y)
    assert loss.shape == ()
    assert logits.shape == (8, 10)
    assert a1.shape == (8, 120)
    assert a2.shape == (8, 84)
    assert (np.array(a1) >= 0).all() and (np.array(a2) >= 0).all()


def test_pointnet_fwd_shapes():
    params = pointnet_init()
    x, y = batch_pointnet(bsz=4, n=32)
    loss, logits, h1, h2 = model.pointnet_fwd(params, x, y)
    assert logits.shape == (4, 40)
    assert h1.shape == (4, 512)
    assert h2.shape == (4, 256)


def test_pointnet_permutation_invariance():
    """Max-pool aggregation => logits invariant to point ordering."""
    params = pointnet_init()
    x, y = batch_pointnet(bsz=2, n=16)
    perm = np.random.default_rng(5).permutation(16)
    _, l1, _, _ = model.pointnet_fwd(params, x, y)
    _, l2, _, _ = model.pointnet_fwd(params, x[:, perm, :], y)
    np.testing.assert_allclose(np.array(l1), np.array(l2), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# hand-written tail BP == jax.grad
# ---------------------------------------------------------------------------


def test_fc_tail1_grads_match_autodiff():
    r = rng(4)
    a = jnp.array(r.standard_normal((8, 84), dtype=np.float32))
    w = jnp.array(r.standard_normal((84, 10), dtype=np.float32) * 0.1)
    b = jnp.array(r.standard_normal((10,), dtype=np.float32) * 0.1)
    y = jnp.array(np.eye(10, dtype=np.float32)[r.integers(0, 10, 8)])

    def loss_fn(w, b):
        from compile.kernels import ref
        return ref.softmax_cross_entropy(a @ w + b, y)

    gw_ref, gb_ref = jax.grad(loss_fn, argnums=(0, 1))(w, b)
    gw, gb = model.fc_tail1_grads(a, w, b, y)
    np.testing.assert_allclose(np.array(gw), np.array(gw_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.array(gb), np.array(gb_ref), rtol=1e-4, atol=1e-5)


def test_fc_tail2_grads_match_autodiff():
    r = rng(5)
    a1 = jnp.array(np.abs(r.standard_normal((8, 120))).astype(np.float32))
    w4 = jnp.array(r.standard_normal((120, 84), dtype=np.float32) * 0.1)
    b4 = jnp.array(r.standard_normal((84,), dtype=np.float32) * 0.1)
    w5 = jnp.array(r.standard_normal((84, 10), dtype=np.float32) * 0.1)
    b5 = jnp.array(r.standard_normal((10,), dtype=np.float32) * 0.1)
    y = jnp.array(np.eye(10, dtype=np.float32)[r.integers(0, 10, 8)])

    def loss_fn(w4, b4, w5, b5):
        from compile.kernels import ref
        h = jnp.maximum(a1 @ w4 + b4, 0.0)
        return ref.softmax_cross_entropy(h @ w5 + b5, y)

    refs = jax.grad(loss_fn, argnums=(0, 1, 2, 3))(w4, b4, w5, b5)
    ours = model.fc_tail2_grads(a1, w4, b4, w5, b5, y)
    for g, gr in zip(ours, refs):
        np.testing.assert_allclose(np.array(g), np.array(gr), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# full-BP step
# ---------------------------------------------------------------------------


def test_lenet_step_decreases_loss():
    params = lenet_init()
    x, y = batch_lenet(bsz=16)
    out = model.lenet_step(params, x, y, jnp.float32(0.05))
    new_params, loss0 = list(out[:-2]), out[-2]
    loss1, _, _, _ = model.lenet_fwd(new_params, x, y, use_pallas=False)
    assert float(loss1) < float(loss0)


def test_pointnet_step_decreases_loss():
    params = pointnet_init()
    x, y = batch_pointnet(bsz=8, n=32)
    out = model.pointnet_step(params, x, y, jnp.float32(0.05))
    new_params, loss0 = list(out[:-2]), out[-2]
    loss1, _, _, _ = model.pointnet_fwd(new_params, x, y, use_pallas=False)
    assert float(loss1) < float(loss0)


def test_lenet_step_preserves_shapes():
    params = lenet_init()
    x, y = batch_lenet(bsz=8)
    out = model.lenet_step(params, x, y, jnp.float32(0.01))
    # 10 updated params + loss + the pre-step logits
    assert len(out) == 12
    for p, (name, shape) in zip(out[:-2], model.LENET_PARAMS):
        assert p.shape == shape, name
    assert out[-1].shape == (8, 10)


def test_lenet_step_logits_match_prestep_forward():
    params = lenet_init()
    x, y = batch_lenet(bsz=8)
    out = model.lenet_step(params, x, y, jnp.float32(0.01))
    _, logits, _, _ = model.lenet_fwd(params, x, y, use_pallas=False)
    assert jnp.allclose(out[-1], logits, atol=1e-5)
