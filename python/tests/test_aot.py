"""AOT pipeline: manifest consistency and HLO-text validity.

These tests exercise the same Builder used by `make artifacts` on a
small throwaway artifact set, then (if present) validate the real
artifacts/ directory against the model ABI."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_builder_roundtrip(tmp_path):
    b = aot.Builder(str(tmp_path))
    aot.build_lenet(b, batch=4)
    b.write_manifest()
    man = json.loads((tmp_path / "manifest.json").read_text())
    names = {e["name"] for e in man["entries"]}
    assert names == {"lenet_fwd_b4", "lenet_fwd_fast_b4", "lenet_tail_c1_b4",
                     "lenet_tail_c2_b4", "lenet_step_b4"}
    for e in man["entries"]:
        text = (tmp_path / e["path"]).read_text()
        assert text.startswith("HloModule"), e["name"]
        # jax lowers with return_tuple=True: root must be a tuple
        assert "ROOT" in text


def test_builder_int8_entry(tmp_path):
    b = aot.Builder(str(tmp_path))
    aot.build_lenet_int8(b, batch=4)
    b.write_manifest()
    man = json.loads((tmp_path / "manifest.json").read_text())
    (e,) = man["entries"]
    assert e["name"] == "lenet_int8_fwd_b4"
    # 5 weights + 5 exponents + x + x_exp
    assert len(e["inputs"]) == 12
    assert e["inputs"][0]["dtype"] == "i8"
    assert e["inputs"][5]["dtype"] == "i32"
    assert e["outputs"][0] == {"name": "logits", "shape": [4, 10], "dtype": "i8"}


def test_fwd_entry_abi_matches_model_spec(tmp_path):
    b = aot.Builder(str(tmp_path))
    aot.build_lenet(b, batch=4)
    fwd = next(e for e in b.entries if e["name"] == "lenet_fwd_b4")
    # first 10 inputs are exactly LENET_PARAMS in order
    for inp, (name, shape) in zip(fwd["inputs"], model.LENET_PARAMS):
        assert inp["name"] == name
        assert tuple(inp["shape"]) == shape
    assert fwd["inputs"][10]["name"] == "x"
    assert fwd["outputs"][0]["name"] == "loss"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts/ not built",
)
def test_real_manifest_consistent():
    man = json.loads(open(os.path.join(ART, "manifest.json")).read())
    assert man["version"] == 1
    for e in man["entries"]:
        path = os.path.join(ART, e["path"])
        assert os.path.exists(path), e["name"]
        head = open(path).read(64)
        assert head.startswith("HloModule"), e["name"]
        assert e["inputs"] and e["outputs"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts/ not built",
)
def test_real_manifest_covers_required_entries():
    man = json.loads(open(os.path.join(ART, "manifest.json")).read())
    names = {e["name"] for e in man["entries"]}
    required = {
        "lenet_fwd_b32", "lenet_tail_c1_b32", "lenet_tail_c2_b32",
        "lenet_step_b32", "lenet_int8_fwd_b32",
    }
    assert required <= names, required - names
    assert any(n.startswith("pointnet_fwd") for n in names)
