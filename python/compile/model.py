"""L2 models: LeNet-5 and PointNet forward / tail-backward / full-BP step.

These are the computations AOT-lowered by aot.py into artifacts/*.hlo.txt
and executed from the rust coordinator via PJRT. The split mirrors
ElasticZO (paper Alg. 1):

  *_fwd       — the forward+loss pass run TWICE per ZO step (l+, l-).
                Also returns the partition activations a_C.. consumed by
                the BP tail, so ElasticZO needs no third forward.
  *_tail_cK   — BP for the last K FC layers only (ZO-Feat-ClsK): takes
                the partition activation and the tail parameters, returns
                tail gradients. Hand-written VJP built from the Pallas
                matmul kernel (verified against jax.grad in pytest).
  *_step      — the Full-BP baseline: one SGD step over ALL parameters
                via jax.grad (forward uses the reference ops so XLA can
                fuse the whole fwd+bwd; pytest asserts the reference
                forward matches the Pallas forward).

Parameter layouts (ordering is the ABI contract with rust/src/runtime):

  LeNet-5 (paper variant, 107,786 params):
    conv1 (6,1,5,5)+(6,)  pad2 relu maxpool2   28x28 -> 14x14
    conv2 (16,6,5,5)+(16,) pad2 relu maxpool2  14x14 -> 7x7 (=784 flat)
    fc1 (784,120)+(120,) relu
    fc2 (120,84)+(84,)   relu
    fc3 (84,10)+(10,)
  PointNet (vanilla, no T-nets; ~= paper's 816,744 params):
    feat: point-shared FC 3->64->64->64->128->1024 (relu each), max-pool
    head: FC 1024->512 relu, 512->256 relu, 256->NCLS
"""

import jax
import jax.numpy as jnp

from . import layers
from .kernels import matmul as matmul_k
from .kernels import ref

# ---------------------------------------------------------------------------
# Parameter specifications (the rust ABI).
# ---------------------------------------------------------------------------

LENET_PARAMS = [
    ("conv1_w", (6, 1, 5, 5)),
    ("conv1_b", (6,)),
    ("conv2_w", (16, 6, 5, 5)),
    ("conv2_b", (16,)),
    ("fc1_w", (784, 120)),
    ("fc1_b", (120,)),
    ("fc2_w", (120, 84)),
    ("fc2_b", (84,)),
    ("fc3_w", (84, 10)),
    ("fc3_b", (10,)),
]

POINTNET_FEAT_DIMS = [3, 64, 64, 64, 128, 1024]
POINTNET_HEAD_DIMS = [1024, 512, 256, 40]


def pointnet_params(ncls: int = 40):
    specs = []
    dims = POINTNET_FEAT_DIMS
    for i in range(len(dims) - 1):
        specs.append((f"feat{i + 1}_w", (dims[i], dims[i + 1])))
        specs.append((f"feat{i + 1}_b", (dims[i + 1],)))
    hd = POINTNET_HEAD_DIMS[:-1] + [ncls]
    for i in range(len(hd) - 1):
        specs.append((f"head{i + 1}_w", (hd[i], hd[i + 1])))
        specs.append((f"head{i + 1}_b", (hd[i + 1],)))
    return specs


POINTNET_PARAMS = pointnet_params()

# ---------------------------------------------------------------------------
# LeNet-5
# ---------------------------------------------------------------------------


def lenet_fwd(params, x, y, use_pallas: bool = True):
    """Forward + loss. Returns (loss, logits, a_fc1, a_fc2).

    a_fc1: (B,120) post-ReLU input of fc2  (partition activation for C=L-2)
    a_fc2: (B,84)  post-ReLU input of fc3  (partition activation for C=L-1)
    """
    (c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b, f3w, f3b) = params
    if use_pallas:
        h = layers.conv2d(x, c1w, c1b, pad=2, act="relu")
        h = layers.maxpool2(h)
        h = layers.conv2d(h, c2w, c2b, pad=2, act="relu")
        h = layers.maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        a1 = layers.linear(h, f1w, f1b, act="relu")
        a2 = layers.linear(a1, f2w, f2b, act="relu")
        logits = layers.linear(a2, f3w, f3b)
        loss = layers.cross_entropy(logits, y)
    else:
        h = jnp.maximum(ref.conv2d(x, c1w, c1b, pad=2), 0.0)
        h = layers.maxpool2(h)
        h = jnp.maximum(ref.conv2d(h, c2w, c2b, pad=2), 0.0)
        h = layers.maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        a1 = jnp.maximum(h @ f1w + f1b, 0.0)
        a2 = jnp.maximum(a1 @ f2w + f2b, 0.0)
        logits = a2 @ f3w + f3b
        loss = ref.softmax_cross_entropy(logits, y)
    return loss, logits, a1, a2


def _softmax(z):
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def fc_tail1_grads(a, w, b, y):
    """Hand-written BP for a single trailing FC + mean-CE.

    e = (softmax(a@w+b) - y)/B ; gw = a^T e ; gb = sum(e).
    All matmuls go through the Pallas kernel.
    """
    bsz = a.shape[0]
    z = matmul_k.matmul(a, w) + b
    e = (_softmax(z) - y) / bsz
    gw = matmul_k.matmul(a.T, e)
    gb = jnp.sum(e, axis=0)
    return gw, gb


def fc_tail2_grads(a1, w4, b4, w5, b5, y):
    """Hand-written BP for the last TWO FC layers (ReLU between)."""
    bsz = a1.shape[0]
    z1 = matmul_k.matmul(a1, w4) + b4
    h = jnp.maximum(z1, 0.0)
    z2 = matmul_k.matmul(h, w5) + b5
    e2 = (_softmax(z2) - y) / bsz
    gw5 = matmul_k.matmul(h.T, e2)
    gb5 = jnp.sum(e2, axis=0)
    e1 = matmul_k.matmul(e2, w5.T) * (z1 > 0.0).astype(jnp.float32)
    gw4 = matmul_k.matmul(a1.T, e1)
    gb4 = jnp.sum(e1, axis=0)
    return gw4, gb4, gw5, gb5


def lenet_loss_ref(params, x, y):
    """Reference forward+loss for jax.grad (full-BP step)."""
    loss, logits, _, _ = lenet_fwd(params, x, y, use_pallas=False)
    return loss, logits


def lenet_step(params, x, y, lr):
    """Full-BP SGD step: returns (new_params..., loss, logits).

    The pre-step logits ride along so the rust coordinator can report
    train accuracy on the Full-BP path without an extra forward.
    """
    (loss, logits), grads = jax.value_and_grad(lenet_loss_ref, has_aux=True)(
        list(params), x, y
    )
    new = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new) + (loss, logits)


# ---------------------------------------------------------------------------
# PointNet
# ---------------------------------------------------------------------------


def pointnet_fwd(params, x, y, use_pallas: bool = True):
    """Forward + loss. Returns (loss, logits, h1, h2).

    h1: (B,512) post-ReLU input of head2 (partition activation for C=L-2)
    h2: (B,256) post-ReLU input of head3 (partition activation for C=L-1)
    """
    nfeat = len(POINTNET_FEAT_DIMS) - 1
    feat = params[: 2 * nfeat]
    head = params[2 * nfeat :]
    h = x
    for i in range(nfeat):
        w, b = feat[2 * i], feat[2 * i + 1]
        if use_pallas:
            h = layers.linear_points(h, w, b, act="relu")
        else:
            h = jnp.maximum(h @ w + b, 0.0)
    g = layers.global_maxpool_points(h)  # (B, 1024)
    w1, b1, w2, b2, w3, b3 = head
    if use_pallas:
        h1 = layers.linear(g, w1, b1, act="relu")
        h2 = layers.linear(h1, w2, b2, act="relu")
        logits = layers.linear(h2, w3, b3)
        loss = layers.cross_entropy(logits, y)
    else:
        h1 = jnp.maximum(g @ w1 + b1, 0.0)
        h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
        logits = h2 @ w3 + b3
        loss = ref.softmax_cross_entropy(logits, y)
    return loss, logits, h1, h2


def pointnet_loss_ref(params, x, y):
    loss, logits, _, _ = pointnet_fwd(params, x, y, use_pallas=False)
    return loss, logits


def pointnet_step(params, x, y, lr):
    """Full-BP SGD step over all PointNet parameters.

    Returns (new_params..., loss, logits) — see `lenet_step`.
    """
    (loss, logits), grads = jax.value_and_grad(pointnet_loss_ref, has_aux=True)(
        list(params), x, y
    )
    new = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new) + (loss, logits)
