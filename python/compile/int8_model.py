"""L2 INT8 model: NITI-style 8-bit LeNet-5 forward graph.

NITI (Wang et al., TPDS'22) represents every tensor as `int8 * 2^s`
(8-bit mantissa tensor + per-tensor scaling exponent). A layer does an
exact int8 x int8 -> int32 contraction (the Pallas int8 kernel), then
requantizes the int32 accumulator back to int8:

    b      = bitwidth(max |acc|)        (exact, integer compares only)
    shift  = max(b - 7, 0)
    out    = clamp(rshift_round(acc, shift), -127, 127)
    s_out  = s_in + s_w + shift

`rshift_round` is round-to-nearest, ties away from zero, sign-symmetric —
the SAME rule as rust/src/int8/rounding.rs, so the XLA artifact and the
native rust engine agree bit-for-bit (asserted in integration tests).
Everything below is integer arithmetic only (no float assist even inside
the artifact); NITI conv/fc layers carry no bias, as in the paper.

This graph is the forward used by ElasticZO-INT8's two ZO passes; the
ZO loss sign is computed on the rust side from the returned int8 logits
(float CE for the paper's "INT8" column, the Eq. 7-12 integer CE sign
for "INT8*").
"""

import jax.numpy as jnp

from .kernels import conv2d as conv_k
from .kernels import int8_matmul as imk

# LeNet-5 INT8 parameter ABI (no biases, as NITI): name, shape.
LENET_INT8_PARAMS = [
    ("conv1_w", (6, 1, 5, 5)),
    ("conv2_w", (16, 6, 5, 5)),
    ("fc1_w", (784, 120)),
    ("fc2_w", (120, 84)),
    ("fc3_w", (84, 10)),
]


def bitwidth(maxabs: jnp.ndarray) -> jnp.ndarray:
    """Minimum bitwidth to represent `maxabs` (int32 scalar, >= 0).

    b = floor(log2(x)) + 1 for x > 0, computed with integer shifts only
    (exact — no float log2), b = 0 for x = 0.
    """
    maxabs = maxabs.astype(jnp.int32)
    return sum(
        ((maxabs >> jnp.int32(i)) > 0).astype(jnp.int32) for i in range(31)
    )


def rshift_round(v: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Arithmetic right shift with round-to-nearest, ties away from zero.

    Sign-symmetric: rshift_round(-v, k) == -rshift_round(v, k).
    k is a traced int32 scalar >= 0; k == 0 is the identity.
    """
    k = k.astype(jnp.int32)
    a = jnp.abs(v)
    half = jnp.where(k > 0, (jnp.int32(1) << jnp.maximum(k - 1, 0)), 0)
    r = (a + half) >> k
    return jnp.where(v < 0, -r, r)


def requantize(acc: jnp.ndarray, s_in: jnp.ndarray):
    """int32 accumulator -> (int8 tensor, exponent delta applied).

    Returns (out_int8, s_out) with s_out = s_in + shift.
    """
    maxabs = jnp.max(jnp.abs(acc)).astype(jnp.int32)
    b = bitwidth(maxabs)
    shift = jnp.maximum(b - 7, 0)
    out = jnp.clip(rshift_round(acc, shift), -127, 127).astype(jnp.int8)
    return out, s_in + shift


def maxpool2_int8(x: jnp.ndarray) -> jnp.ndarray:
    b, c, h, w = x.shape
    return jnp.max(x.reshape(b, c, h // 2, 2, w // 2, 2), axis=(3, 5))


def relu_int8(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, jnp.int8(0))


def lenet_int8_fwd(params, exps, x, x_exp):
    """NITI LeNet-5 forward.

    params: 5 int8 weight tensors (LENET_INT8_PARAMS order)
    exps:   5 int32 scalars, the weight exponents s_w
    x:      (B,1,28,28) int8 input, x_exp: int32 scalar

    Returns (logits_int8 (B,10), s_out int32 scalar).
    """
    c1w, c2w, f1w, f2w, f3w = params
    s1, s2, s3, s4, s5 = exps

    acc = conv_k.conv2d_int8(x, c1w, pad=2)
    h, s = requantize(acc, x_exp + s1)
    h = maxpool2_int8(relu_int8(h))

    acc = conv_k.conv2d_int8(h, c2w, pad=2)
    h, s = requantize(acc, s + s2)
    h = maxpool2_int8(relu_int8(h))

    h = h.reshape(h.shape[0], -1)  # (B, 784)

    acc = imk.int8_matmul(h, f1w)
    h, s = requantize(acc, s + s3)
    h = relu_int8(h)

    acc = imk.int8_matmul(h, f2w)
    h, s = requantize(acc, s + s4)
    h = relu_int8(h)

    acc = imk.int8_matmul(h, f3w)
    logits, s = requantize(acc, s + s5)
    return logits, s
