"""L1 kernel: stride-1 'same'/valid 2-D convolution as im2col + Pallas matmul.

The paper's C++ engine lowers conv to an im2col GEMM on NEON; the TPU
counterpart is the identical transformation with the GEMM on the MXU via
the blocked Pallas matmul kernel (see matmul.py / DESIGN.md
§Hardware-Adaptation). The patch-matrix layout keeps the contraction
dimension (C*kh*kw) contiguous so the kernel streams (bk,bn) RHS tiles
straight out of VMEM.
"""

import jax.numpy as jnp

from . import matmul as mk
from . import int8_matmul as imk
from . import ref


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, pad: int):
    """(B,C,H,W) f32 conv (OC,C,kh,kw) + (OC,) -> (B,OC,OH,OW), stride 1."""
    oc, c, kh, kw = w.shape
    cols, (bsz, oh, ow) = ref.im2col(x, kh, kw, pad)  # (B*OH*OW, C*kh*kw)
    wmat = w.reshape(oc, c * kh * kw).T  # (C*kh*kw, OC)
    out = mk.matmul(cols, wmat) + b  # (B*OH*OW, OC)
    return out.reshape(bsz, oh, ow, oc).transpose(0, 3, 1, 2)


def conv2d_int8(x: jnp.ndarray, w: jnp.ndarray, pad: int):
    """(B,C,H,W) int8 conv (OC,C,kh,kw) int8 -> (B,OC,OH,OW) int32.

    NITI conv layers carry no bias; the int32 accumulator is requantized
    by the caller (see int8_model.py).
    """
    oc, c, kh, kw = w.shape
    cols, (bsz, oh, ow) = ref.im2col(x, kh, kw, pad)
    wmat = w.reshape(oc, c * kh * kw).T
    out = imk.int8_matmul(cols.astype(jnp.int8), wmat.astype(jnp.int8))
    return out.reshape(bsz, oh, ow, oc).transpose(0, 3, 1, 2)
