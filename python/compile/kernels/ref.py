"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: python/tests/ sweeps shapes and
dtypes with hypothesis and asserts the Pallas kernels (interpret=True)
match these references to tight tolerances.
"""

import jax.numpy as jnp


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """f32 matrix product, (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def matmul_bias_act(x, w, b, act: str = "none"):
    """Fused (M,K)@(K,N) + b with optional ReLU — the FC-layer oracle."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def int8_matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 accumulation, (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(
        x.astype(jnp.int32), y.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def im2col(x: jnp.ndarray, kh: int, kw: int, pad: int):
    """(B,C,H,W) -> (B*OH*OW, C*kh*kw) patch matrix, stride 1.

    Column ordering is (C, kh, kw) fastest-last, matching a weight
    reshape of (OC, C, kh, kw) -> (OC, C*kh*kw).
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh, ow = h + 2 * pad - kh + 1, w + 2 * pad - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, :, i : i + oh, j : j + ow])
    # (kh*kw, B, C, OH, OW) -> (B, OH, OW, C, kh*kw)
    patches = jnp.stack(cols, axis=0)
    patches = patches.transpose(1, 3, 4, 2, 0)
    return patches.reshape(b * oh * ow, c * kh * kw), (b, oh, ow)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, pad: int):
    """Direct conv oracle: (B,C,H,W) * (OC,C,kh,kw) + (OC,) -> (B,OC,OH,OW)."""
    import jax

    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b.reshape(1, -1, 1, 1)


def softmax_cross_entropy(logits: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy over the batch; numerically stable."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    picked = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - picked)
