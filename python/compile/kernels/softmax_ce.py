"""L1 Pallas kernel: fused softmax cross-entropy (mean over batch).

One grid step per batch-row block: the (bm, NCLASS) logit tile is reduced
in VMEM (row max -> exp -> log-sum-exp -> pick label logit) without ever
materializing the softmax, and per-row losses land in a (bm,) output that
the wrapper means over. This is the loss evaluated twice per ZO step
(l+ and l-), so it sits on the artifact hot path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _ce_kernel(logits_ref, onehot_ref, loss_ref):
    logits = logits_ref[...]
    onehot = onehot_ref[...]
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    picked = jnp.sum(logits * onehot, axis=-1)
    loss_ref[...] = lse - picked


def _tile(d: int, cap: int) -> int:
    t = 8
    while t * 2 <= min(d, cap):
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax_cross_entropy(
    logits: jnp.ndarray, onehot: jnp.ndarray, *, bm: int = BM
) -> jnp.ndarray:
    """Mean softmax CE over the batch; logits/onehot are (B, NCLASS) f32.

    Rows are padded to the block multiple with a benign pattern (zero
    logits, zero onehot -> per-row loss log(NCLASS) with picked=0); the
    wrapper masks padded rows out of the mean.
    """
    b, n = logits.shape
    bm = _tile(b, bm)
    pb = (-b) % bm
    lp = jnp.pad(logits, ((0, pb), (0, 0)))
    op = jnp.pad(onehot, ((0, pb), (0, 0)))
    per_row = pl.pallas_call(
        _ce_kernel,
        grid=((b + pb) // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b + pb,), jnp.float32),
        interpret=True,
    )(lp, op)
    return jnp.sum(per_row[:b]) / b
