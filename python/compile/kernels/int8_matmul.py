"""L1 Pallas kernel: int8 x int8 -> int32 blocked matmul.

This is the NITI hot-spot: every INT8 FC / conv layer is an int8
contraction accumulated in int32 (the TPU analogue of ARM NEON SDOT the
paper's C++ implementation uses). Tiles are (bm,bk)x(bk,bn) with an
int32 accumulator tile revisited across the K grid axis — on a real TPU
the int8 operands feed the MXU in its 8-bit mode.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 128, 128, 128


def _int8_matmul_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Widen to int32 before the contraction; MXU 8-bit mode does this
    # natively, interpret mode needs the explicit astype.
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        y_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def _pad2(x, m0, m1):
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _tile(d: int, cap: int) -> int:
    t = 8
    while t * 2 <= min(d, cap):
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def int8_matmul(
    x: jnp.ndarray, y: jnp.ndarray, *, bm: int = BM, bn: int = BN, bk: int = BK
):
    """(M,K) int8 @ (K,N) int8 -> (M,N) int32, exact integer arithmetic."""
    assert x.dtype == jnp.int8 and y.dtype == jnp.int8, (x.dtype, y.dtype)
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    xp, yp = _pad2(x, bm, bk), _pad2(y, bk, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    out = pl.pallas_call(
        _int8_matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]
