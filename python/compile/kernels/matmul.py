"""L1 Pallas kernel: blocked f32 matmul (+ fused bias / ReLU epilogue).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
(M/bm, N/bn, K/bk); each step holds one (bm,bk) LHS tile, one (bk,bn)
RHS tile and the (bm,bn) accumulator in VMEM and contracts on the MXU.
The K axis is the innermost grid dimension so the output/accumulator
tile stays resident while K streams through (revisited output block).

On this box kernels run with interpret=True (CPU PJRT); the real-TPU
VMEM/MXU analysis lives in DESIGN.md §9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-native 128 lanes / 8-row sublane multiples.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm,bn) output tile; accumulates over the K grid axis in-place."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


def _pad2(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _tile(d: int, cap: int) -> int:
    """Largest power-of-two tile <= min(d, cap), at least 8."""
    t = 8
    while t * 2 <= min(d, cap):
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = BM, bn: int = BN, bk: int = BK):
    """(M,K) @ (K,N) -> (M,N) in f32 via the blocked Pallas kernel.

    Shapes need not be tile-aligned; inputs are zero-padded to the grid
    and the result is sliced back.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    xp, yp = _pad2(x, bm, bk), _pad2(y, bk, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matmul_bias_act(x, w, b, act: str = "none", **tiles):
    """FC layer forward: pallas matmul + bias + optional ReLU epilogue."""
    out = matmul(x, w, **tiles) + b
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out
