"""AOT entry point: lower every L2 computation to HLO text + manifest.

Run once by `make artifacts`; python never appears on the training path.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

The emitted `manifest.json` is the ABI contract with rust/src/runtime:
for every artifact it records the input/output names, shapes and dtypes
in execution order.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import int8_model, model

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int8.dtype: "i8", jnp.int32.dtype: "i32"}


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def io_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": DTYPE_NAMES[s.dtype]}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []

    def add(self, name, fn, inputs, outputs, meta):
        """Lower fn over the named input specs and write the artifact."""
        in_specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.entries.append(
            {
                "name": name,
                "path": path,
                "inputs": [io_entry(n, s) for n, s in inputs],
                "outputs": [io_entry(n, s) for n, s in outputs],
                "meta": meta,
            }
        )
        print(f"  {name}: {len(text) / 1024:.0f} KiB, "
              f"{len(inputs)} in / {len(outputs)} out")

    def write_manifest(self):
        manifest = {"version": 1, "entries": self.entries}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.entries)} entries")


def f32s(pairs):
    return [(n, spec(s)) for n, s in pairs]


def build_lenet(b: Builder, batch: int):
    params = f32s(model.LENET_PARAMS)
    x = ("x", spec((batch, 1, 28, 28)))
    y = ("y", spec((batch, 10)))
    outs = [
        ("loss", spec(())),
        ("logits", spec((batch, 10))),
        ("a_fc1", spec((batch, 120))),
        ("a_fc2", spec((batch, 84))),
    ]
    b.add(
        f"lenet_fwd_b{batch}",
        lambda *a: model.lenet_fwd(a[:10], a[10], a[11]),
        params + [x, y],
        outs,
        {"model": "lenet", "kind": "fwd", "batch": batch},
    )
    # Fast variant: identical math through jnp/lax reference ops that
    # XLA-CPU fuses natively. The Pallas variant above is the TPU-shaped
    # kernel path (interpret-mode while-loops are slow on CPU PJRT);
    # pytest asserts the two agree, rust defaults to the fast one.
    # See DESIGN.md §9 / EXPERIMENTS.md §Perf.
    b.add(
        f"lenet_fwd_fast_b{batch}",
        lambda *a: model.lenet_fwd(a[:10], a[10], a[11], use_pallas=False),
        params + [x, y],
        outs,
        {"model": "lenet", "kind": "fwd_fast", "batch": batch},
    )
    b.add(
        f"lenet_tail_c1_b{batch}",
        model.fc_tail1_grads,
        [("a_fc2", spec((batch, 84))), ("fc3_w", spec((84, 10))),
         ("fc3_b", spec((10,))), y],
        [("g_fc3_w", spec((84, 10))), ("g_fc3_b", spec((10,)))],
        {"model": "lenet", "kind": "tail", "bp_layers": 1, "batch": batch},
    )
    b.add(
        f"lenet_tail_c2_b{batch}",
        model.fc_tail2_grads,
        [("a_fc1", spec((batch, 120))),
         ("fc2_w", spec((120, 84))), ("fc2_b", spec((84,))),
         ("fc3_w", spec((84, 10))), ("fc3_b", spec((10,))), y],
        [("g_fc2_w", spec((120, 84))), ("g_fc2_b", spec((84,))),
         ("g_fc3_w", spec((84, 10))), ("g_fc3_b", spec((10,)))],
        {"model": "lenet", "kind": "tail", "bp_layers": 2, "batch": batch},
    )
    b.add(
        f"lenet_step_b{batch}",
        lambda *a: model.lenet_step(a[:10], a[10], a[11], a[12]),
        params + [x, y, ("lr", spec(()))],
        [(f"new_{n}", s) for n, s in params]
        + [("loss", spec(())), ("logits", spec((batch, 10)))],
        {"model": "lenet", "kind": "step", "batch": batch},
    )


def build_pointnet(b: Builder, batch: int, npoints: int, ncls: int):
    pspecs = model.pointnet_params(ncls)
    params = f32s(pspecs)
    x = ("x", spec((batch, npoints, 3)))
    y = ("y", spec((batch, ncls)))
    np_ = len(params)
    outs = [
        ("loss", spec(())),
        ("logits", spec((batch, ncls))),
        ("h1", spec((batch, 512))),
        ("h2", spec((batch, 256))),
    ]
    b.add(
        f"pointnet_fwd_n{npoints}_b{batch}",
        lambda *a: model.pointnet_fwd(a[:np_], a[np_], a[np_ + 1]),
        params + [x, y],
        outs,
        {"model": "pointnet", "kind": "fwd", "batch": batch,
         "npoints": npoints, "ncls": ncls},
    )
    b.add(
        f"pointnet_fwd_fast_n{npoints}_b{batch}",
        lambda *a: model.pointnet_fwd(a[:np_], a[np_], a[np_ + 1], use_pallas=False),
        params + [x, y],
        outs,
        {"model": "pointnet", "kind": "fwd_fast", "batch": batch,
         "npoints": npoints, "ncls": ncls},
    )
    b.add(
        f"pointnet_tail_c1_n{npoints}_b{batch}",
        model.fc_tail1_grads,
        [("h2", spec((batch, 256))), ("head3_w", spec((256, ncls))),
         ("head3_b", spec((ncls,))), y],
        [("g_head3_w", spec((256, ncls))), ("g_head3_b", spec((ncls,)))],
        {"model": "pointnet", "kind": "tail", "bp_layers": 1, "batch": batch,
         "npoints": npoints, "ncls": ncls},
    )
    b.add(
        f"pointnet_tail_c2_n{npoints}_b{batch}",
        model.fc_tail2_grads,
        [("h1", spec((batch, 512))),
         ("head2_w", spec((512, 256))), ("head2_b", spec((256,))),
         ("head3_w", spec((256, ncls))), ("head3_b", spec((ncls,))), y],
        [("g_head2_w", spec((512, 256))), ("g_head2_b", spec((256,))),
         ("g_head3_w", spec((256, ncls))), ("g_head3_b", spec((ncls,)))],
        {"model": "pointnet", "kind": "tail", "bp_layers": 2, "batch": batch,
         "npoints": npoints, "ncls": ncls},
    )
    b.add(
        f"pointnet_step_n{npoints}_b{batch}",
        lambda *a: model.pointnet_step(a[:np_], a[np_], a[np_ + 1], a[np_ + 2]),
        params + [x, y, ("lr", spec(()))],
        [(f"new_{n}", s) for n, s in params]
        + [("loss", spec(())), ("logits", spec((batch, ncls)))],
        {"model": "pointnet", "kind": "step", "batch": batch,
         "npoints": npoints, "ncls": ncls},
    )


def build_lenet_int8(b: Builder, batch: int):
    params = [(n, spec(s, jnp.int8)) for n, s in int8_model.LENET_INT8_PARAMS]
    exps = [(f"{n}_exp", spec((), jnp.int32)) for n, _ in int8_model.LENET_INT8_PARAMS]
    x = ("x", spec((batch, 1, 28, 28), jnp.int8))
    xe = ("x_exp", spec((), jnp.int32))
    b.add(
        f"lenet_int8_fwd_b{batch}",
        lambda *a: int8_model.lenet_int8_fwd(a[:5], a[5:10], a[10], a[11]),
        params + exps + [x, xe],
        [("logits", spec((batch, 10), jnp.int8)), ("s_out", spec((), jnp.int32))],
        {"model": "lenet_int8", "kind": "fwd", "batch": batch},
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--lenet-batches", default="8,32")
    ap.add_argument("--pointnet-batch", type=int, default=16)
    ap.add_argument("--pointnet-npoints", type=int, default=128)
    ap.add_argument("--pointnet-ncls", type=int, default=40)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    b = Builder(args.out)
    for batch in [int(s) for s in args.lenet_batches.split(",")]:
        build_lenet(b, batch)
        build_lenet_int8(b, batch)
    build_pointnet(b, args.pointnet_batch, args.pointnet_npoints, args.pointnet_ncls)
    b.write_manifest()


if __name__ == "__main__":
    main()
