"""L2 building blocks: thin jnp layers that call the L1 Pallas kernels.

Everything here is traced by jax.jit in aot.py and lowered into the HLO
artifacts; nothing in this module runs at training time.
"""

import jax.numpy as jnp

from .kernels import conv2d as conv_k
from .kernels import matmul as matmul_k
from .kernels import softmax_ce as ce_k


def linear(x, w, b, act: str = "none"):
    """FC layer over (B, IN) f32 via the Pallas matmul kernel."""
    return matmul_k.matmul_bias_act(x, w, b, act=act)


def linear_points(x, w, b, act: str = "none"):
    """Shared ('point-wise') FC over (B, N, IN): PointNet's per-point MLP.

    Flattened to a (B*N, IN) GEMM so the whole point cloud hits the MXU
    as one contraction.
    """
    bsz, n, cin = x.shape
    out = matmul_k.matmul_bias_act(x.reshape(bsz * n, cin), w, b, act=act)
    return out.reshape(bsz, n, -1)


def conv2d(x, w, b, pad: int, act: str = "none"):
    """Conv layer via im2col + Pallas matmul."""
    out = conv_k.conv2d(x, w, b, pad)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def maxpool2(x):
    """2x2 stride-2 max pooling over (B,C,H,W)."""
    b, c, h, w = x.shape
    return jnp.max(x.reshape(b, c, h // 2, 2, w // 2, 2), axis=(3, 5))


def global_maxpool_points(x):
    """PointNet symmetric aggregation: (B,N,F) -> (B,F)."""
    return jnp.max(x, axis=1)


def cross_entropy(logits, onehot):
    """Mean softmax CE via the fused Pallas kernel."""
    return ce_k.softmax_cross_entropy(logits, onehot)
