//! Rotated-(F)MNIST construction (paper Table 2): rotate every image of
//! a dataset by a fixed angle with bilinear resampling about the image
//! centre — the distribution-shift fine-tuning target.

use super::Dataset;

/// Bilinear sample with zero padding outside the image.
fn bilinear(img: &[f32], side: usize, x: f32, y: f32) -> f32 {
    if x < -1.0 || y < -1.0 || x > side as f32 || y > side as f32 {
        return 0.0;
    }
    let x0 = x.floor() as isize;
    let y0 = y.floor() as isize;
    let fx = x - x0 as f32;
    let fy = y - y0 as f32;
    let get = |ix: isize, iy: isize| -> f32 {
        if ix < 0 || iy < 0 || ix >= side as isize || iy >= side as isize {
            0.0
        } else {
            img[iy as usize * side + ix as usize]
        }
    };
    let a = get(x0, y0) * (1.0 - fx) + get(x0 + 1, y0) * fx;
    let b = get(x0, y0 + 1) * (1.0 - fx) + get(x0 + 1, y0 + 1) * fx;
    a * (1.0 - fy) + b * fy
}

/// Rotate one `side`×`side` image by `deg` degrees (counter-clockwise).
pub fn rotate_image(img: &[f32], side: usize, deg: f32) -> Vec<f32> {
    let rad = deg.to_radians();
    let (s, c) = rad.sin_cos();
    let ctr = (side as f32 - 1.0) / 2.0;
    let mut out = vec![0.0f32; side * side];
    for iy in 0..side {
        for ix in 0..side {
            // inverse mapping: destination -> source
            let dx = ix as f32 - ctr;
            let dy = iy as f32 - ctr;
            let sx = c * dx + s * dy + ctr;
            let sy = -s * dx + c * dy + ctr;
            out[iy * side + ix] = bilinear(img, side, sx, sy);
        }
    }
    out
}

/// Rotate a whole image dataset (28×28 layout assumed from sample_len).
pub fn rotate_dataset(d: &Dataset, deg: f32) -> Dataset {
    let side = (d.sample_len as f64).sqrt() as usize;
    assert_eq!(side * side, d.sample_len, "not a square image dataset");
    let mut x = Vec::with_capacity(d.x.len());
    for i in 0..d.len() {
        x.extend(rotate_image(d.sample(i), side, deg));
    }
    Dataset {
        name: format!("{}-rot{}", d.name, deg as i32),
        x,
        labels: d.labels.clone(),
        sample_len: d.sample_len,
        nclass: d.nclass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn zero_rotation_is_near_identity() {
        let d = synth_mnist::generate(4, 1);
        let r = rotate_dataset(&d, 0.0);
        for (a, b) in d.x.iter().zip(&r.x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rotation_preserves_mass_roughly() {
        let d = synth_mnist::generate(8, 2);
        let r = rotate_dataset(&d, 30.0);
        for i in 0..8 {
            let m0: f32 = d.sample(i).iter().sum();
            let m1: f32 = r.sample(i).iter().sum();
            // some ink rotates out of frame; most mass survives
            assert!(m1 > m0 * 0.6 && m1 < m0 * 1.2, "m0 {m0} m1 {m1}");
        }
    }

    #[test]
    fn four_quarter_turns_roundtrip() {
        let d = synth_mnist::generate(2, 3);
        let mut img = d.sample(0).to_vec();
        for _ in 0..4 {
            img = rotate_image(&img, 28, 90.0);
        }
        let err: f32 = img
            .iter()
            .zip(d.sample(0))
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / img.len() as f32;
        assert!(err < 0.02, "roundtrip err {err}");
    }

    #[test]
    fn rotation_changes_distribution() {
        let d = synth_mnist::generate(8, 4);
        let r = rotate_dataset(&d, 45.0);
        let dist: f32 = d.x.iter().zip(&r.x).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist / d.x.len() as f32 > 0.02);
    }

    #[test]
    fn labels_unchanged() {
        let d = synth_mnist::generate(16, 5);
        let r = rotate_dataset(&d, 45.0);
        assert_eq!(d.labels, r.labels);
    }
}
