//! SynthMNIST: procedurally rendered digit glyphs.
//!
//! Each class is a digit skeleton (polyline set on a 7-segment-style
//! grid, plus diagonals for 2/4/7) rendered into 28×28 with a random
//! affine jitter (translation, rotation, scale), stroke-thickness
//! variation and additive noise. The task is 10-class, linearly
//! non-separable, and learnable to high accuracy — the same loss-surface
//! character as MNIST at identical tensor shapes (DESIGN.md §3).

use super::Dataset;
use crate::rng::Rng64;

pub const SIDE: usize = 28;
pub const SAMPLE_LEN: usize = SIDE * SIDE;

type Seg = ((f32, f32), (f32, f32));

/// Segment endpoints on the unit glyph box (x right, y down).
/// 7-seg layout: A top, B top-right, C bottom-right, D bottom,
/// E bottom-left, F top-left, G middle.
const A: Seg = ((0.1, 0.0), (0.9, 0.0));
const B: Seg = ((0.9, 0.0), (0.9, 0.5));
const C: Seg = ((0.9, 0.5), (0.9, 1.0));
const D: Seg = ((0.1, 1.0), (0.9, 1.0));
const E: Seg = ((0.1, 0.5), (0.1, 1.0));
const F: Seg = ((0.1, 0.0), (0.1, 0.5));
const G: Seg = ((0.1, 0.5), (0.9, 0.5));
/// Diagonals that break 7-segment symmetry (more MNIST-like).
const DIAG2: Seg = ((0.9, 0.5), (0.1, 1.0)); // the '2' slash
const DIAG7: Seg = ((0.9, 0.0), (0.3, 1.0)); // the '7' leg
const STEM1: Seg = ((0.5, 0.0), (0.5, 1.0)); // the '1' stem
const SERIF1: Seg = ((0.3, 0.2), (0.5, 0.0)); // the '1' serif

/// Digit skeletons.
fn glyph(digit: u8) -> Vec<Seg> {
    match digit {
        0 => vec![A, B, C, D, E, F],
        1 => vec![STEM1, SERIF1],
        2 => vec![A, B, G, DIAG2, D],
        3 => vec![A, B, G, C, D],
        4 => vec![F, G, B, C],
        5 => vec![A, F, G, C, D],
        6 => vec![A, F, G, E, D, C],
        7 => vec![A, DIAG7],
        8 => vec![A, B, C, D, E, F, G],
        9 => vec![A, B, F, G, C, D],
        _ => unreachable!("digit out of range"),
    }
}

fn dist_to_seg(px: f32, py: f32, seg: &Seg) -> f32 {
    let ((x1, y1), (x2, y2)) = *seg;
    let (dx, dy) = (x2 - x1, y2 - y1);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - x1) * dx + (py - y1) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (x1 + t * dx, y1 + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render one digit with the given jitter parameters into `out` (28×28).
#[allow(clippy::too_many_arguments)]
fn render(
    out: &mut [f32],
    digit: u8,
    cx_off: f32,
    cy_off: f32,
    angle: f32,
    scale: f32,
    thickness: f32,
    rng: &mut Rng64,
) {
    let segs = glyph(digit);
    let (sin, cos) = angle.sin_cos();
    for iy in 0..SIDE {
        for ix in 0..SIDE {
            // Pixel centre in glyph coordinates: un-jitter, un-rotate.
            let gx = (ix as f32 + 0.5) / SIDE as f32 - 0.5 - cx_off;
            let gy = (iy as f32 + 0.5) / SIDE as f32 - 0.5 - cy_off;
            let rx = (gx * cos + gy * sin) / scale + 0.5;
            let ry = (-gx * sin + gy * cos) / scale + 0.5;
            // Glyph box occupies the central 60% of the image.
            let ux = (rx - 0.2) / 0.6;
            let uy = (ry - 0.2) / 0.6;
            let d = segs
                .iter()
                .map(|s| dist_to_seg(ux, uy, s))
                .fold(f32::INFINITY, f32::min);
            // Soft stroke edge + mild speckle noise.
            let ink = (1.0 - (d - thickness) / 0.06).clamp(0.0, 1.0);
            let noise = (rng.uniform() - 0.5) * 0.08;
            out[iy * SIDE + ix] = (ink + noise).clamp(0.0, 1.0);
        }
    }
}

/// Generate `n` labelled samples (round-robin over classes, shuffled).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng64::new(seed ^ 0x5947_4D4E); // "MNIS"
    let mut x = vec![0.0f32; n * SAMPLE_LEN];
    let mut labels = vec![0u8; n];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let digit = (i % 10) as u8;
        labels[slot] = digit;
        let cx = (rng.uniform() - 0.5) * 0.12;
        let cy = (rng.uniform() - 0.5) * 0.12;
        let angle = (rng.uniform() - 0.5) * 0.35; // ±10°
        let scale = 0.85 + rng.uniform() * 0.3;
        let thickness = 0.05 + rng.uniform() * 0.06;
        render(
            &mut x[slot * SAMPLE_LEN..(slot + 1) * SAMPLE_LEN],
            digit,
            cx,
            cy,
            angle,
            scale,
            thickness,
            &mut rng,
        );
    }
    Dataset {
        name: "synth-mnist".into(),
        x,
        labels,
        sample_len: SAMPLE_LEN,
        nclass: 10,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(32, 7);
        let b = generate(32, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn seeds_differ() {
        let a = generate(32, 7);
        let b = generate(32, 8);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn values_in_unit_range() {
        let d = generate(64, 1);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn classes_balanced() {
        let d = generate(100, 2);
        let counts = d.class_counts();
        assert_eq!(counts, vec![10; 10]);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean inter-class L2 distance must exceed mean intra-class
        // distance — otherwise the task is unlearnable.
        let d = generate(200, 3);
        let mut intra = (0.0f64, 0usize);
        let mut inter = (0.0f64, 0usize);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let dist: f64 = d
                    .sample(i)
                    .iter()
                    .zip(d.sample(j))
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                if d.labels[i] == d.labels[j] {
                    intra.0 += dist;
                    intra.1 += 1;
                } else {
                    inter.0 += dist;
                    inter.1 += 1;
                }
            }
        }
        let intra_mean = intra.0 / intra.1.max(1) as f64;
        let inter_mean = inter.0 / inter.1.max(1) as f64;
        assert!(
            inter_mean > intra_mean * 1.15,
            "inter {inter_mean} vs intra {intra_mean}"
        );
    }

    #[test]
    fn glyphs_have_ink() {
        let d = generate(20, 4);
        for i in 0..20 {
            let ink: f32 = d.sample(i).iter().sum();
            assert!(ink > 10.0, "sample {i} nearly blank");
        }
    }
}
