//! SynthFashion: shape/texture composites standing in for Fashion-MNIST.
//!
//! Each class pairs a filled silhouette (drawn from rectangles,
//! trapezoids and bar pairs arranged like garment outlines) with a
//! texture (solid, stripes at two orientations, checker). Harder than
//! SynthMNIST — silhouettes overlap more — mirroring the MNIST →
//! Fashion-MNIST difficulty step in the paper's Table 1.

use super::Dataset;
use crate::rng::Rng64;

pub const SIDE: usize = 28;
pub const SAMPLE_LEN: usize = SIDE * SIDE;

/// Axis-aligned box in unit coords (x0,y0,x1,y1).
type Box4 = (f32, f32, f32, f32);

/// Garment-ish silhouettes: boxes composing each class outline.
fn silhouette(class: u8) -> Vec<Box4> {
    match class {
        // t-shirt: wide torso + two short sleeves
        0 => vec![(0.3, 0.25, 0.7, 0.85), (0.1, 0.25, 0.3, 0.45), (0.7, 0.25, 0.9, 0.45)],
        // trouser: two legs + waistband
        1 => vec![(0.3, 0.2, 0.48, 0.9), (0.52, 0.2, 0.7, 0.9), (0.3, 0.12, 0.7, 0.24)],
        // pullover: torso + long sleeves
        2 => vec![(0.3, 0.2, 0.7, 0.85), (0.08, 0.2, 0.3, 0.75), (0.7, 0.2, 0.92, 0.75)],
        // dress: narrow top, wide bottom (two stacked boxes)
        3 => vec![(0.38, 0.15, 0.62, 0.5), (0.25, 0.5, 0.75, 0.92)],
        // coat: wide torso + sleeves + collar gap (center slit)
        4 => vec![(0.25, 0.18, 0.47, 0.9), (0.53, 0.18, 0.75, 0.9), (0.08, 0.2, 0.25, 0.7), (0.75, 0.2, 0.92, 0.7)],
        // sandal: two thin horizontal straps + sole
        5 => vec![(0.15, 0.72, 0.85, 0.85), (0.2, 0.45, 0.8, 0.53), (0.3, 0.25, 0.7, 0.33)],
        // shirt: torso + sleeves + button strip
        6 => vec![(0.3, 0.2, 0.7, 0.85), (0.12, 0.2, 0.3, 0.6), (0.7, 0.2, 0.88, 0.6), (0.47, 0.2, 0.53, 0.85)],
        // sneaker: low wedge + toe box
        7 => vec![(0.1, 0.55, 0.9, 0.8), (0.55, 0.42, 0.9, 0.55)],
        // bag: body + handle (thin top bar)
        8 => vec![(0.2, 0.4, 0.8, 0.88), (0.35, 0.2, 0.65, 0.28)],
        // ankle boot: shaft + foot
        9 => vec![(0.35, 0.15, 0.65, 0.6), (0.35, 0.6, 0.9, 0.85)],
        _ => unreachable!("class out of range"),
    }
}

/// Texture id per class (fixed so texture is a class-informative cue).
fn texture(class: u8) -> u8 {
    class % 4 // 0 solid, 1 h-stripes, 2 v-stripes, 3 checker
}

pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng64::new(seed ^ 0x4641_5348); // "FASH"
    let mut x = vec![0.0f32; n * SAMPLE_LEN];
    let mut labels = vec![0u8; n];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let class = (i % 10) as u8;
        labels[slot] = class;
        let boxes = silhouette(class);
        let tex = texture(class);
        let jx = (rng.uniform() - 0.5) * 0.1;
        let jy = (rng.uniform() - 0.5) * 0.1;
        let scale = 0.85 + rng.uniform() * 0.3;
        let phase = rng.uniform() * 4.0;
        let stripe_w = 2.0 + rng.uniform() * 2.0;
        let out = &mut x[slot * SAMPLE_LEN..(slot + 1) * SAMPLE_LEN];
        for iy in 0..SIDE {
            for ix in 0..SIDE {
                let ux = ((ix as f32 + 0.5) / SIDE as f32 - 0.5 - jx) / scale + 0.5;
                let uy = ((iy as f32 + 0.5) / SIDE as f32 - 0.5 - jy) / scale + 0.5;
                let inside = boxes
                    .iter()
                    .any(|&(x0, y0, x1, y1)| ux >= x0 && ux < x1 && uy >= y0 && uy < y1);
                let mut v = if inside {
                    match tex {
                        0 => 0.85,
                        1 => {
                            if ((iy as f32 / stripe_w + phase) as i32) % 2 == 0 {
                                0.9
                            } else {
                                0.45
                            }
                        }
                        2 => {
                            if ((ix as f32 / stripe_w + phase) as i32) % 2 == 0 {
                                0.9
                            } else {
                                0.45
                            }
                        }
                        _ => {
                            let a = ((ix as f32 / stripe_w + phase) as i32) % 2;
                            let b = ((iy as f32 / stripe_w + phase) as i32) % 2;
                            if a == b {
                                0.9
                            } else {
                                0.4
                            }
                        }
                    }
                } else {
                    0.05
                };
                v += (rng.uniform() - 0.5) * 0.1;
                out[iy * SIDE + ix] = v.clamp(0.0, 1.0);
            }
        }
    }
    Dataset {
        name: "synth-fashion".into(),
        x,
        labels,
        sample_len: SAMPLE_LEN,
        nclass: 10,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let a = generate(40, 9);
        let b = generate(40, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.class_counts(), vec![4; 10]);
    }

    #[test]
    fn values_in_unit_range() {
        let d = generate(32, 5);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn silhouettes_cover_all_classes() {
        for c in 0..10u8 {
            assert!(!silhouette(c).is_empty());
        }
    }

    #[test]
    fn different_classes_differ() {
        let d = generate(20, 6);
        // find a class-0 and class-1 sample and check they differ a lot
        let i0 = d.labels.iter().position(|&l| l == 0).unwrap();
        let i1 = d.labels.iter().position(|&l| l == 1).unwrap();
        let dist: f32 = d
            .sample(i0)
            .iter()
            .zip(d.sample(i1))
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(dist > 20.0, "dist {dist}");
    }
}
