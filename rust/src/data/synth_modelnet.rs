//! SynthModelNet: 40 parametric 3-D surface categories standing in for
//! ModelNet40 point clouds.
//!
//! 8 base primitives × 5 deformation variants = 40 classes. For each
//! sample, `npoints` points are sampled on the (deformed) surface with
//! per-sample jitter, then normalized to zero centroid / unit radius —
//! exactly the preprocessing the paper describes for ModelNet40.

use super::Dataset;
use crate::rng::Rng64;

/// Base primitive families.
#[derive(Debug, Clone, Copy)]
enum Prim {
    Sphere,
    Box,
    Cylinder,
    Cone,
    Torus,
    Ellipsoid,
    Pyramid,
    Capsule,
}

const PRIMS: [Prim; 8] = [
    Prim::Sphere,
    Prim::Box,
    Prim::Cylinder,
    Prim::Cone,
    Prim::Torus,
    Prim::Ellipsoid,
    Prim::Pyramid,
    Prim::Capsule,
];

/// Per-class deformation parameters derived from the variant index.
fn variant_params(variant: usize) -> (f32, f32) {
    // aspect in {0.4, 0.7, 1.0, 1.6, 2.4}; secondary in {0.2..0.6}
    let aspects = [0.4, 0.7, 1.0, 1.6, 2.4];
    let secondary = [0.2, 0.3, 0.4, 0.5, 0.6];
    (aspects[variant], secondary[variant])
}

fn sample_surface(prim: Prim, aspect: f32, sec: f32, rng: &mut Rng64) -> [f32; 3] {
    use std::f32::consts::PI;
    match prim {
        Prim::Sphere => {
            let z = rng.uniform() * 2.0 - 1.0;
            let t = rng.uniform() * 2.0 * PI;
            let r = (1.0 - z * z).max(0.0).sqrt();
            [r * t.cos(), r * t.sin(), z * aspect]
        }
        Prim::Ellipsoid => {
            let z = rng.uniform() * 2.0 - 1.0;
            let t = rng.uniform() * 2.0 * PI;
            let r = (1.0 - z * z).max(0.0).sqrt();
            [r * t.cos() * aspect, r * t.sin() * sec * 2.0, z]
        }
        Prim::Box => {
            // pick a face, uniform on it
            let face = (rng.next_u64() % 6) as usize;
            let u = rng.uniform() * 2.0 - 1.0;
            let v = rng.uniform() * 2.0 - 1.0;
            let h = aspect;
            match face {
                0 => [1.0, u, v * h],
                1 => [-1.0, u, v * h],
                2 => [u, 1.0, v * h],
                3 => [u, -1.0, v * h],
                4 => [u, v, h],
                _ => [u, v, -h],
            }
        }
        Prim::Cylinder => {
            let t = rng.uniform() * 2.0 * PI;
            if rng.uniform() < 0.7 {
                // lateral surface
                let z = (rng.uniform() * 2.0 - 1.0) * aspect;
                [t.cos(), t.sin(), z]
            } else {
                // caps
                let r = rng.uniform().sqrt();
                let z = if rng.uniform() < 0.5 { aspect } else { -aspect };
                [r * t.cos(), r * t.sin(), z]
            }
        }
        Prim::Cone => {
            let t = rng.uniform() * 2.0 * PI;
            if rng.uniform() < 0.75 {
                let u = rng.uniform().sqrt(); // area-uniform along slant
                let r = 1.0 - u;
                [r * t.cos(), r * t.sin(), (u * 2.0 - 1.0) * aspect]
            } else {
                let r = rng.uniform().sqrt();
                [r * t.cos(), r * t.sin(), -aspect]
            }
        }
        Prim::Torus => {
            let t = rng.uniform() * 2.0 * PI;
            let p = rng.uniform() * 2.0 * PI;
            let rr = sec; // tube radius
            [
                (1.0 + rr * p.cos()) * t.cos(),
                (1.0 + rr * p.cos()) * t.sin(),
                rr * p.sin() * aspect * 2.0,
            ]
        }
        Prim::Pyramid => {
            // square base at z=-h, apex at (0,0,h)
            let h = aspect;
            if rng.uniform() < 0.6 {
                // side faces: interpolate base edge -> apex
                let edge = (rng.next_u64() % 4) as usize;
                let u = rng.uniform() * 2.0 - 1.0;
                let v = rng.uniform(); // 0 base, 1 apex
                let base = match edge {
                    0 => [u, 1.0],
                    1 => [u, -1.0],
                    2 => [1.0, u],
                    _ => [-1.0, u],
                };
                [base[0] * (1.0 - v), base[1] * (1.0 - v), -h + 2.0 * h * v]
            } else {
                let u = rng.uniform() * 2.0 - 1.0;
                let v = rng.uniform() * 2.0 - 1.0;
                [u, v, -h]
            }
        }
        Prim::Capsule => {
            let t = rng.uniform() * 2.0 * PI;
            if rng.uniform() < 0.5 {
                let z = (rng.uniform() * 2.0 - 1.0) * aspect;
                [t.cos(), t.sin(), z]
            } else {
                // hemispherical ends
                let z = rng.uniform();
                let r = (1.0 - z * z).max(0.0).sqrt();
                let sign = if rng.uniform() < 0.5 { 1.0 } else { -1.0 };
                [r * t.cos(), r * t.sin(), sign * (aspect + z * sec)]
            }
        }
    }
}

/// Normalize to zero centroid and unit max radius (paper's protocol).
fn normalize(points: &mut [f32]) {
    let n = points.len() / 3;
    let mut c = [0.0f32; 3];
    for p in points.chunks(3) {
        for k in 0..3 {
            c[k] += p[k];
        }
    }
    for v in &mut c {
        *v /= n as f32;
    }
    let mut maxr = 1e-9f32;
    for p in points.chunks_mut(3) {
        for k in 0..3 {
            p[k] -= c[k];
        }
        let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
        maxr = maxr.max(r);
    }
    for v in points.iter_mut() {
        *v /= maxr;
    }
}

pub fn generate(n: usize, npoints: usize, seed: u64) -> Dataset {
    let mut rng = Rng64::new(seed ^ 0x4D44_4C34); // "MDL4"
    let nclass = 40;
    let mut x = vec![0.0f32; n * npoints * 3];
    let mut labels = vec![0u8; n];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let class = i % nclass;
        labels[slot] = class as u8;
        let prim = PRIMS[class / 5];
        let (aspect, sec) = variant_params(class % 5);
        // per-sample global rotation about z + anisotropic scale jitter
        let rot = rng.uniform() * 2.0 * std::f32::consts::PI;
        let (sr, cr) = rot.sin_cos();
        let jitter = 0.02;
        let sx = 0.9 + rng.uniform() * 0.2;
        let sy = 0.9 + rng.uniform() * 0.2;
        let out = &mut x[slot * npoints * 3..(slot + 1) * npoints * 3];
        for p in 0..npoints {
            let mut pt = sample_surface(prim, aspect, sec, &mut rng);
            // rotate about z, scale, jitter
            let (px, py) = (pt[0] * cr - pt[1] * sr, pt[0] * sr + pt[1] * cr);
            pt[0] = px * sx + (rng.uniform() - 0.5) * jitter;
            pt[1] = py * sy + (rng.uniform() - 0.5) * jitter;
            pt[2] += (rng.uniform() - 0.5) * jitter;
            out[p * 3..p * 3 + 3].copy_from_slice(&pt);
        }
        normalize(out);
    }
    Dataset {
        name: "synth-modelnet".into(),
        x,
        labels,
        sample_len: npoints * 3,
        nclass,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(16, 64, 3);
        let b = generate(16, 64, 3);
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn normalized_unit_radius() {
        let d = generate(8, 128, 1);
        for i in 0..8 {
            let s = d.sample(i);
            let mut maxr = 0.0f32;
            let mut centroid = [0.0f32; 3];
            for p in s.chunks(3) {
                let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
                maxr = maxr.max(r);
                for k in 0..3 {
                    centroid[k] += p[k];
                }
            }
            assert!((maxr - 1.0).abs() < 1e-4, "max radius {maxr}");
            for c in centroid {
                assert!((c / 128.0).abs() < 1e-4, "centroid {c}");
            }
        }
    }

    #[test]
    fn forty_classes() {
        let d = generate(80, 32, 2);
        assert_eq!(d.nclass, 40);
        let counts = d.class_counts();
        assert_eq!(counts, vec![2; 40]);
    }

    #[test]
    fn primitives_geometrically_distinct() {
        // sphere (class 10 aspect=1.0 -> class index 2 of family 0) vs
        // box family: mean |z| distribution differs from sphere's.
        let d = generate(400, 128, 5);
        let avg_extent = |class: u8| -> f32 {
            let mut total = 0.0;
            let mut count = 0;
            for i in 0..d.len() {
                if d.labels[i] == class {
                    let s = d.sample(i);
                    // bounding-box volume proxy
                    let (mut mx, mut my, mut mz) = (0.0f32, 0.0f32, 0.0f32);
                    for p in s.chunks(3) {
                        mx = mx.max(p[0].abs());
                        my = my.max(p[1].abs());
                        mz = mz.max(p[2].abs());
                    }
                    total += mx * my * mz;
                    count += 1;
                }
            }
            total / count as f32
        };
        // torus (flat, hole) vs sphere: extents differ measurably
        let sphere = avg_extent(2); // Sphere aspect 1.0
        let torus = avg_extent(22); // Torus aspect 1.0
        assert!((sphere - torus).abs() > 0.05, "sphere {sphere} torus {torus}");
    }
}
