//! Minibatch pipeline: epoch shuffling, fixed-size batch assembly and
//! one-hot label encoding — the L3 data path feeding both engines.

use super::Dataset;
use crate::rng::Rng64;

/// One assembled minibatch (row-major, engine-ready).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Flattened inputs, `bsz * sample_len`.
    pub x: Vec<f32>,
    /// One-hot labels, `bsz * nclass`.
    pub y_onehot: Vec<f32>,
    /// Raw labels.
    pub labels: Vec<u8>,
    pub bsz: usize,
}

/// One deterministic slice of data-parallel work: replica `index` of
/// `of`. A shard is applied to each assembled batch by striding over
/// its rows (`index, index+of, index+2·of, …`), so the union of all
/// `of` shards of a batch is exactly the batch, shards are pairwise
/// disjoint, and the batch order itself remains the single-node
/// `(seed, epoch)` shuffle — replay stays bit-identical no matter how
/// many replicas share the epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub of: usize,
}

impl Shard {
    /// The degenerate single-replica shard (the whole batch).
    pub fn full() -> Shard {
        Shard { index: 0, of: 1 }
    }

    /// Number of rows this shard owns in a `bsz`-row batch.
    pub fn size(&self, bsz: usize) -> usize {
        if self.index >= bsz {
            0
        } else {
            (bsz - self.index).div_ceil(self.of)
        }
    }
}

impl Batch {
    /// The sub-batch owned by `shard`: rows `index, index+of, …` of
    /// this batch, in batch order.
    pub fn shard(&self, shard: Shard) -> Batch {
        assert!(shard.of >= 1 && shard.index < shard.of, "bad shard {shard:?}");
        if shard.of == 1 {
            return self.clone();
        }
        let sl = self.x.len() / self.bsz.max(1);
        let nc = self.y_onehot.len() / self.bsz.max(1);
        let rows = shard.size(self.bsz);
        let mut x = Vec::with_capacity(rows * sl);
        let mut y = Vec::with_capacity(rows * nc);
        let mut labels = Vec::with_capacity(rows);
        for row in (shard.index..self.bsz).step_by(shard.of) {
            x.extend_from_slice(&self.x[row * sl..(row + 1) * sl]);
            y.extend_from_slice(&self.y_onehot[row * nc..(row + 1) * nc]);
            labels.push(self.labels[row]);
        }
        Batch { x, y_onehot: y, labels, bsz: rows }
    }
}

/// Shuffled epoch iterator producing fixed-size batches.
///
/// The tail of the dataset is wrapped with samples from the epoch start
/// so every batch has exactly `bsz` rows (the AOT artifacts have static
/// batch shapes).
pub struct Loader<'a> {
    data: &'a Dataset,
    bsz: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl<'a> Loader<'a> {
    pub fn new(data: &'a Dataset, bsz: usize, seed: u64, epoch: u64) -> Loader<'a> {
        assert!(bsz > 0 && data.len() >= 1);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut rng = Rng64::new(seed ^ epoch.wrapping_mul(0x9E37_79B9));
        rng.shuffle(&mut order);
        Loader { data, bsz, order, cursor: 0 }
    }

    /// Number of batches in one epoch (ceil so every sample is seen).
    pub fn batches_per_epoch(&self) -> usize {
        self.data.len().div_ceil(self.bsz)
    }

    fn assemble(&self, idxs: &[usize]) -> Batch {
        let sl = self.data.sample_len;
        let nc = self.data.nclass;
        let mut x = Vec::with_capacity(idxs.len() * sl);
        let mut y = vec![0.0f32; idxs.len() * nc];
        let mut labels = Vec::with_capacity(idxs.len());
        for (row, &i) in idxs.iter().enumerate() {
            x.extend_from_slice(self.data.sample(i));
            let l = self.data.labels[i];
            y[row * nc + l as usize] = 1.0;
            labels.push(l);
        }
        Batch { x, y_onehot: y, labels, bsz: idxs.len() }
    }
}

impl<'a> Iterator for Loader<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = self.cursor + self.bsz;
        let mut idxs: Vec<usize> = self.order
            [self.cursor..end.min(self.order.len())]
            .to_vec();
        // wrap the ragged tail so batch shape stays static
        let mut wrap = 0;
        while idxs.len() < self.bsz {
            idxs.push(self.order[wrap % self.order.len()]);
            wrap += 1;
        }
        self.cursor = end;
        Some(self.assemble(&idxs))
    }
}

/// Sequential (unshuffled) evaluation batches over a dataset.
pub fn eval_batches(data: &Dataset, bsz: usize) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let end = (i + bsz).min(data.len());
        let mut idxs: Vec<usize> = (i..end).collect();
        while idxs.len() < bsz {
            idxs.push(idxs[idxs.len() - 1]); // pad by repeating; extra rows ignored via real_len
        }
        let loader = Loader { data, bsz, order: idxs.clone(), cursor: 0 };
        let mut b = loader.assemble(&idxs);
        b.bsz = end - i; // record real row count for accuracy masking
        out.push(b);
        i = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn epoch_covers_all_samples() {
        let d = synth_mnist::generate(50, 1);
        let loader = Loader::new(&d, 8, 42, 0);
        assert_eq!(loader.batches_per_epoch(), 7);
        let mut seen = vec![false; 50];
        for b in Loader::new(&d, 8, 42, 0) {
            assert_eq!(b.x.len(), 8 * d.sample_len);
            assert_eq!(b.y_onehot.len(), 8 * 10);
            for &l in &b.labels {
                assert!((l as usize) < 10);
            }
            let _ = &mut seen; // coverage checked via order below
        }
        // direct coverage check on the shuffle order
        let l = Loader::new(&d, 8, 42, 0);
        let mut sorted = l.order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn onehot_is_consistent() {
        let d = synth_mnist::generate(20, 2);
        for b in Loader::new(&d, 4, 1, 0) {
            for row in 0..4 {
                let oh = &b.y_onehot[row * 10..(row + 1) * 10];
                assert_eq!(oh.iter().sum::<f32>(), 1.0);
                assert_eq!(oh[b.labels[row] as usize], 1.0);
            }
        }
    }

    #[test]
    fn epochs_shuffle_differently() {
        let d = synth_mnist::generate(32, 3);
        let o0 = Loader::new(&d, 8, 7, 0).order.clone();
        let o1 = Loader::new(&d, 8, 7, 1).order.clone();
        assert_ne!(o0, o1);
        // but the same epoch replays identically
        let o0b = Loader::new(&d, 8, 7, 0).order.clone();
        assert_eq!(o0, o0b);
    }

    #[test]
    fn ragged_tail_is_padded() {
        let d = synth_mnist::generate(10, 4);
        let batches: Vec<Batch> = Loader::new(&d, 8, 1, 0).collect();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[1].x.len(), 8 * d.sample_len); // padded to full
    }

    #[test]
    fn shards_partition_each_batch() {
        let d = synth_mnist::generate(40, 6);
        for b in Loader::new(&d, 8, 9, 0) {
            for of in [1usize, 2, 3, 4] {
                let parts: Vec<Batch> =
                    (0..of).map(|i| b.shard(Shard { index: i, of })).collect();
                // sizes partition the batch
                assert_eq!(parts.iter().map(|p| p.bsz).sum::<usize>(), b.bsz);
                for (i, p) in parts.iter().enumerate() {
                    assert_eq!(p.bsz, (Shard { index: i, of }).size(b.bsz));
                    assert_eq!(p.x.len(), p.bsz * d.sample_len);
                    assert_eq!(p.y_onehot.len(), p.bsz * d.nclass);
                    // each shard row is the expected strided batch row
                    for (row, &l) in p.labels.iter().enumerate() {
                        let src = i + row * of;
                        assert_eq!(l, b.labels[src]);
                        assert_eq!(
                            p.x[row * d.sample_len..(row + 1) * d.sample_len],
                            b.x[src * d.sample_len..(src + 1) * d.sample_len]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn shard_is_deterministic_and_full_is_identity() {
        let d = synth_mnist::generate(16, 7);
        let b = Loader::new(&d, 16, 3, 0).next().unwrap();
        let a1 = b.shard(Shard { index: 1, of: 3 });
        let a2 = b.shard(Shard { index: 1, of: 3 });
        assert_eq!(a1.x, a2.x);
        assert_eq!(a1.labels, a2.labels);
        let full = b.shard(Shard::full());
        assert_eq!(full.x, b.x);
        assert_eq!(full.bsz, b.bsz);
    }

    #[test]
    fn eval_batches_cover_exactly() {
        let d = synth_mnist::generate(21, 5);
        let bs = eval_batches(&d, 8);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].bsz, 8);
        assert_eq!(bs[2].bsz, 5); // real rows in the tail batch
        assert_eq!(bs[2].x.len(), 8 * d.sample_len); // padded storage
    }
}
