//! Minibatch pipeline: epoch shuffling, fixed-size batch assembly and
//! one-hot label encoding — the L3 data path feeding both engines.

use super::Dataset;
use crate::rng::Rng64;

/// One assembled minibatch (row-major, engine-ready).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Flattened inputs, `bsz * sample_len`.
    pub x: Vec<f32>,
    /// One-hot labels, `bsz * nclass`.
    pub y_onehot: Vec<f32>,
    /// Raw labels.
    pub labels: Vec<u8>,
    pub bsz: usize,
}

/// Shuffled epoch iterator producing fixed-size batches.
///
/// The tail of the dataset is wrapped with samples from the epoch start
/// so every batch has exactly `bsz` rows (the AOT artifacts have static
/// batch shapes).
pub struct Loader<'a> {
    data: &'a Dataset,
    bsz: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl<'a> Loader<'a> {
    pub fn new(data: &'a Dataset, bsz: usize, seed: u64, epoch: u64) -> Loader<'a> {
        assert!(bsz > 0 && data.len() >= 1);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut rng = Rng64::new(seed ^ epoch.wrapping_mul(0x9E37_79B9));
        rng.shuffle(&mut order);
        Loader { data, bsz, order, cursor: 0 }
    }

    /// Number of batches in one epoch (ceil so every sample is seen).
    pub fn batches_per_epoch(&self) -> usize {
        self.data.len().div_ceil(self.bsz)
    }

    fn assemble(&self, idxs: &[usize]) -> Batch {
        let sl = self.data.sample_len;
        let nc = self.data.nclass;
        let mut x = Vec::with_capacity(idxs.len() * sl);
        let mut y = vec![0.0f32; idxs.len() * nc];
        let mut labels = Vec::with_capacity(idxs.len());
        for (row, &i) in idxs.iter().enumerate() {
            x.extend_from_slice(self.data.sample(i));
            let l = self.data.labels[i];
            y[row * nc + l as usize] = 1.0;
            labels.push(l);
        }
        Batch { x, y_onehot: y, labels, bsz: idxs.len() }
    }
}

impl<'a> Iterator for Loader<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = self.cursor + self.bsz;
        let mut idxs: Vec<usize> = self.order
            [self.cursor..end.min(self.order.len())]
            .to_vec();
        // wrap the ragged tail so batch shape stays static
        let mut wrap = 0;
        while idxs.len() < self.bsz {
            idxs.push(self.order[wrap % self.order.len()]);
            wrap += 1;
        }
        self.cursor = end;
        Some(self.assemble(&idxs))
    }
}

/// Sequential (unshuffled) evaluation batches over a dataset.
pub fn eval_batches(data: &Dataset, bsz: usize) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let end = (i + bsz).min(data.len());
        let mut idxs: Vec<usize> = (i..end).collect();
        while idxs.len() < bsz {
            idxs.push(idxs[idxs.len() - 1]); // pad by repeating; extra rows ignored via real_len
        }
        let loader = Loader { data, bsz, order: idxs.clone(), cursor: 0 };
        let mut b = loader.assemble(&idxs);
        b.bsz = end - i; // record real row count for accuracy masking
        out.push(b);
        i = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn epoch_covers_all_samples() {
        let d = synth_mnist::generate(50, 1);
        let loader = Loader::new(&d, 8, 42, 0);
        assert_eq!(loader.batches_per_epoch(), 7);
        let mut seen = vec![false; 50];
        for b in Loader::new(&d, 8, 42, 0) {
            assert_eq!(b.x.len(), 8 * d.sample_len);
            assert_eq!(b.y_onehot.len(), 8 * 10);
            for &l in &b.labels {
                assert!((l as usize) < 10);
            }
            let _ = &mut seen; // coverage checked via order below
        }
        // direct coverage check on the shuffle order
        let l = Loader::new(&d, 8, 42, 0);
        let mut sorted = l.order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn onehot_is_consistent() {
        let d = synth_mnist::generate(20, 2);
        for b in Loader::new(&d, 4, 1, 0) {
            for row in 0..4 {
                let oh = &b.y_onehot[row * 10..(row + 1) * 10];
                assert_eq!(oh.iter().sum::<f32>(), 1.0);
                assert_eq!(oh[b.labels[row] as usize], 1.0);
            }
        }
    }

    #[test]
    fn epochs_shuffle_differently() {
        let d = synth_mnist::generate(32, 3);
        let o0 = Loader::new(&d, 8, 7, 0).order.clone();
        let o1 = Loader::new(&d, 8, 7, 1).order.clone();
        assert_ne!(o0, o1);
        // but the same epoch replays identically
        let o0b = Loader::new(&d, 8, 7, 0).order.clone();
        assert_eq!(o0, o0b);
    }

    #[test]
    fn ragged_tail_is_padded() {
        let d = synth_mnist::generate(10, 4);
        let batches: Vec<Batch> = Loader::new(&d, 8, 1, 0).collect();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[1].x.len(), 8 * d.sample_len); // padded to full
    }

    #[test]
    fn eval_batches_cover_exactly() {
        let d = synth_mnist::generate(21, 5);
        let bs = eval_batches(&d, 8);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].bsz, 8);
        assert_eq!(bs[2].bsz, 5); // real rows in the tail batch
        assert_eq!(bs[2].x.len(), 8 * d.sample_len); // padded storage
    }
}
