//! Dataset substrate: procedurally generated, deterministic, seedable
//! stand-ins for the paper's datasets (the build box has no network
//! access — see DESIGN.md §3 for the substitution argument).
//!
//! * [`synth_mnist`]  — stroke-rendered digit glyphs, 10 classes, 28×28.
//! * [`synth_fashion`] — shape/texture composites, 10 classes, 28×28.
//! * [`synth_modelnet`] — parametric 3-D surfaces, 40 classes, (N,3)
//!   point clouds, unit-sphere normalized (PointNet input format).
//! * [`rotate`] — the Rotated-(F)MNIST construction used by the paper's
//!   fine-tuning study (Table 2): bilinear rotation by 30°/45°.
//! * [`loader`] — shuffled minibatch iteration and one-hot assembly.

pub mod loader;
pub mod rotate;
pub mod synth_fashion;
pub mod synth_mnist;
pub mod synth_modelnet;

/// An in-memory classification dataset.
///
/// `x` is row-major: images are `(n, 1, 28, 28)` flattened, point clouds
/// `(n, npoints, 3)` flattened. Values are f32 (images in [0,1]).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: Vec<f32>,
    pub labels: Vec<u8>,
    pub sample_len: usize,
    pub nclass: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_len..(i + 1) * self.sample_len]
    }

    /// Split off the first `n` samples as one dataset, rest as another.
    pub fn split_at(&self, n: usize) -> (Dataset, Dataset) {
        assert!(n <= self.len());
        let a = Dataset {
            name: self.name.clone(),
            x: self.x[..n * self.sample_len].to_vec(),
            labels: self.labels[..n].to_vec(),
            sample_len: self.sample_len,
            nclass: self.nclass,
        };
        let b = Dataset {
            name: self.name.clone(),
            x: self.x[n * self.sample_len..].to_vec(),
            labels: self.labels[n..].to_vec(),
            sample_len: self.sample_len,
            nclass: self.nclass,
        };
        (a, b)
    }

    /// Class histogram (for balance checks).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nclass];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Which synthetic dataset to generate (config-level enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    SynthMnist,
    SynthFashion,
    SynthModelNet,
}

impl DatasetKind {
    pub fn parse(s: &str) -> anyhow::Result<DatasetKind> {
        match s {
            "mnist" | "synth-mnist" => Ok(DatasetKind::SynthMnist),
            "fashion" | "fashion-mnist" | "synth-fashion" => Ok(DatasetKind::SynthFashion),
            "modelnet" | "modelnet40" | "synth-modelnet" => Ok(DatasetKind::SynthModelNet),
            other => anyhow::bail!("unknown dataset '{other}'"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            DatasetKind::SynthMnist => "mnist",
            DatasetKind::SynthFashion => "fashion",
            DatasetKind::SynthModelNet => "modelnet",
        }
    }
}

/// Generate `(train, test)` splits for a dataset kind.
pub fn generate(
    kind: DatasetKind,
    train_n: usize,
    test_n: usize,
    seed: u64,
    npoints: usize,
) -> (Dataset, Dataset) {
    match kind {
        DatasetKind::SynthMnist => (
            synth_mnist::generate(train_n, seed),
            synth_mnist::generate(test_n, seed ^ 0xDEAD_BEEF),
        ),
        DatasetKind::SynthFashion => (
            synth_fashion::generate(train_n, seed),
            synth_fashion::generate(test_n, seed ^ 0xDEAD_BEEF),
        ),
        DatasetKind::SynthModelNet => (
            synth_modelnet::generate(train_n, npoints, seed),
            synth_modelnet::generate(test_n, npoints, seed ^ 0xDEAD_BEEF),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_preserves_samples() {
        let d = synth_mnist::generate(20, 1);
        let (a, b) = d.split_at(5);
        assert_eq!(a.len(), 5);
        assert_eq!(b.len(), 15);
        assert_eq!(a.sample(0), d.sample(0));
        assert_eq!(b.sample(0), d.sample(5));
    }

    #[test]
    fn kinds_parse() {
        assert_eq!(DatasetKind::parse("mnist").unwrap(), DatasetKind::SynthMnist);
        assert_eq!(
            DatasetKind::parse("fashion-mnist").unwrap(),
            DatasetKind::SynthFashion
        );
        assert!(DatasetKind::parse("imagenet").is_err());
    }

    #[test]
    fn tokens_roundtrip() {
        for k in [
            DatasetKind::SynthMnist,
            DatasetKind::SynthFashion,
            DatasetKind::SynthModelNet,
        ] {
            assert_eq!(DatasetKind::parse(k.token()).unwrap(), k);
        }
    }
}
