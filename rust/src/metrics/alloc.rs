//! Tracked global allocator: live/peak heap accounting for the
//! `repro` binary, turning the paper's *modeled* memory numbers
//! (`crate::memory`) into *measured* ones.
//!
//! The `repro` binary installs [`TrackedAlloc`] as its
//! `#[global_allocator]`; every (de)allocation updates process-wide
//! atomics read by the `repro_mem_live_bytes` / `repro_mem_peak_bytes`
//! gauges and by `repro train --mem-report`. Library users (and
//! `cargo test`, which uses the default allocator) simply read zeros —
//! the counters are only fed when the allocator is installed.
//!
//! [`measure_scope`] brackets a region (one training session) and
//! reports the peak *net new* bytes allocated inside it — i.e. the
//! high-water mark of (allocations − frees) since scope entry, which
//! is the quantity the paper's per-method memory model predicts.
//! Scopes are process-global: allocations from other live threads are
//! attributed to an open scope, so measure with the serve plane idle.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
/// Depth of open [`measure_scope`] calls (0 = no scope active).
static SCOPE_DEPTH: AtomicUsize = AtomicUsize::new(0);
static SCOPE_NET: AtomicI64 = AtomicI64::new(0);
static SCOPE_PEAK: AtomicI64 = AtomicI64::new(0);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

#[inline]
fn on_alloc(n: usize) {
    let live = LIVE.fetch_add(n, Ordering::Relaxed) + n;
    PEAK.fetch_max(live, Ordering::Relaxed);
    ALLOCS.fetch_add(1, Ordering::Relaxed);
    if SCOPE_DEPTH.load(Ordering::Relaxed) > 0 {
        let net = SCOPE_NET.fetch_add(n as i64, Ordering::Relaxed) + n as i64;
        SCOPE_PEAK.fetch_max(net, Ordering::Relaxed);
    }
}

#[inline]
fn on_dealloc(n: usize) {
    LIVE.fetch_sub(n, Ordering::Relaxed);
    if SCOPE_DEPTH.load(Ordering::Relaxed) > 0 {
        SCOPE_NET.fetch_sub(n as i64, Ordering::Relaxed);
    }
}

/// A `System`-backed allocator that keeps live/peak byte counts.
pub struct TrackedAlloc;

// SAFETY: defers all allocation to `System`; the bookkeeping is
// atomic-only (no allocation, no panics) so it is safe inside the
// allocator itself.
unsafe impl GlobalAlloc for TrackedAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc_zeroed(layout) };
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Currently-allocated heap bytes (0 unless [`TrackedAlloc`] is the
/// global allocator).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Process-lifetime peak of [`live_bytes`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Total number of allocations served (a monotone counter).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// What a [`measure_scope`] observed.
#[derive(Debug, Clone, Copy)]
pub struct ScopeStats {
    /// High-water mark of net new bytes (allocations − frees) while
    /// the scope was open.
    pub peak_net_bytes: usize,
}

/// Run `f` with scope accounting on and report its peak net
/// allocation. Nested calls share the outermost scope's counters.
pub fn measure_scope<R>(f: impl FnOnce() -> R) -> (R, ScopeStats) {
    if SCOPE_DEPTH.fetch_add(1, Ordering::SeqCst) == 0 {
        SCOPE_NET.store(0, Ordering::SeqCst);
        SCOPE_PEAK.store(0, Ordering::SeqCst);
    }
    let r = f();
    let peak = SCOPE_PEAK.load(Ordering::SeqCst).max(0) as usize;
    SCOPE_DEPTH.fetch_sub(1, Ordering::SeqCst);
    (r, ScopeStats { peak_net_bytes: peak })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test binary does not install TrackedAlloc, so exercise the
    // bookkeeping hooks directly. The counters are process-global, so
    // these tests serialize on a lock.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn live_and_peak_track_the_high_water_mark() {
        let _g = LOCK.lock().unwrap();
        let before_live = live_bytes();
        on_alloc(1000);
        on_alloc(500);
        on_dealloc(800);
        assert_eq!(live_bytes(), before_live + 700);
        assert!(peak_bytes() >= before_live + 1500);
        on_dealloc(700);
        assert_eq!(live_bytes(), before_live);
    }

    #[test]
    fn scope_reports_net_peak_not_total_traffic() {
        let _g = LOCK.lock().unwrap();
        let ((), s) = measure_scope(|| {
            on_alloc(4096);
            on_dealloc(4096);
            on_alloc(1024); // peak net is 4096, not 5120
            on_dealloc(1024);
        });
        assert_eq!(s.peak_net_bytes, 4096);
    }

    #[test]
    fn scope_without_allocations_is_zero() {
        let _g = LOCK.lock().unwrap();
        let ((), s) = measure_scope(|| {});
        assert_eq!(s.peak_net_bytes, 0);
    }
}
