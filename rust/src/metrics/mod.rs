//! Std-only metrics registry with Prometheus text-format exposition —
//! the measurement half of the paper's claims (same no-deps discipline
//! as `util::json`).
//!
//! Three primitives, all lock-free after registration:
//!
//! * [`Counter`] — a monotone `AtomicU64` (`_total` series),
//! * [`Gauge`] — an `f64` stored as atomic bits (sampled values:
//!   queue depth, live bytes, per-job loss),
//! * [`Histogram`] — fixed upper-bound buckets + CAS-accumulated sum
//!   (request latency, per-phase epoch seconds).
//!
//! Handles are `Arc`s: instrument sites fetch them from the process
//! [`global`] registry (a `Mutex<BTreeMap>` — held only during
//! registration/lookup and [`Registry::render`]) and update with
//! relaxed atomics. `render()` emits the Prometheus text exposition
//! format (`# HELP`/`# TYPE`, cumulative `_bucket{le=...}` ending in
//! `+Inf`, `_sum`/`_count`) served at `GET /metrics`.
//!
//! The [`alloc`] submodule holds the tracked global allocator behind
//! `repro train --mem-report` and the `repro_mem_*` gauges.

pub mod alloc;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Latency buckets in seconds: 100µs … 10s in a 1-2.5-5 ladder. Wide
/// enough for both sub-millisecond control-plane requests and
/// multi-second training epochs.
pub const LATENCY_BUCKETS_S: [f64; 16] = [
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0,
];

/// A monotonically-increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Mirror an externally-maintained monotone count (e.g. the event
    /// bus shed total, authoritative in `BusInner`). `fetch_max` keeps
    /// the exposed series monotone even under scrape races.
    pub fn mirror(&self, v: u64) {
        self.v.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A settable instantaneous value (stored as `f64` bits).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A fixed-bucket histogram. Bucket counts are stored per-bucket
/// (non-cumulative) and summed into the Prometheus cumulative form at
/// render time.
#[derive(Debug)]
pub struct Histogram {
    uppers: Vec<f64>,
    buckets: Vec<AtomicU64>, // uppers.len() + 1; last is +Inf
    count: AtomicU64,
    sum_bits: AtomicU64, // f64 bits, CAS-accumulated
}

impl Histogram {
    fn new(uppers: &[f64]) -> Histogram {
        debug_assert!(uppers.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        Histogram {
            uppers: uppers.to_vec(),
            buckets: (0..=uppers.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        let ix = self.uppers.iter().position(|&u| v <= u).unwrap_or(self.uppers.len());
        self.buckets[ix].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// `(upper_bound, cumulative_count)` pairs ending with `+Inf`
    /// (`f64::INFINITY`), the shape `_bucket{le=...}` lines are built
    /// from.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.buckets.len());
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            let le = self.uppers.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, acc));
        }
        out
    }
}

#[derive(Debug, Clone)]
enum Child {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Child {
    fn kind(&self) -> &'static str {
        match self {
            Child::Counter(_) => "counter",
            Child::Gauge(_) => "gauge",
            Child::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug)]
struct Family {
    help: String,
    kind: &'static str,
    /// Children keyed by their rendered label set (`""` for none).
    children: BTreeMap<String, Child>,
}

/// A named collection of metric families. One process-wide instance
/// lives behind [`global`]; separate registries exist only in tests.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Family>>,
}

/// The process-wide registry rendered at `GET /metrics`.
pub fn global() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

/// Render a label set as `{k="v",...}` (empty string for no labels).
fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                _ => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
    out
}

/// Merge an extra label into an already-rendered label key (used for
/// histogram `le`).
fn with_label(key: &str, k: &str, v: &str) -> String {
    if key.is_empty() {
        format!("{{{k}=\"{v}\"}}")
    } else {
        format!("{},{k}=\"{v}\"}}", &key[..key.len() - 1])
    }
}

fn fmt_f64(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else {
        format!("{v}")
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn child(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        mk: impl FnOnce() -> Child,
    ) -> Child {
        debug_assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
                && !name.starts_with(|c: char| c.is_ascii_digit()),
            "bad metric name {name:?}"
        );
        let mut inner = self.inner.lock().unwrap();
        let fam = inner.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind: "",
            children: BTreeMap::new(),
        });
        let child = fam.children.entry(label_key(labels)).or_insert_with(mk);
        if fam.kind.is_empty() {
            fam.kind = child.kind();
        }
        assert_eq!(fam.kind, child.kind(), "metric {name} re-registered as a different type");
        child.clone()
    }

    /// Register (or fetch) a counter for this name + label set.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.child(name, help, labels, || Child::Counter(Arc::new(Counter::default()))) {
            Child::Counter(c) => c,
            _ => unreachable!("metric {name} is not a counter"),
        }
    }

    /// Register (or fetch) a gauge for this name + label set.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.child(name, help, labels, || Child::Gauge(Arc::new(Gauge::new()))) {
            Child::Gauge(g) => g,
            _ => unreachable!("metric {name} is not a gauge"),
        }
    }

    /// Register (or fetch) a histogram with the given finite upper
    /// bounds (a `+Inf` bucket is always appended).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        uppers: &[f64],
    ) -> Arc<Histogram> {
        match self.child(name, help, labels, || Child::Histogram(Arc::new(Histogram::new(uppers))))
        {
            Child::Histogram(h) => h,
            _ => unreachable!("metric {name} is not a histogram"),
        }
    }

    /// Names of every registered family (test + catalog support).
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Prometheus text exposition format (version 0.0.4).
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in inner.iter() {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind));
            for (key, child) in &fam.children {
                match child {
                    Child::Counter(c) => {
                        out.push_str(&format!("{name}{key} {}\n", c.get()));
                    }
                    Child::Gauge(g) => {
                        out.push_str(&format!("{name}{key} {}\n", fmt_f64(g.get())));
                    }
                    Child::Histogram(h) => {
                        for (le, cum) in h.cumulative() {
                            let lk = with_label(key, "le", &fmt_f64(le));
                            out.push_str(&format!("{name}_bucket{lk} {cum}\n"));
                        }
                        out.push_str(&format!("{name}_sum{key} {}\n", fmt_f64(h.sum())));
                        out.push_str(&format!("{name}_count{key} {}\n", h.count()));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("t_requests_total", "requests", &[("route", "GET /x")]);
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // same name + labels yields the same underlying counter
        r.counter("t_requests_total", "requests", &[("route", "GET /x")]).inc();
        assert_eq!(c.get(), 4);
        let g = r.gauge("t_depth", "queue depth", &[]);
        g.set(7.5);
        assert_eq!(g.get(), 7.5);
    }

    #[test]
    fn histogram_cumulative_ends_at_count() {
        let r = Registry::new();
        let h = r.histogram("t_lat_seconds", "latency", &[], &[0.01, 0.1, 1.0]);
        for v in [0.005, 0.05, 0.05, 0.5, 5.0] {
            h.observe(v);
        }
        let cum = h.cumulative();
        assert_eq!(cum.len(), 4);
        assert_eq!(cum[0], (0.01, 1));
        assert_eq!(cum[1], (0.1, 3));
        assert_eq!(cum[2], (1.0, 4));
        assert_eq!(cum[3], (f64::INFINITY, 5));
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 5.605).abs() < 1e-9);
        // cumulative counts never decrease with le
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn render_is_prometheus_text_format() {
        let r = Registry::new();
        r.counter("t_total", "a counter", &[("k", "v")]).add(3);
        r.gauge("t_gauge", "a gauge", &[]).set(1.5);
        r.histogram("t_hist", "a histogram", &[], &[0.5]).observe(0.25);
        let text = r.render();
        assert!(text.contains("# TYPE t_total counter\n"));
        assert!(text.contains("t_total{k=\"v\"} 3\n"));
        assert!(text.contains("# TYPE t_gauge gauge\n"));
        assert!(text.contains("t_gauge 1.5\n"));
        assert!(text.contains("# TYPE t_hist histogram\n"));
        assert!(text.contains("t_hist_bucket{le=\"0.5\"} 1\n"));
        assert!(text.contains("t_hist_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("t_hist_sum 0.25\n"));
        assert!(text.contains("t_hist_count 1\n"));
        // every sample line's family has a preceding # TYPE line
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let fam = line.split(['{', ' ']).next().unwrap();
            let base = fam
                .strip_suffix("_bucket")
                .or_else(|| fam.strip_suffix("_sum"))
                .or_else(|| fam.strip_suffix("_count"))
                .unwrap_or(fam);
            assert!(text.contains(&format!("# TYPE {base} ")), "no TYPE for {line}");
        }
    }

    #[test]
    fn label_escaping() {
        assert_eq!(label_key(&[("k", "a\"b\\c\nd")]), "{k=\"a\\\"b\\\\c\\nd\"}");
        assert_eq!(with_label("{a=\"b\"}", "le", "+Inf"), "{a=\"b\",le=\"+Inf\"}");
        assert_eq!(with_label("", "le", "1"), "{le=\"1\"}");
    }

    #[test]
    fn mirror_is_monotone() {
        let c = Counter::default();
        c.mirror(5);
        c.mirror(3); // stale scrape must not move the series backwards
        assert_eq!(c.get(), 5);
        c.mirror(9);
        assert_eq!(c.get(), 9);
    }
}
