//! The precision-agnostic run launcher: one [`Config`] in, one
//! [`TrainResult`] out.
//!
//! This is the single place that turns a validated config into a
//! running session — dataset generation, backend construction (engine +
//! params for FP32, NITI weights for INT8), checkpoint load/save/resume,
//! and the dispatch into the unified `coordinator::session` loop. The
//! `repro train` CLI, every local `serve` worker AND every remote
//! cluster agent (`repro agent`, which receives the same serialized
//! spec over the wire) go through [`run`], so a job spec and a command
//! line can never drift apart — and a job interrupted on one machine
//! resumes bit-identically on another.
//!
//! # Durability
//!
//! Three checkpoint paths flow through here:
//!
//! * `load` — warm-start the params only (fine-tuning, paper Table 2);
//!   the loop starts from epoch 0 with fresh streams.
//! * `save` — the final checkpoint, written with a v2 training-state
//!   trailer when the run completes. While the run is live, the same
//!   path receives cadence snapshots (`Config::ckpt_every`, default
//!   every epoch) from inside `session::run`, so a killed or cancelled
//!   run keeps its last completed epoch on disk — the final save is
//!   deliberately skipped for stopped runs instead of clobbering that
//!   snapshot with mid-epoch params.
//! * `resume` — restore params AND loop state from a v2 checkpoint and
//!   continue from epoch k with bit-identical batch order and ZO
//!   perturbation streams. The checkpoint's serialized spec must match
//!   the current run's (see `checkpoint::ensure_spec_matches`).

use crate::config::{Config, Precision};
use crate::coordinator::checkpoint::{self, CkptTensor, TrainState};
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::coordinator::dp_session::{DpLocalSession, DpWorld};
use crate::coordinator::session::{self, TrainResult, TrainSpec};
use crate::coordinator::{int8_trainer, trainer, ParamSet};
use crate::data;
use crate::exp;
use crate::int8::lenet8;
use anyhow::Result;

/// Outcome of a launched run.
pub struct Launch {
    pub result: TrainResult,
    /// Backend label for logs: the engine name for FP32 runs,
    /// `"niti-int8"` for the int8 path.
    pub engine: String,
    /// Epoch the run resumed from (`--resume` only).
    pub resumed_from: Option<usize>,
}

/// Run one training job to completion (or cancellation): the exact
/// same path behind `repro train` and the `serve` worker pool.
pub fn run(cfg: &Config, stop: StopFlag, progress: ProgressSink) -> Result<Launch> {
    let (train_d, test_d) =
        data::generate(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed, cfg.npoints);
    let mut spec = cfg.train_spec();
    spec.stop = stop;
    spec.progress = progress;

    // Data-parallel jobs popped by a LOCAL worker run the single-process
    // dp reference: all N shards evaluated in one cycle per step — the
    // same trajectory a distributed run commits, so a dp job degrades
    // correctly on a coordinator with no agents attached.
    if let Some(dp) = cfg.dp_spec() {
        let world = DpWorld::new(cfg.model_enum(), spec.clone(), dp, train_d.len())?;
        let mut sess = DpLocalSession::new(world);
        let result = session::run(&mut sess, &spec, &train_d, &test_d)?;
        save_final(cfg, &spec, &result, None, || sess.world.snapshot())?;
        return Ok(Launch {
            result,
            engine: format!("native dp{}", dp.replicas),
            resumed_from: None,
        });
    }

    match cfg.precision {
        Precision::Fp32 => {
            let model = cfg.model_enum();
            let mut engine =
                exp::build_engine_at(model, cfg.batch, cfg.engine, cfg.artifacts_dir.as_deref());
            let mut params = ParamSet::init(model, cfg.seed ^ 0xC0FFEE);
            let resume_state = match &cfg.resume {
                Some(path) => {
                    let (tensors, state) = load_resumable(path, &spec)?;
                    checkpoint::params_from_tensors(&tensors, &mut params)?;
                    Some(state)
                }
                None => {
                    if let Some(path) = &cfg.load_checkpoint {
                        checkpoint::load_params(path, &mut params)?;
                    }
                    None
                }
            };
            let result = trainer::train_from(
                engine.as_mut(),
                &mut params,
                &train_d,
                &test_d,
                &spec,
                resume_state.as_ref(),
            )?;
            save_final(cfg, &spec, &result, resume_state.as_ref(), || {
                checkpoint::params_to_tensors(&params)
            })?;
            Ok(Launch {
                result,
                engine: engine.name().to_string(),
                resumed_from: resume_state.map(|s| s.epochs_done),
            })
        }
        Precision::Int8 | Precision::Int8Star => {
            let mut ws = lenet8::init_params(cfg.seed ^ 0xC0FFEE, cfg.r_max.max(16));
            let resume_state = match &cfg.resume {
                Some(path) => {
                    let (tensors, state) = load_resumable(path, &spec)?;
                    ws = checkpoint::int8_from_tensors(tensors)?;
                    Some(state)
                }
                None => {
                    if let Some(path) = &cfg.load_checkpoint {
                        ws = checkpoint::load_int8(path)?;
                    }
                    None
                }
            };
            let result = int8_trainer::train_int8_from(
                &mut ws,
                &train_d,
                &test_d,
                &spec,
                resume_state.as_ref(),
            )?;
            let names: Vec<&str> = lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
            save_final(cfg, &spec, &result, resume_state.as_ref(), || {
                checkpoint::int8_to_tensors(&names, &ws)
            })?;
            Ok(Launch {
                result,
                engine: "niti-int8".to_string(),
                resumed_from: resume_state.map(|s| s.epochs_done),
            })
        }
    }
}

/// Load a `--resume` checkpoint: its tensors plus the (required)
/// training state, spec-checked against the current run.
fn load_resumable(path: &str, spec: &TrainSpec) -> Result<(Vec<CkptTensor>, TrainState)> {
    let (tensors, state) = checkpoint::load_full(path)?;
    let state = state.ok_or_else(|| {
        anyhow::anyhow!(
            "checkpoint {path} has no training state (v1 or params-only); \
             use --load for a params-only warm start instead of --resume"
        )
    })?;
    checkpoint::ensure_spec_matches(&state.spec, &spec.to_json())?;
    Ok((tensors, state))
}

/// The final checkpoint, written with its training state when the run
/// completes. A stopped run skips it on purpose: its params are
/// mid-epoch (the stop flag fires between batches), while the cadence
/// snapshots `session::run` already wrote hold the last *completed*
/// epoch — previously a job cancelled at epoch 9/10 persisted nothing.
fn save_final(
    cfg: &Config,
    spec: &TrainSpec,
    result: &TrainResult,
    resume: Option<&TrainState>,
    tensors: impl FnOnce() -> Vec<CkptTensor>,
) -> Result<()> {
    if let (Some(path), false) = (&cfg.save_checkpoint, result.stopped) {
        let state = session::final_state(spec, result, resume);
        checkpoint::save_with_state(path, &tensors(), Some(&state))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(precision: &str, method: &str) -> Config {
        let mut cfg = Config::default();
        cfg.set("engine", "native").unwrap();
        cfg.set("precision", precision).unwrap();
        cfg.set("method", method).unwrap();
        cfg.set("epochs", "1").unwrap();
        cfg.set("batch", "16").unwrap();
        cfg.set("train_n", "48").unwrap();
        cfg.set("test_n", "32").unwrap();
        cfg.validate().unwrap();
        cfg
    }

    #[test]
    fn all_four_methods_run_on_both_precisions() {
        for method in ["full-zo", "cls1", "cls2", "full-bp"] {
            for precision in ["fp32", "int8", "int8*"] {
                let cfg = tiny_cfg(precision, method);
                let l = run(&cfg, StopFlag::default(), ProgressSink::default())
                    .unwrap_or_else(|e| panic!("{precision}/{method}: {e:#}"));
                assert_eq!(l.result.history.epochs.len(), 1, "{precision}/{method}");
                assert!(!l.result.stopped);
            }
        }
    }

    #[test]
    fn fp32_full_bp_reports_live_train_acc() {
        // acceptance: Full BP drives the unified loop with nonzero
        // train accuracy (the full_step logits ABI)
        let mut cfg = tiny_cfg("fp32", "full-bp");
        cfg.set("epochs", "2").unwrap();
        cfg.set("train_n", "128").unwrap();
        cfg.set("lr", "0.05").unwrap();
        let l = run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
        let last = l.result.history.epochs.last().unwrap();
        assert!(last.train_acc > 0.0, "Full BP train_acc must be live");
    }

    #[test]
    fn dp_local_run_trains_and_saves() {
        let path = std::env::temp_dir()
            .join(format!("ezo_launch_dp_{}", std::process::id()))
            .display()
            .to_string();
        let mut cfg = tiny_cfg("fp32", "full-zo");
        cfg.set("dp", "2").unwrap();
        cfg.set("save", &path).unwrap();
        cfg.validate().unwrap();
        let l = run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
        assert_eq!(l.engine, "native dp2");
        assert_eq!(l.result.history.epochs.len(), 1);
        let (tensors, state) = checkpoint::load_full(&path).unwrap();
        assert!(!tensors.is_empty());
        assert_eq!(state.unwrap().step, l.result.steps_done);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn final_save_carries_training_state() {
        let path = std::env::temp_dir()
            .join(format!("ezo_launch_final_{}", std::process::id()))
            .display()
            .to_string();
        let mut cfg = tiny_cfg("fp32", "cls1");
        cfg.set("epochs", "2").unwrap();
        cfg.set("save", &path).unwrap();
        cfg.validate().unwrap();
        let l = run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
        assert!(!l.result.stopped);
        let (_, state) = checkpoint::load_full(&path).unwrap();
        let state = state.expect("final save must carry training state");
        assert_eq!(state.epochs_done, 2);
        assert_eq!(state.step, l.result.steps_done);
        checkpoint::ensure_spec_matches(&state.spec, &cfg.train_spec().to_json()).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
