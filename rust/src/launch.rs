//! The precision-agnostic run launcher: one [`Config`] in, one
//! [`TrainResult`] out.
//!
//! This is the single place that turns a validated config into a
//! running session — dataset generation, backend construction (engine +
//! params for FP32, NITI weights for INT8), checkpoint load/save, and
//! the dispatch into the unified `coordinator::session` loop. Both the
//! `repro train` CLI and every `serve` worker go through [`run`], so a
//! job spec and a command line can never drift apart.

use crate::config::{Config, Precision};
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::coordinator::session::TrainResult;
use crate::coordinator::{checkpoint, int8_trainer, trainer, ParamSet};
use crate::data;
use crate::exp;
use crate::int8::lenet8;
use anyhow::Result;

/// Outcome of a launched run.
pub struct Launch {
    pub result: TrainResult,
    /// Backend label for logs: the engine name for FP32 runs,
    /// `"niti-int8"` for the int8 path.
    pub engine: String,
}

/// Run one training job to completion (or cancellation): the exact
/// same path behind `repro train` and the `serve` worker pool.
pub fn run(cfg: &Config, stop: StopFlag, progress: ProgressSink) -> Result<Launch> {
    let (train_d, test_d) =
        data::generate(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed, cfg.npoints);
    let mut spec = cfg.train_spec();
    spec.stop = stop;
    spec.progress = progress;

    match cfg.precision {
        Precision::Fp32 => {
            let model = cfg.model_enum();
            let mut engine =
                exp::build_engine_at(model, cfg.batch, cfg.engine, cfg.artifacts_dir.as_deref());
            let mut params = ParamSet::init(model, cfg.seed ^ 0xC0FFEE);
            if let Some(path) = &cfg.load_checkpoint {
                checkpoint::load_params(path, &mut params)?;
            }
            let result = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &spec)?;
            if let (Some(path), false) = (&cfg.save_checkpoint, result.stopped) {
                checkpoint::save_params(path, &params)?;
            }
            Ok(Launch { result, engine: engine.name().to_string() })
        }
        Precision::Int8 | Precision::Int8Star => {
            let mut ws = lenet8::init_params(cfg.seed ^ 0xC0FFEE, cfg.r_max.max(16));
            if let Some(path) = &cfg.load_checkpoint {
                ws = checkpoint::load_int8(path)?;
            }
            let result = int8_trainer::train_int8(&mut ws, &train_d, &test_d, &spec)?;
            if let (Some(path), false) = (&cfg.save_checkpoint, result.stopped) {
                let names: Vec<&str> = lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
                checkpoint::save_int8(path, &names, &ws)?;
            }
            Ok(Launch { result, engine: "niti-int8".to_string() })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(precision: &str, method: &str) -> Config {
        let mut cfg = Config::default();
        cfg.set("engine", "native").unwrap();
        cfg.set("precision", precision).unwrap();
        cfg.set("method", method).unwrap();
        cfg.set("epochs", "1").unwrap();
        cfg.set("batch", "16").unwrap();
        cfg.set("train_n", "48").unwrap();
        cfg.set("test_n", "32").unwrap();
        cfg.validate().unwrap();
        cfg
    }

    #[test]
    fn all_four_methods_run_on_both_precisions() {
        for method in ["full-zo", "cls1", "cls2", "full-bp"] {
            for precision in ["fp32", "int8", "int8*"] {
                let cfg = tiny_cfg(precision, method);
                let l = run(&cfg, StopFlag::default(), ProgressSink::default())
                    .unwrap_or_else(|e| panic!("{precision}/{method}: {e:#}"));
                assert_eq!(l.result.history.epochs.len(), 1, "{precision}/{method}");
                assert!(!l.result.stopped);
            }
        }
    }

    #[test]
    fn fp32_full_bp_reports_live_train_acc() {
        // acceptance: Full BP drives the unified loop with nonzero
        // train accuracy (the full_step logits ABI)
        let mut cfg = tiny_cfg("fp32", "full-bp");
        cfg.set("epochs", "2").unwrap();
        cfg.set("train_n", "128").unwrap();
        cfg.set("lr", "0.05").unwrap();
        let l = run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
        let last = l.result.history.epochs.last().unwrap();
        assert!(last.train_acc > 0.0, "Full BP train_acc must be live");
    }
}
