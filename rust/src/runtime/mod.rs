//! PJRT runtime: load the AOT artifacts emitted by `python/compile/aot.py`
//! and execute them from the training hot loop.
//!
//! The flow is the one proven by /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. HLO **text** is the interchange
//! format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//! rejects; the text parser reassigns ids).

pub mod executable;
pub mod manifest;
pub mod registry;

pub use executable::{ArgValue, LoadedArtifact, OutValue};
pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};
pub use registry::Registry;
