//! PJRT runtime: load the AOT artifacts emitted by `python/compile/aot.py`
//! and execute them from the training hot loop.
//!
//! The flow is the one proven by /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. HLO **text** is the interchange
//! format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//! rejects; the text parser reassigns ids).

//! The manifest parser is always available; the PJRT executor
//! (`executable`/`registry`) needs the external `xla` bindings and is
//! gated behind the off-by-default `xla` cargo feature.

#[cfg(feature = "xla")]
pub mod executable;
pub mod manifest;
#[cfg(feature = "xla")]
pub mod registry;

#[cfg(feature = "xla")]
pub use executable::{ArgValue, LoadedArtifact, OutValue};
pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};
#[cfg(feature = "xla")]
pub use registry::Registry;
