//! A loaded artifact: compiled PJRT executable + typed I/O marshalling
//! checked against the manifest ABI.

use super::manifest::{ArtifactSpec, Dtype, IoSpec};
use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// A typed argument for an artifact call (borrowed host data).
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I8(&'a [i8]),
    I32(&'a [i32]),
}

impl ArgValue<'_> {
    pub fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I8(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> Dtype {
        match self {
            ArgValue::F32(_) => Dtype::F32,
            ArgValue::I8(_) => Dtype::I8,
            ArgValue::I32(_) => Dtype::I32,
        }
    }

    fn bytes(&self) -> &[u8] {
        match self {
            ArgValue::F32(v) => bytemuck_cast(v),
            ArgValue::I8(v) => bytemuck_cast(v),
            ArgValue::I32(v) => bytemuck_cast(v),
        }
    }
}

fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    // Safe for plain-old-data scalar slices.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// A typed output tensor copied back to the host.
#[derive(Debug, Clone)]
pub enum OutValue {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

impl OutValue {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32(v) => Ok(v),
            _ => bail!("output is not f32"),
        }
    }
    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            OutValue::I8(v) => Ok(v),
            _ => bail!("output is not i8"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            OutValue::I32(v) => Ok(v),
            _ => bail!("output is not i32"),
        }
    }
    pub fn scalar_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }
}

fn element_type(d: Dtype) -> ElementType {
    match d {
        Dtype::F32 => ElementType::F32,
        Dtype::I8 => ElementType::S8,
        Dtype::I32 => ElementType::S32,
    }
}

/// Build an XLA literal for one manifest input from a typed arg.
fn to_literal(spec: &IoSpec, arg: &ArgValue) -> Result<Literal> {
    if arg.dtype() != spec.dtype {
        bail!(
            "input '{}' dtype mismatch: artifact wants {:?}, got {:?}",
            spec.name,
            spec.dtype,
            arg.dtype()
        );
    }
    if arg.len() != spec.numel() {
        bail!(
            "input '{}' length mismatch: artifact wants {:?} ({} elems), got {}",
            spec.name,
            spec.shape,
            spec.numel(),
            arg.len()
        );
    }
    Literal::create_from_shape_and_untyped_data(
        element_type(spec.dtype),
        &spec.shape,
        arg.bytes(),
    )
    .with_context(|| format!("literal for input '{}'", spec.name))
}

/// An artifact compiled onto a PJRT client.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Load HLO text from `path`, compile, wrap.
    pub fn load(client: &PjRtClient, spec: ArtifactSpec, path: &std::path::Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{}'", spec.name))?;
        Ok(LoadedArtifact { spec, exe })
    }

    /// Execute with ABI-checked inputs; outputs come back in manifest
    /// order, copied to host vectors.
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<OutValue>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        let literals = self
            .spec
            .inputs
            .iter()
            .zip(args)
            .map(|(spec, arg)| to_literal(spec, arg))
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = result.to_tuple().context("detupling result")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        self.spec
            .outputs
            .iter()
            .zip(parts)
            .map(|(ospec, lit)| -> Result<OutValue> {
                Ok(match ospec.dtype {
                    Dtype::F32 => OutValue::F32(lit.to_vec::<f32>()?),
                    Dtype::I8 => OutValue::I8(lit.to_vec::<i8>()?),
                    Dtype::I32 => OutValue::I32(lit.to_vec::<i32>()?),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_lengths_and_bytes() {
        let f = [1.0f32, 2.0];
        let a = ArgValue::F32(&f);
        assert_eq!(a.len(), 2);
        assert_eq!(a.bytes().len(), 8);
        let i = [1i8, 2, 3];
        assert_eq!(ArgValue::I8(&i).bytes().len(), 3);
    }

    #[test]
    fn to_literal_rejects_mismatch() {
        let spec = IoSpec { name: "x".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        let short = [0.0f32; 3];
        assert!(to_literal(&spec, &ArgValue::F32(&short)).is_err());
        let wrong_ty = [0i8; 4];
        assert!(to_literal(&spec, &ArgValue::I8(&wrong_ty)).is_err());
        let ok = [0.0f32; 4];
        assert!(to_literal(&spec, &ArgValue::F32(&ok)).is_ok());
    }

    #[test]
    fn outvalue_accessors() {
        let o = OutValue::F32(vec![3.5]);
        assert_eq!(o.scalar_f32().unwrap(), 3.5);
        assert!(o.as_i8().is_err());
    }
}
