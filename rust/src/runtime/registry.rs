//! Executable registry: one PJRT CPU client, artifacts compiled lazily
//! on first use and cached for the rest of the process lifetime.

use super::executable::LoadedArtifact;
use super::manifest::Manifest;
use anyhow::{Context, Result};
use std::collections::HashMap;
use xla::PjRtClient;

pub struct Registry {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, LoadedArtifact>,
}

impl Registry {
    /// Open the registry over an artifacts directory.
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Registry> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Registry { client, manifest, cache: HashMap::new() })
    }

    /// Open at the default artifacts location.
    pub fn open_default() -> Result<Registry> {
        Registry::open(super::manifest::default_dir())
    }

    /// Get (compiling if needed) an artifact by name.
    pub fn get(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.find(name)?.clone();
            let path = self.manifest.hlo_path(&spec);
            let loaded = LoadedArtifact::load(&self.client, spec, &path)?;
            self.cache.insert(name.to_string(), loaded);
        }
        Ok(&self.cache[name])
    }

    /// Names available in the manifest.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}
