//! Artifact manifest: the ABI contract emitted by aot.py
//! (`artifacts/manifest.json`), parsed with the in-tree JSON module.

use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i8" => Ok(Dtype::I8),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 => 1,
        }
    }
}

/// One input or output tensor of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text path, relative to the manifest directory.
    pub path: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Value,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactSpec>,
}

fn parse_io(v: &Value) -> Result<IoSpec> {
    let name = v.get("name").as_str().context("io missing name")?.to_string();
    let shape = v
        .get("shape")
        .as_arr()
        .context("io missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(v.get("dtype").as_str().context("io missing dtype")?)?;
    Ok(IoSpec { name, shape, dtype })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = json::parse(text).context("manifest json")?;
        let version = v.get("version").as_i64().context("manifest version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let entries = v
            .get("entries")
            .as_arr()
            .context("manifest entries")?
            .iter()
            .map(|e| -> Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    name: e.get("name").as_str().context("entry name")?.to_string(),
                    path: e.get("path").as_str().context("entry path")?.to_string(),
                    inputs: e
                        .get("inputs")
                        .as_arr()
                        .context("entry inputs")?
                        .iter()
                        .map(parse_io)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")
                        .as_arr()
                        .context("entry outputs")?
                        .iter()
                        .map(parse_io)
                        .collect::<Result<Vec<_>>>()?,
                    meta: e.get("meta").clone(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, entries })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| {
                format!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.entries
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }
}

/// Default artifacts directory: `$REPRO_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_dir() -> PathBuf {
    if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // relative to the crate root (works for cargo test/run from repo root)
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "m1", "path": "m1.hlo.txt",
         "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"},
                     {"name": "s", "shape": [], "dtype": "i32"}],
         "outputs": [{"name": "y", "shape": [2], "dtype": "i8"}],
         "meta": {"model": "lenet", "batch": 2}}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("m1").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].dtype, Dtype::F32);
        assert_eq!(e.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.outputs[0].dtype, Dtype::I8);
        assert_eq!(e.meta.get("model").as_str(), Some("lenet"));
        assert_eq!(e.inputs[1].numel(), 1); // scalar
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let err = m.find("nope").unwrap_err().to_string();
        assert!(err.contains("m1"), "{err}");
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(Dtype::F32.size(), 4);
        assert_eq!(Dtype::I8.size(), 1);
        assert!(Dtype::parse("f64").is_err());
    }
}
