//! Dense CPU tensors for the native engine (f32 / i8 / i32).
//!
//! Deliberately small: contiguous row-major storage, shape tracking,
//! and the handful of ops the LeNet/PointNet engines need. The heavy
//! math lives in `nn::` (f32) and `int8::` (NITI), which operate on
//! these buffers directly.

pub mod ops;

/// Shape = dimension list; row-major (C-order) layout, matching both
/// numpy defaults and the XLA literals produced by the runtime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn of(dims: &[usize]) -> Shape {
        Shape(dims.to_vec())
    }
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }
    pub fn rank(&self) -> usize {
        self.0.len()
    }
    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, ")")
    }
}

/// Generic dense tensor over a scalar element type.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    pub shape: Shape,
    pub data: Vec<T>,
}

pub type TensorF32 = Tensor<f32>;
pub type TensorI8 = Tensor<i8>;
pub type TensorI32 = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(dims: &[usize]) -> Tensor<T> {
        let shape = Shape::of(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![T::default(); n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<T>) -> Tensor<T> {
        let shape = Shape::of(dims);
        assert_eq!(shape.numel(), data.len(), "shape {shape} vs len {}", data.len());
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, dims: &[usize]) -> Tensor<T> {
        let new = Shape::of(dims);
        assert_eq!(new.numel(), self.numel(), "reshape {new} from {}", self.shape);
        self.shape = new;
        self
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.shape.rank(), 2);
        self.data[i * self.shape.0[1] + j]
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> T {
        debug_assert_eq!(self.shape.rank(), 4);
        let s = &self.shape.0;
        self.data[((a * s[1] + b) * s[2] + c) * s[3] + d]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: T) {
        let s = &self.shape.0;
        let idx = ((a * s[1] + b) * s[2] + c) * s[3] + d;
        self.data[idx] = v;
    }
}

impl TensorF32 {
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

impl TensorI32 {
    pub fn max_abs(&self) -> i32 {
        self.data.iter().fold(0i32, |m, v| m.max(v.wrapping_abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: TensorF32 = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.shape.rank(), 3);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_vec_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_wrong_len_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0f32]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1i32, 2, 3, 4]).reshape(&[2, 2]);
        assert_eq!(t.at2(1, 1), 4);
    }

    #[test]
    fn index4() {
        let mut t: TensorI8 = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7);
        assert_eq!(t.at4(1, 2, 3, 4), 7);
        assert_eq!(t.at4(0, 0, 0, 0), 0);
    }

    #[test]
    fn max_abs() {
        let t = Tensor::from_vec(&[4], vec![1.0f32, -5.0, 3.0, -2.0]);
        assert_eq!(t.max_abs(), 5.0);
        let t = Tensor::from_vec(&[3], vec![1i32, -9, 4]);
        assert_eq!(t.max_abs(), 9);
    }

    #[test]
    fn shape_display() {
        assert_eq!(Shape::of(&[2, 3]).to_string(), "(2,3)");
    }
}
