//! Core tensor math used by the native engines: f32 GEMM (the hot path,
//! written cache-friendly), int8→int32 GEMM, transposes and reductions.

use super::{Tensor, TensorF32, TensorI32, TensorI8};

/// C = A(M,K) @ B(K,N), f32. i-k-j loop order: the inner loop runs
/// contiguously over B's rows and C's row, which vectorizes well.
pub fn matmul_f32(a: &TensorF32, b: &TensorF32) -> TensorF32 {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = vec![0.0f32; m * n];
    matmul_f32_into(&a.data, &b.data, &mut c, m, k, n);
    Tensor::from_vec(&[m, n], c)
}

/// GEMM into a caller-provided buffer (avoids allocation on hot paths).
pub fn matmul_f32_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C = A(M,K) @ B(K,N), int8 operands, exact int32 accumulation.
pub fn matmul_i8(a: &TensorI8, b: &TensorI8) -> TensorI32 {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// B = Aᵀ for a 2-D tensor.
pub fn transpose2<T: Copy + Default>(a: &Tensor<T>) -> Tensor<T> {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = vec![T::default(); m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data[i * n + j];
        }
    }
    Tensor::from_vec(&[n, m], out)
}

/// Column sums of a 2-D tensor: (M,N) -> (N,).
pub fn col_sum_f32(a: &TensorF32) -> TensorF32 {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a.data[i * n + j];
        }
    }
    Tensor::from_vec(&[n], out)
}

/// y += alpha * x (saxpy), used by SGD updates and the ZO perturbation
/// replay. Chunked into fixed 16-lane strips so the compiler emits wide
/// vector code without a `-C target-cpu` hint; per-element math is the
/// same mul-then-add as the plain loop, so results are bit-identical on
/// any chunk width.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    const LANES: usize = 16;
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ys, xs) in (&mut yc).zip(&mut xc) {
        for (yi, &xi) in ys.iter_mut().zip(xs) {
            *yi += alpha * xi;
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * xi;
    }
}

/// ReLU in place.
pub fn relu_f32(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn relu_i8(x: &mut [i8]) {
    for v in x {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// argmax over the last axis of a 2-D tensor; returns (M,) indices.
pub fn argmax_rows(a: &TensorF32) -> Vec<usize> {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    (0..m)
        .map(|i| {
            let row = &a.data[i * n..(i + 1) * n];
            row.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

pub fn argmax_rows_i8(a: &TensorI8) -> Vec<usize> {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    (0..m)
        .map(|i| {
            let row = &a.data[i * n..(i + 1) * n];
            row.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul_f32(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity_prop() {
        prop::cases(10, |rng, _| {
            let m = 1 + (rng.next_u64() % 16) as usize;
            let k = 1 + (rng.next_u64() % 16) as usize;
            let a = Tensor::from_vec(
                &[m, k],
                (0..m * k).map(|_| rng.normal()).collect(),
            );
            let mut eye = Tensor::zeros(&[k, k]);
            for i in 0..k {
                eye.data[i * k + i] = 1.0f32;
            }
            let c = matmul_f32(&a, &eye);
            assert_eq!(c.data, a.data);
        });
    }

    #[test]
    fn matmul_i8_matches_f32_path() {
        prop::cases(10, |rng, _| {
            let m = 1 + (rng.next_u64() % 8) as usize;
            let k = 1 + (rng.next_u64() % 32) as usize;
            let n = 1 + (rng.next_u64() % 8) as usize;
            let a = Tensor::from_vec(
                &[m, k],
                (0..m * k).map(|_| rng.uniform_i32(-128, 127) as i8).collect(),
            );
            let b = Tensor::from_vec(
                &[k, n],
                (0..k * n).map(|_| rng.uniform_i32(-128, 127) as i8).collect(),
            );
            let ci = matmul_i8(&a, &b);
            let af = Tensor::from_vec(&[m, k], a.data.iter().map(|&v| v as f32).collect());
            let bf = Tensor::from_vec(&[k, n], b.data.iter().map(|&v| v as f32).collect());
            let cf = matmul_f32(&af, &bf);
            for (x, y) in ci.data.iter().zip(&cf.data) {
                assert_eq!(*x, *y as i32);
            }
        });
    }

    #[test]
    fn transpose_involution() {
        prop::cases(10, |rng, _| {
            let m = 1 + (rng.next_u64() % 10) as usize;
            let n = 1 + (rng.next_u64() % 10) as usize;
            let a = Tensor::from_vec(&[m, n], (0..m * n).map(|_| rng.normal()).collect());
            let tt = transpose2(&transpose2(&a));
            assert_eq!(tt, a);
        });
    }

    #[test]
    fn transpose_matmul_identity() {
        // (A B)ᵀ = Bᵀ Aᵀ
        prop::cases(5, |rng, _| {
            let (m, k, n) = (3usize, 4usize, 5usize);
            let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| rng.normal()).collect());
            let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.normal()).collect());
            let lhs = transpose2(&matmul_f32(&a, &b));
            let rhs = matmul_f32(&transpose2(&b), &transpose2(&a));
            for (x, y) in lhs.data.iter().zip(&rhs.data) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn col_sum() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(col_sum_f32(&a).data, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn relu_and_argmax() {
        let mut v = vec![-1.0f32, 2.0, -3.0];
        relu_f32(&mut v);
        assert_eq!(v, vec![0.0, 2.0, 0.0]);
        let a = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 0.7, 0.2, 0.1]);
        assert_eq!(argmax_rows(&a), vec![1, 0]);
    }

    #[test]
    fn axpy_updates() {
        let x = vec![1.0f32, 2.0];
        let mut y = vec![10.0f32, 20.0];
        axpy(-2.0, &x, &mut y);
        assert_eq!(y, vec![8.0, 16.0]);
    }
}
