//! Figs. 2–3: training/test loss curves of LeNet-5 over epochs for the
//! four methods — FP32 (fig2) and INT8 (fig3). Prints per-epoch series
//! and dumps the full curves as JSON (plot-ready).
//!
//! Shape check: ElasticZO (Cls1/Cls2) converges visibly faster than
//! Full ZO and approaches Full BP; the INT8 hybrid has much lower loss
//! than INT8 Full ZO at early epochs.

use super::{dump_result, run_fp32, run_int8, Scale};
use crate::coordinator::engine::{EngineKind, Method};
use crate::coordinator::int8_trainer::ZoGradMode;
use crate::coordinator::metrics::History;
use crate::coordinator::Model;
use crate::data::DatasetKind;
use crate::util::json::Value;
use anyhow::Result;

fn curves_json(histories: &[History]) -> Value {
    Value::Arr(histories.iter().map(|h| h.to_json()).collect())
}

fn print_curves(title: &str, histories: &[History]) {
    println!("## {title}");
    // header
    print!("{:<7}", "epoch");
    for h in histories {
        print!(" | {:^21}", h.label);
    }
    println!();
    let max_epochs = histories.iter().map(|h| h.epochs.len()).max().unwrap_or(0);
    for e in 0..max_epochs {
        print!("{e:<7}");
        for h in histories {
            match h.epochs.get(e) {
                Some(s) => print!(" | tr {:>7.4} te {:>7.4}", s.train_loss, s.test_loss),
                None => print!(" | {:^21}", "-"),
            }
        }
        println!();
    }
}

pub fn run_fig2(scale: Scale, engine: EngineKind) -> Result<()> {
    for (name, kind) in [
        ("SynthMNIST", DatasetKind::SynthMnist),
        ("SynthFashion", DatasetKind::SynthFashion),
    ] {
        let mut histories = Vec::new();
        for method in Method::ALL {
            let r = run_fp32(
                Model::LeNet, kind, method, engine,
                scale.fp32_epochs(), 32, scale.train_n(), scale.test_n(), 42,
            )?;
            histories.push(r.history);
        }
        print_curves(&format!("Fig 2 ({name}, FP32 loss curves)"), &histories);
        dump_result(
            &format!("fig2_{}", name.to_lowercase()),
            &curves_json(&histories),
        )?;
    }
    Ok(())
}

pub fn run_fig3(scale: Scale) -> Result<()> {
    for (name, kind) in [
        ("SynthMNIST", DatasetKind::SynthMnist),
        ("SynthFashion", DatasetKind::SynthFashion),
    ] {
        let mut histories = Vec::new();
        for method in Method::ALL {
            let r = run_int8(
                kind, method, ZoGradMode::FloatCE,
                scale.int8_epochs(), 32, scale.train_n(), scale.test_n(), 43,
            )?;
            histories.push(r.history);
        }
        print_curves(&format!("Fig 3 ({name}, INT8 loss curves)"), &histories);
        dump_result(
            &format!("fig3_{}", name.to_lowercase()),
            &curves_json(&histories),
        )?;
    }
    Ok(())
}
