//! Figs. 4–6: memory-usage breakdowns from the analytic model (paper
//! Eqs. 2–4 for FP32, 13–15 for INT8) — exact, no training required.
//!
//! Shape checks (paper §5.3): Full BP = 2× Full ZO (FP32); Cls1/Cls2
//! overheads ≈ +0.07–2.4%; INT8 saves 1.46–1.60× (not 4×, because of
//! int32 scratch); PointNet activations dominate (>99%).

use super::dump_result;
use crate::coordinator::engine::Method;
use crate::memory::{self, models, Breakdown};
use crate::util::json::Value;
use crate::util::table::{bytes, pct, Table};
use anyhow::Result;

fn row(label: &str, b: &Breakdown, base_total: Option<usize>) -> Vec<String> {
    let over = match base_total {
        Some(base) if b.total() >= base => {
            format!("+{}", pct((b.total() - base) as f64 / base as f64))
        }
        _ => "-".to_string(),
    };
    vec![
        label.to_string(),
        bytes(b.params),
        bytes(b.acts),
        bytes(b.grads),
        bytes(b.errors),
        bytes(b.int32_scratch),
        bytes(b.total()),
        over,
    ]
}

fn breakdown_json(b: &Breakdown) -> Value {
    Value::obj(vec![
        ("params", Value::num(b.params as f64)),
        ("acts", Value::num(b.acts as f64)),
        ("grads", Value::num(b.grads as f64)),
        ("errors", Value::num(b.errors as f64)),
        ("int32_scratch", Value::num(b.int32_scratch as f64)),
        ("total", Value::num(b.total() as f64)),
    ])
}

const HEADER: [&str; 8] = ["method", "params", "acts", "grads", "errors", "int32", "total", "vs ZO"];

pub fn run_fig4() -> Result<()> {
    let layers = models::lenet_layers();
    let mut out = Vec::new();
    for batch in [32usize, 256] {
        let mut t = Table::new(&format!("Fig 4: LeNet-5 FP32 memory, B={batch}"), &HEADER);
        let zo_total = memory::fp32(&layers, batch, Method::FULL_ZO.memory_method(), false).total();
        for m in [Method::FULL_ZO, Method::CLS2, Method::CLS1, Method::FullBp] {
            let b = memory::fp32(&layers, batch, m.memory_method(), false);
            t.row(&row(&m.label(), &b, Some(zo_total)));
            out.push(Value::obj(vec![
                ("batch", Value::num(batch as f64)),
                ("method", Value::str(m.label())),
                ("breakdown", breakdown_json(&b)),
            ]));
        }
        t.print();
    }
    dump_result("fig4", &Value::Arr(out))
}

pub fn run_fig5() -> Result<()> {
    let layers = models::lenet_int8_layers();
    let fp_layers = models::lenet_layers();
    let mut out = Vec::new();
    for batch in [32usize, 256] {
        let mut t = Table::new(&format!("Fig 5: LeNet-5 INT8 memory, B={batch}"), &HEADER);
        let zo_total = memory::int8(&layers, batch, Method::FULL_ZO.memory_method()).total();
        for m in [Method::FULL_ZO, Method::CLS2, Method::CLS1, Method::FullBp] {
            let b = memory::int8(&layers, batch, m.memory_method());
            t.row(&row(&m.label(), &b, Some(zo_total)));
            let fp = memory::fp32(&fp_layers, batch, m.memory_method(), false);
            out.push(Value::obj(vec![
                ("batch", Value::num(batch as f64)),
                ("method", Value::str(m.label())),
                ("breakdown", breakdown_json(&b)),
                ("fp32_over_int8", Value::num(fp.total() as f64 / b.total() as f64)),
            ]));
        }
        t.print();
        // the paper's headline: INT8 saves 1.46-1.60x vs FP32
        for m in [Method::FULL_ZO, Method::CLS2, Method::CLS1] {
            let f = memory::fp32(&fp_layers, batch, m.memory_method(), false).total();
            let i = memory::int8(&layers, batch, m.memory_method()).total();
            println!(
                "   {} B={batch}: FP32/INT8 = {:.2}x (paper: 1.46-1.60x)",
                m.label(),
                f as f64 / i as f64
            );
        }
    }
    dump_result("fig5", &Value::Arr(out))
}

pub fn run_fig6() -> Result<()> {
    let layers = models::pointnet_layers(1024, 40);
    let mut out = Vec::new();
    let batch = 32;
    let mut t = Table::new("Fig 6: PointNet FP32 memory, B=32, N=1024", &HEADER);
    let zo_total = memory::fp32(&layers, batch, Method::FULL_ZO.memory_method(), false).total();
    for m in [Method::FULL_ZO, Method::CLS2, Method::CLS1, Method::FullBp] {
        let b = memory::fp32(&layers, batch, m.memory_method(), false);
        t.row(&row(&m.label(), &b, Some(zo_total)));
        out.push(Value::obj(vec![
            ("method", Value::str(m.label())),
            ("breakdown", breakdown_json(&b)),
        ]));
    }
    t.print();
    let e2 = memory::fp32(&layers, batch, Method::CLS2.memory_method(), false);
    println!(
        "   activations+errors share (Cls2): {} (paper: 99.4%)",
        pct((e2.acts + e2.errors) as f64 / e2.total() as f64)
    );
    dump_result("fig6", &Value::Arr(out))
}
