//! Experiment harnesses: one entry per paper table/figure
//! (`repro exp <id>`). Each harness regenerates its artifact at a
//! config-scaled size and prints paper-style rows; results are also
//! dumped as JSON under `results/`.
//!
//! | id     | paper artifact                                   |
//! |--------|--------------------------------------------------|
//! | table1 | Tab. 1 — accuracy, 4 methods × {FP32,INT8,INT8*} |
//! | table2 | Tab. 2 — fine-tuning on rotated datasets          |
//! | fig2   | FP32 loss curves (MNIST / Fashion)                |
//! | fig3   | INT8 loss curves                                  |
//! | fig4   | FP32 LeNet memory breakdown (B=32/256)            |
//! | fig5   | INT8 LeNet memory breakdown                       |
//! | fig6   | PointNet memory breakdown (B=32)                  |
//! | fig7   | execution-time phase breakdown, FP32 vs INT8      |

pub mod fig7;
pub mod figs_loss;
pub mod figs_mem;
pub mod table1;
pub mod table2;

use crate::coordinator::engine::{EngineKind, Method};
use crate::coordinator::int8_trainer::{self, ZoGradMode};
use crate::coordinator::native_engine::NativeEngine;
use crate::coordinator::session::{PrecisionSpec, TrainResult, TrainSpec};
use crate::coordinator::trainer;
#[cfg(feature = "xla")]
use crate::coordinator::xla_engine::XlaEngine;
use crate::coordinator::{Engine, Model, ParamSet};
use crate::data::{self, Dataset, DatasetKind};
use crate::int8::lenet8;
use crate::int8::qtensor::QTensor;
use crate::util::json::Value;
use anyhow::Result;

/// Run-scale knobs: `--fast` shrinks everything for smoke runs; the
/// default is the EXPERIMENTS.md reproduction scale; `--paper` matches
/// the paper's epochs/sizes (slow; hours).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Fast,
    Repro,
    Paper,
}

impl Scale {
    pub fn from_flags(fast: bool, paper: bool) -> Scale {
        if fast {
            Scale::Fast
        } else if paper {
            Scale::Paper
        } else {
            Scale::Repro
        }
    }

    pub fn train_n(&self) -> usize {
        match self {
            Scale::Fast => 1536,
            Scale::Repro => 3072,
            Scale::Paper => 50_000,
        }
    }
    pub fn test_n(&self) -> usize {
        match self {
            Scale::Fast => 512,
            Scale::Repro => 1024,
            Scale::Paper => 10_000,
        }
    }
    pub fn fp32_epochs(&self) -> usize {
        match self {
            Scale::Fast => 8,
            Scale::Repro => 15,
            Scale::Paper => 100,
        }
    }
    pub fn int8_epochs(&self) -> usize {
        match self {
            Scale::Fast => 8,
            Scale::Repro => 12,
            Scale::Paper => 100,
        }
    }
    pub fn pointnet_epochs(&self) -> usize {
        match self {
            Scale::Fast => 8,
            Scale::Repro => 12,
            Scale::Paper => 200,
        }
    }
    pub fn pointnet_train_n(&self) -> usize {
        match self {
            Scale::Fast => 960,
            Scale::Repro => 1600,
            Scale::Paper => 9_843,
        }
    }
    pub fn pointnet_test_n(&self) -> usize {
        match self {
            Scale::Fast => 320,
            Scale::Repro => 640,
            Scale::Paper => 2_468,
        }
    }
    pub fn ft_n(&self) -> usize {
        1024 // paper: 1024 rotated samples
    }
    pub fn ft_epochs(&self) -> usize {
        match self {
            Scale::Fast => 6,
            Scale::Repro => 10,
            Scale::Paper => 50,
        }
    }
}

/// Shared FP32 run context.
pub struct Fp32Run {
    pub model: Model,
    pub batch: usize,
    pub engine: Box<dyn Engine>,
}

/// Build the configured engine, falling back to native (with a warning)
/// when artifacts are unavailable or the crate was built without the
/// `xla` feature.
pub fn build_engine(model: Model, batch: usize, kind: EngineKind) -> Box<dyn Engine> {
    build_engine_at(model, batch, kind, None)
}

/// Like [`build_engine`], with an explicit artifacts directory override
/// (the `serve` workers use this so per-job `artifacts` specs don't
/// race on a process-wide env var).
pub fn build_engine_at(
    model: Model,
    batch: usize,
    kind: EngineKind,
    artifacts: Option<&str>,
) -> Box<dyn Engine> {
    match kind {
        EngineKind::Native => Box::new(NativeEngine::new(model)),
        #[cfg(feature = "xla")]
        EngineKind::Xla => {
            let open = || -> Result<XlaEngine> {
                match artifacts {
                    Some(dir) => {
                        XlaEngine::new(crate::runtime::Registry::open(dir)?, model, batch)
                    }
                    None => XlaEngine::open_default(model, batch),
                }
            };
            match open() {
                Ok(e) => Box::new(e),
                Err(err) => {
                    eprintln!(
                        "warning: XLA engine unavailable ({err:#}); falling back to native engine"
                    );
                    Box::new(NativeEngine::new(model))
                }
            }
        }
        #[cfg(not(feature = "xla"))]
        EngineKind::Xla => {
            // only the XLA artifacts have static batch shapes / a dir
            let _ = (batch, artifacts);
            eprintln!(
                "warning: built without the `xla` feature; falling back to native engine"
            );
            Box::new(NativeEngine::new(model))
        }
    }
}

/// Per-method FP32 hyper-parameters (paper §5.1.1 shapes, pre-tuned on
/// the synthetic datasets).
pub fn fp32_train_spec(method: Method, epochs: usize, batch: usize, seed: u64) -> TrainSpec {
    let lr0 = match method {
        Method::FullBp => 0.05,
        Method::Tail(_) => 2e-3,
    };
    TrainSpec {
        method,
        epochs,
        batch,
        lr0,
        eps: 1e-2,
        g_clip: 5.0,
        seed,
        eval_every: 1,
        verbose: std::env::var("REPRO_VERBOSE").is_ok(),
        ..Default::default()
    }
}

/// One FP32 training run (fresh params).
pub fn run_fp32(
    model: Model,
    kind: DatasetKind,
    method: Method,
    engine_kind: EngineKind,
    epochs: usize,
    batch: usize,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> Result<TrainResult> {
    let npoints = match model {
        Model::PointNet { npoints, .. } => npoints,
        _ => 0,
    };
    let (train_d, test_d) = data::generate(kind, train_n, test_n, seed, npoints);
    let mut engine = build_engine(model, batch, engine_kind);
    let mut params = ParamSet::init(model, seed ^ 0xC0FFEE);
    let spec = fp32_train_spec(method, epochs, batch, seed);
    trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &spec)
}

/// One INT8 training run (fresh NITI weights). LeNet only, as in the paper.
pub fn run_int8(
    kind: DatasetKind,
    method: Method,
    grad_mode: ZoGradMode,
    epochs: usize,
    batch: usize,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> Result<TrainResult> {
    let (train_d, test_d) = data::generate(kind, train_n, test_n, seed, 0);
    let mut ws: Vec<QTensor> = lenet8::init_params(seed ^ 0xC0FFEE, 32);
    let spec = TrainSpec {
        method,
        precision: PrecisionSpec::int8(grad_mode),
        epochs,
        batch,
        seed,
        eval_every: 1,
        verbose: std::env::var("REPRO_VERBOSE").is_ok(),
        ..Default::default()
    };
    int8_trainer::train_int8(&mut ws, &train_d, &test_d, &spec)
}

/// Generate rotated fine-tuning splits (paper Table 2 protocol).
pub fn rotated_splits(kind: DatasetKind, deg: f32, n: usize, seed: u64) -> (Dataset, Dataset) {
    let (train_d, test_d) = data::generate(kind, n, n, seed, 0);
    (
        crate::data::rotate::rotate_dataset(&train_d, deg),
        crate::data::rotate::rotate_dataset(&test_d, deg),
    )
}

/// Write a result JSON under results/.
pub fn dump_result(name: &str, v: &Value) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.json");
    std::fs::write(&path, crate::util::json::to_string_pretty(v))?;
    println!("(wrote {path})");
    Ok(())
}

/// Dispatch an experiment id.
pub fn run(id: &str, scale: Scale, engine: EngineKind) -> Result<()> {
    match id {
        "table1" => table1::run(scale, engine),
        "table2" => table2::run(scale, engine),
        "fig2" => figs_loss::run_fig2(scale, engine),
        "fig3" => figs_loss::run_fig3(scale),
        "fig4" => figs_mem::run_fig4(),
        "fig5" => figs_mem::run_fig5(),
        "fig6" => figs_mem::run_fig6(),
        "fig7" => fig7::run(scale),
        "all" => {
            for id in ["fig4", "fig5", "fig6", "fig7", "fig2", "fig3", "table1", "table2"] {
                println!("\n=== exp {id} ===");
                run(id, scale, engine)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (table1|table2|fig2..fig7|all)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_flags() {
        assert_eq!(Scale::from_flags(true, false), Scale::Fast);
        assert_eq!(Scale::from_flags(false, true), Scale::Paper);
        assert_eq!(Scale::from_flags(false, false), Scale::Repro);
        assert!(Scale::Paper.train_n() > Scale::Repro.train_n());
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(run("table9", Scale::Fast, EngineKind::Native).is_err());
    }
}
