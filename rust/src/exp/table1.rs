//! Table 1: classification accuracy of LeNet-5 (SynthMNIST /
//! SynthFashion) across {Full ZO, ZO-Feat-Cls2, ZO-Feat-Cls1, Full BP}
//! × {FP32, INT8, INT8*}, plus PointNet (SynthModelNet) FP32.
//!
//! Shape check (paper): accuracy ordering Full ZO < Cls2 < Cls1 ≲ Full
//! BP in every column; INT8 ≈ FP32; INT8* slightly below INT8.

use super::{dump_result, run_fp32, run_int8, Scale};
use crate::coordinator::engine::{EngineKind, Method};
use crate::coordinator::int8_trainer::ZoGradMode;
use crate::coordinator::Model;
use crate::data::DatasetKind;
use crate::util::json::Value;
use crate::util::table::Table;
use anyhow::Result;

pub fn run(scale: Scale, engine: EngineKind) -> Result<()> {
    let mut table = Table::new(
        "Table 1: accuracy of LeNet-5 (SynthMNIST, SynthFashion) and PointNet (SynthModelNet)",
        &["method", "MNIST FP32", "MNIST INT8", "MNIST INT8*",
          "F-MNIST FP32", "F-MNIST INT8", "F-MNIST INT8*", "ModelNet FP32"],
    );
    let mut json_rows: Vec<Value> = Vec::new();

    for method in Method::ALL {
        let mut cells = vec![method.label().to_string()];
        let mut row_obj = vec![("method", Value::str(method.label()))];

        for (di, kind) in [DatasetKind::SynthMnist, DatasetKind::SynthFashion]
            .iter()
            .enumerate()
        {
            // FP32
            let r = run_fp32(
                Model::LeNet, *kind, method, engine,
                scale.fp32_epochs(), 32, scale.train_n(), scale.test_n(),
                100 + di as u64,
            )?;
            let fp32_acc = r.history.best_test_acc();
            cells.push(format!("{:.2}", fp32_acc * 100.0));

            // INT8 (float-CE sign); for Full BP this is the NITI baseline
            let int8_acc = run_int8(
                *kind, method, ZoGradMode::FloatCE, scale.int8_epochs(),
                32, scale.train_n(), scale.test_n(), 200 + di as u64,
            )?
            .history
            .best_test_acc();
            cells.push(format!("{:.2}", int8_acc * 100.0));

            let int8s_acc = if method == Method::FullBp {
                f32::NAN // paper: INT8* column not applicable to Full BP
            } else {
                run_int8(*kind, method, ZoGradMode::IntCE, scale.int8_epochs(),
                         32, scale.train_n(), scale.test_n(), 300 + di as u64)?
                    .history
                    .best_test_acc()
            };
            cells.push(if int8s_acc.is_nan() {
                "-".to_string()
            } else {
                format!("{:.2}", int8s_acc * 100.0)
            });

            let ds = if di == 0 { "mnist" } else { "fashion" };
            row_obj.push((
                match di {
                    0 => "mnist",
                    _ => "fashion",
                },
                Value::obj(vec![
                    ("fp32", Value::num(fp32_acc as f64)),
                    ("int8", Value::num(int8_acc as f64)),
                    (
                        "int8_star",
                        if int8s_acc.is_nan() { Value::Null } else { Value::num(int8s_acc as f64) },
                    ),
                ]),
            ));
            let _ = ds;
        }

        // PointNet / SynthModelNet, FP32 only (as the paper)
        let model = Model::PointNet { npoints: 128, ncls: 40 };
        let r = run_fp32(
            model, DatasetKind::SynthModelNet, method, engine,
            scale.pointnet_epochs(), 16, scale.pointnet_train_n(),
            scale.pointnet_test_n(), 400,
        )?;
        let pn_acc = r.history.best_test_acc();
        cells.push(format!("{:.2}", pn_acc * 100.0));
        row_obj.push(("modelnet_fp32", Value::num(pn_acc as f64)));

        table.row(&cells);
        json_rows.push(Value::obj(row_obj));
        // print incrementally so long runs show progress
        println!("  [{}] done", method.label());
    }

    table.print();
    dump_result("table1", &Value::obj(vec![("rows", Value::Arr(json_rows))]))?;
    Ok(())
}
