//! Table 2: fine-tuning on Rotated SynthMNIST / Rotated SynthFashion
//! (30° and 45°), FP32 and INT8.
//!
//! Protocol (paper §5.2): pretrain on the clean dataset with BP, then
//! fine-tune on 1024 rotated samples with each method; the "w/o
//! Fine-tuning" row evaluates the pretrained model on the rotated test
//! split directly. Shape check: fine-tuning recovers most of the
//! rotation-induced drop, ordering Full ZO < Cls2 ≈ Cls1 < Full BP.

use super::{build_engine, dump_result, fp32_train_spec, rotated_splits, Scale};
use crate::coordinator::engine::{EngineKind, Method};
use crate::coordinator::int8_trainer::{self, ZoGradMode};
use crate::coordinator::session::{PrecisionSpec, TrainSpec};
use crate::coordinator::{trainer, Model, ParamSet};
use crate::data::{self, DatasetKind};
use crate::int8::lenet8;
use crate::util::json::Value;
use crate::util::table::Table;
use anyhow::Result;

pub fn run(scale: Scale, engine_kind: EngineKind) -> Result<()> {
    let mut table = Table::new(
        "Table 2: LeNet-5 w/ and w/o fine-tuning on rotated datasets",
        &["method",
          "FP32 M-30", "FP32 M-45", "FP32 F-30", "FP32 F-45",
          "INT8 M-30", "INT8 M-45", "INT8 F-30", "INT8 F-45"],
    );

    let configs: Vec<(DatasetKind, f32)> = vec![
        (DatasetKind::SynthMnist, 30.0),
        (DatasetKind::SynthMnist, 45.0),
        (DatasetKind::SynthFashion, 30.0),
        (DatasetKind::SynthFashion, 45.0),
    ];

    // ---- pretrain once per dataset (FP32 + INT8) -------------------
    let mut fp32_pre: Vec<ParamSet> = Vec::new();
    let mut int8_pre: Vec<Vec<crate::int8::qtensor::QTensor>> = Vec::new();
    for (di, kind) in [DatasetKind::SynthMnist, DatasetKind::SynthFashion].iter().enumerate() {
        let (train_d, test_d) = data::generate(*kind, scale.train_n(), scale.test_n(), 77, 0);
        // FP32 pretrain: Full BP
        let mut engine = build_engine(Model::LeNet, 32, engine_kind);
        let mut params = ParamSet::init(Model::LeNet, 500 + di as u64);
        let spec = fp32_train_spec(Method::FullBp, scale.ft_epochs().min(8), 32, 77);
        trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &spec)?;
        fp32_pre.push(params);
        // INT8 pretrain: NITI full BP
        let mut ws = lenet8::init_params(600 + di as u64, 32);
        let ispec = TrainSpec {
            method: Method::FullBp,
            precision: PrecisionSpec::int8(ZoGradMode::FloatCE),
            epochs: scale.int8_epochs().min(10),
            batch: 32,
            seed: 77,
            ..Default::default()
        };
        int8_trainer::train_int8(&mut ws, &train_d, &test_d, &ispec)?;
        int8_pre.push(ws);
    }

    let mut json_rows: Vec<Value> = Vec::new();
    let methods: Vec<Option<Method>> = vec![
        None, // w/o fine-tuning
        Some(Method::FULL_ZO),
        Some(Method::CLS2),
        Some(Method::CLS1),
        Some(Method::FullBp),
    ];

    for m in methods {
        let label = m.map(|m| m.label()).unwrap_or_else(|| "w/o Fine-tuning".to_string());
        let mut cells = vec![label.clone()];
        let mut accs_json = vec![("method", Value::str(label.clone()))];

        // FP32 columns then INT8 columns
        for precision in ["fp32", "int8"] {
            for (ci, (kind, deg)) in configs.iter().enumerate() {
                let di = if *kind == DatasetKind::SynthMnist { 0 } else { 1 };
                let (ft_train, ft_test) = rotated_splits(*kind, *deg, scale.ft_n(), 88);
                let acc = match (precision, m) {
                    ("fp32", None) => {
                        let mut engine = build_engine(Model::LeNet, 32, engine_kind);
                        trainer::evaluate(engine.as_mut(), &fp32_pre[di], &ft_test, 32)?.1
                    }
                    ("fp32", Some(method)) => {
                        let mut engine = build_engine(Model::LeNet, 32, engine_kind);
                        let mut params = fp32_pre[di].clone();
                        let spec = fp32_train_spec(method, scale.ft_epochs(), 32, 90 + ci as u64);
                        let r = trainer::train(
                            engine.as_mut(), &mut params, &ft_train, &ft_test, &spec,
                        )?;
                        r.history.best_test_acc()
                    }
                    ("int8", None) => {
                        int8_trainer::evaluate_int8(&int8_pre[di], &ft_test, 32).1
                    }
                    ("int8", Some(method)) => {
                        let mut ws = int8_pre[di].clone();
                        let ispec = TrainSpec {
                            method,
                            precision: PrecisionSpec::int8(ZoGradMode::FloatCE),
                            epochs: scale.ft_epochs(),
                            batch: 32,
                            seed: 91 + ci as u64,
                            ..Default::default()
                        };
                        let r = int8_trainer::train_int8(&mut ws, &ft_train, &ft_test, &ispec)?;
                        r.history.best_test_acc()
                    }
                    _ => unreachable!(),
                };
                cells.push(format!("{:.2}", acc * 100.0));
                let _ = &mut accs_json;
            }
        }
        println!("  [{label}] done");
        table.row(&cells);
        json_rows.push(Value::obj(vec![
            ("method", Value::str(label)),
            (
                "cells",
                Value::Arr(cells[1..].iter().map(|c| Value::str(c.clone())).collect()),
            ),
        ]));
        let _ = accs_json;
    }

    table.print();
    dump_result("table2", &Value::obj(vec![("rows", Value::Arr(json_rows))]))?;
    Ok(())
}
