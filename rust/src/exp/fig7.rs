//! Fig. 7: execution-time breakdown of the native engine (the paper's
//! C++-on-RasPi counterpart): per-phase fractions for Full ZO /
//! ZO-Feat-Cls2 / ZO-Feat-Cls1, FP32 (left) and INT8 (right).
//!
//! Shape checks (paper §5.4): forward passes dominate (84–97%); BP tail
//! is negligible (<2.5%); INT8 runs ~1.4× faster per epoch than FP32;
//! ZO perturb+update is a visible slice in FP32 (~12%) but ~1% in INT8.

use super::{dump_result, Scale};
use crate::coordinator::engine::Method;
use crate::coordinator::int8_trainer::{self, ZoGradMode};
use crate::coordinator::native_engine::NativeEngine;
use crate::coordinator::session::{PrecisionSpec, TrainSpec};
use crate::coordinator::trainer;
use crate::coordinator::{Model, ParamSet};
use crate::data::{self, DatasetKind};
use crate::int8::lenet8;
use crate::telemetry::{Phase, PhaseTimer};
use crate::util::json::Value;
use crate::util::table::{pct, Table};
use anyhow::Result;

fn breakdown_cells(label: &str, timer: &PhaseTimer, seconds: f64) -> Vec<String> {
    let frac = |p: Phase| pct(timer.total(p).as_secs_f64() / timer.grand_total().as_secs_f64());
    vec![
        label.to_string(),
        format!("{seconds:.2}s"),
        frac(Phase::Forward),
        frac(Phase::ZoPerturb),
        frac(Phase::ZoUpdate),
        frac(Phase::BpBackward),
        frac(Phase::Loss),
        frac(Phase::Eval),
    ]
}

pub fn run(scale: Scale) -> Result<()> {
    let epochs = match scale {
        Scale::Fast => 1,
        _ => 2,
    };
    let n = scale.train_n().min(1024);
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, n, 128, 7, 0);

    let header = ["method", "epoch time", "Forward", "ZO Perturb", "ZO Update",
                  "BP", "Loss", "Eval"];
    let mut json_out: Vec<Value> = Vec::new();

    // ---- FP32 (native engine) --------------------------------------
    let mut t = Table::new("Fig 7 (left): FP32 native-engine time breakdown", &header);
    let mut fp32_epoch_secs = 0.0;
    for method in [Method::FULL_ZO, Method::CLS2, Method::CLS1] {
        let mut engine = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 1);
        let spec = TrainSpec { method, epochs, batch: 32, ..Default::default() };
        let r = trainer::train(&mut engine, &mut params, &train_d, &test_d, &spec)?;
        let secs: f64 = r.history.epochs.iter().map(|e| e.seconds).sum::<f64>()
            / r.history.epochs.len() as f64;
        if method == Method::FULL_ZO {
            fp32_epoch_secs = secs;
        }
        t.row(&breakdown_cells(&method.label(), &r.timer, secs));
        json_out.push(Value::obj(vec![
            ("precision", Value::str("fp32")),
            ("method", Value::str(method.label())),
            ("epoch_seconds", Value::num(secs)),
            ("forward_frac", Value::num(
                r.timer.total(Phase::Forward).as_secs_f64()
                    / r.timer.grand_total().as_secs_f64(),
            )),
        ]));
    }
    t.print();

    // ---- INT8 (native NITI engine) ---------------------------------
    let mut t = Table::new("Fig 7 (right): INT8 native-engine time breakdown", &header);
    let mut int8_epoch_secs = 0.0;
    for method in [Method::FULL_ZO, Method::CLS2, Method::CLS1] {
        let mut ws = lenet8::init_params(2, 32);
        let spec = TrainSpec {
            method,
            precision: PrecisionSpec::int8(ZoGradMode::IntCE),
            epochs,
            batch: 32,
            ..Default::default()
        };
        let r = int8_trainer::train_int8(&mut ws, &train_d, &test_d, &spec)?;
        let secs: f64 = r.history.epochs.iter().map(|e| e.seconds).sum::<f64>()
            / r.history.epochs.len() as f64;
        if method == Method::FULL_ZO {
            int8_epoch_secs = secs;
        }
        t.row(&breakdown_cells(&method.label(), &r.timer, secs));
        json_out.push(Value::obj(vec![
            ("precision", Value::str("int8")),
            ("method", Value::str(method.label())),
            ("epoch_seconds", Value::num(secs)),
        ]));
    }
    t.print();

    if int8_epoch_secs > 0.0 {
        println!(
            "   FP32/INT8 epoch-time ratio (Full ZO): {:.2}x (paper: 1.38-1.42x)",
            fp32_epoch_secs / int8_epoch_secs
        );
        json_out.push(Value::obj(vec![(
            "fp32_over_int8_epoch_time",
            Value::num(fp32_epoch_secs / int8_epoch_secs),
        )]));
    }
    dump_result("fig7", &Value::Arr(json_out))
}
