//! Pseudo-random substrate for the MeZO seed trick.
//!
//! ZO training regenerates the SAME perturbation vector `z` four times
//! per step (perturb +ε, perturb −2ε, restore +ε, update −ηg·z) from a
//! stored 8-byte seed instead of materializing `z` (paper §3.2). This
//! module provides the deterministic streams that make that exact replay
//! possible: [`Rng64`] (splitmix64-seeded xoshiro256**), Gaussian
//! sampling via Box–Muller for FP32 perturbations, and the
//! uniform-int8 + Bernoulli-mask sparse perturbations of ElasticZO-INT8
//! (paper Alg. 2 lines 15–16).

/// xoshiro256** seeded through splitmix64 — fast, high-quality, and
/// fully deterministic across platforms (no libc rand, no HW entropy).
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    pub fn new(seed: u64) -> Rng64 {
        // splitmix64 to spread a small seed over the full state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng64 { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn uniform_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second half is deliberately dropped to keep the stream position
    /// a pure function of the call count — essential for seed replay).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                let theta = 2.0 * std::f64::consts::PI * u2 as f64;
                return (r * theta.cos()) as f32;
            }
        }
    }

    /// Bernoulli(p) sample.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Fill `out` with N(0, I) — the FP32 perturbation z (paper Eq. 1).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.normal();
        }
    }

    /// One sparse INT8 perturbation entry: Bernoulli(1−p_zero) mask ⊙
    /// U(−r_max, r_max) (paper Alg. 2 line 15–16).
    #[inline]
    pub fn sparse_i8(&mut self, r_max: i8, p_zero: f32) -> i8 {
        // Draw the uniform FIRST so the stream advances identically
        // regardless of the mask outcome (replay safety).
        let u = self.uniform_i32(-(r_max as i32), r_max as i32) as i8;
        let keep = !self.bernoulli(p_zero);
        if keep {
            u
        } else {
            0
        }
    }

    /// Kaiming-uniform fill for layer init: U(−b, b), b = sqrt(6/fan_in).
    pub fn fill_kaiming_uniform(&mut self, out: &mut [f32], fan_in: usize) {
        let bound = (6.0 / fan_in.max(1) as f32).sqrt();
        for v in out {
            *v = (self.uniform() * 2.0 - 1.0) * bound;
        }
    }

    /// Shuffle indices in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }
}

/// A per-step ZO perturbation stream: the seed-trick object.
///
/// All four replays within one training step construct a `ZoStream`
/// from the same `(run_seed, step)` pair and therefore observe the
/// identical `z` sequence. Box–Muller produces values in PAIRS
/// (cos & sin); caching the spare halves the transcendental work per
/// element — replay-safe because every phase rebuilds the stream and
/// replays the same call count (EXPERIMENTS.md §Perf, L3 iteration 3).
#[derive(Debug, Clone)]
pub struct ZoStream {
    rng: Rng64,
    spare: Option<f32>,
}

impl ZoStream {
    pub fn for_step(run_seed: u64, step: u64) -> ZoStream {
        // Mix run seed and step index into one 64-bit stream id.
        let seed = run_seed ^ step.wrapping_mul(0xA076_1D64_78BD_642F) ^ 0x5EED_2E10;
        ZoStream { rng: Rng64::new(seed), spare: None }
    }

    /// Next Gaussian z entry (FP32 path).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.rng.uniform();
            if u1 > 1e-12 {
                let u2 = self.rng.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                let (s, c) = (2.0 * std::f64::consts::PI * u2 as f64).sin_cos();
                self.spare = Some((r * s) as f32);
                return (r * c) as f32;
            }
        }
    }

    /// Next sparse int8 z entry (INT8 path).
    #[inline]
    pub fn sparse_i8(&mut self, r_max: i8, p_zero: f32) -> i8 {
        self.rng.sparse_i8(r_max, p_zero)
    }

    /// Drain the raw Box–Muller uniforms for `npairs` Gaussian pairs in
    /// one pass — the rejection-sampling phase of [`ZoStream::normal`]
    /// split off from the transcendental phase, so a caller can evaluate
    /// the ln/sin_cos work out of stream order (the chunked/parallel
    /// fill in `coordinator::kernels`). Each `(u1, u2)` entry maps to
    /// the `(r·cosθ, r·sinθ)` pair two consecutive `normal()` calls
    /// would return; the rejection loop is replayed exactly, so the
    /// stream position after this call equals `2·npairs` `normal()`
    /// calls on a fresh stream. Must be called on a freshly built
    /// stream (no cached spare half).
    pub fn raw_pairs(&mut self, npairs: usize, out: &mut Vec<(f32, f32)>) {
        debug_assert!(self.spare.is_none(), "raw_pairs requires a fresh ZoStream");
        out.clear();
        out.reserve(npairs);
        for _ in 0..npairs {
            loop {
                let u1 = self.rng.uniform();
                if u1 > 1e-12 {
                    out.push((u1, self.rng.uniform()));
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = {
            let mut r = Rng64::new(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng64::new(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn uniform_i32_bounds_and_coverage() {
        let mut r = Rng64::new(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.uniform_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            seen[(v + 3) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sparse_i8_zero_fraction_tracks_p() {
        let mut r = Rng64::new(17);
        let n = 50_000;
        let zeros = (0..n).filter(|_| r.sparse_i8(31, 0.9) == 0).count();
        let frac = zeros as f64 / n as f64;
        // p_zero=0.9 plus the ~1/63 chance u==0 itself.
        assert!((frac - 0.9).abs() < 0.02, "zero frac {frac}");
    }

    #[test]
    fn sparse_i8_stream_position_is_mask_independent() {
        // Two streams with different p_zero must consume the same number
        // of raw draws per entry — verified by checking that after N
        // entries both underlying RNGs produce the same next_u64.
        let mut a = Rng64::new(23);
        let mut b = Rng64::new(23);
        for _ in 0..1000 {
            let _ = a.sparse_i8(31, 0.0);
            let _ = b.sparse_i8(31, 1.0);
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zo_stream_replay_exact() {
        let mut s1 = ZoStream::for_step(99, 1234);
        let z1: Vec<f32> = (0..512).map(|_| s1.normal()).collect();
        let mut s2 = ZoStream::for_step(99, 1234);
        let z2: Vec<f32> = (0..512).map(|_| s2.normal()).collect();
        assert_eq!(z1, z2); // bitwise identical
    }

    #[test]
    fn raw_pairs_transform_matches_normal_bitwise() {
        // raw_pairs + the Box–Muller transform must reproduce normal()'s
        // exact bits: same draws, same f64 math, same truncation.
        let mut reference = ZoStream::for_step(21, 77);
        let want: Vec<u32> = (0..257).map(|_| reference.normal().to_bits()).collect();
        let mut raw = Vec::new();
        ZoStream::for_step(21, 77).raw_pairs(129, &mut raw);
        let mut got = Vec::with_capacity(258);
        for &(u1, u2) in &raw {
            let r = (-2.0 * (u1 as f64).ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2 as f64).sin_cos();
            got.push(((r * c) as f32).to_bits());
            got.push(((r * s) as f32).to_bits());
        }
        assert_eq!(&got[..257], &want[..], "odd tail drops the spare half only");
    }

    #[test]
    fn zo_stream_steps_decorrelated() {
        let mut s1 = ZoStream::for_step(99, 1);
        let mut s2 = ZoStream::for_step(99, 2);
        let a: Vec<i32> = (0..64).map(|_| (s1.normal() * 1000.0) as i32).collect();
        let b: Vec<i32> = (0..64).map(|_| (s2.normal() * 1000.0) as i32).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn kaiming_bound() {
        let mut r = Rng64::new(5);
        let mut buf = vec![0.0f32; 4096];
        r.fill_kaiming_uniform(&mut buf, 100);
        let bound = (6.0f32 / 100.0).sqrt();
        assert!(buf.iter().all(|v| v.abs() <= bound));
        assert!(buf.iter().any(|v| v.abs() > bound * 0.5));
    }
}
