//! XLA engine: `Engine` implemented over the AOT artifacts (JAX/Pallas
//! → HLO text → PJRT). This is the request-path configuration: python
//! authored the computation once at build time; every call here is pure
//! rust → PJRT.

use super::engine::{Engine, StepOut};
use super::params::{Model, ParamSet};
use crate::nn::{Forward, TailGrads};
use crate::runtime::{ArgValue, Registry};
use anyhow::{bail, Context, Result};

pub struct XlaEngine {
    registry: Registry,
    model: Model,
    /// The static batch size baked into the artifacts being used.
    bsz: usize,
    fwd_name: String,
    tail1_name: String,
    tail2_name: String,
    step_name: String,
}

impl XlaEngine {
    pub fn new(registry: Registry, model: Model, bsz: usize) -> Result<XlaEngine> {
        // Forward default: the `_fast` reference-ops lowering (same math,
        // XLA-fused; see DESIGN.md §9). REPRO_PALLAS_FWD=1 forces the
        // Pallas-kernel lowering (interpret-mode — slow on CPU PJRT, the
        // TPU-shaped path) for parity checks.
        let pallas_fwd = std::env::var("REPRO_PALLAS_FWD").is_ok();
        let (fwd_name, tail1_name, tail2_name, step_name) = match model {
            Model::LeNet => (
                if pallas_fwd {
                    format!("lenet_fwd_b{bsz}")
                } else {
                    format!("lenet_fwd_fast_b{bsz}")
                },
                format!("lenet_tail_c1_b{bsz}"),
                format!("lenet_tail_c2_b{bsz}"),
                format!("lenet_step_b{bsz}"),
            ),
            Model::PointNet { npoints, .. } => (
                if pallas_fwd {
                    format!("pointnet_fwd_n{npoints}_b{bsz}")
                } else {
                    format!("pointnet_fwd_fast_n{npoints}_b{bsz}")
                },
                format!("pointnet_tail_c1_n{npoints}_b{bsz}"),
                format!("pointnet_tail_c2_n{npoints}_b{bsz}"),
                format!("pointnet_step_n{npoints}_b{bsz}"),
            ),
        };
        let mut eng = XlaEngine {
            registry,
            model,
            bsz,
            fwd_name,
            tail1_name,
            tail2_name,
            step_name,
        };
        // Fail fast (and pre-compile) if the artifact set is missing.
        eng.registry
            .get(&eng.fwd_name.clone())
            .with_context(|| format!("artifact for model {model:?} batch {bsz}"))?;
        Ok(eng)
    }

    pub fn open_default(model: Model, bsz: usize) -> Result<XlaEngine> {
        XlaEngine::new(Registry::open_default()?, model, bsz)
    }

    fn check_bsz(&self, bsz: usize) -> Result<()> {
        if bsz != self.bsz {
            bail!(
                "XLA engine compiled for batch {}, called with {bsz} \
                 (artifacts have static shapes)",
                self.bsz
            );
        }
        Ok(())
    }

    /// Tail-grad tensor indices for this model (ABI positions): the
    /// last `k` (weight, bias) pairs.
    fn tail_indices(&self, k: usize) -> Vec<usize> {
        let n = self.model.param_specs().len();
        (n.saturating_sub(2 * k)..n).collect()
    }
}

impl Engine for XlaEngine {
    fn forward(&mut self, params: &ParamSet, x: &[f32], y: &[f32], bsz: usize) -> Result<Forward> {
        self.check_bsz(bsz)?;
        let name = self.fwd_name.clone();
        let exe = self.registry.get(&name)?;
        let mut args: Vec<ArgValue> = params.data.iter().map(|p| ArgValue::F32(p)).collect();
        args.push(ArgValue::F32(x));
        args.push(ArgValue::F32(y));
        let out = exe.run(&args)?;
        Ok(Forward {
            loss: out[0].scalar_f32()?,
            logits: out[1].as_f32()?.to_vec(),
            // AOT artifacts only expose the two classic partition
            // activations; tails deeper than 2 need engine=native
            act_c3: Vec::new(),
            act_c2: out[2].as_f32()?.to_vec(),
            act_c1: out[3].as_f32()?.to_vec(),
        })
    }

    fn tail_grads(
        &mut self,
        params: &ParamSet,
        fwd: &Forward,
        y: &[f32],
        k: usize,
        bsz: usize,
    ) -> Result<TailGrads> {
        self.check_bsz(bsz)?;
        let idxs = self.tail_indices(k);
        let name = match k {
            1 => self.tail1_name.clone(),
            2 => self.tail2_name.clone(),
            _ => bail!(
                "the XLA artifact set has no bp-tail={k} program; \
                 deeper tails require engine=native"
            ),
        };
        let exe = self.registry.get(&name)?;
        // ABI: partition activation, then the BP'd params in order
        // (c1 -> w,b of the last layer; c2 -> w,b,w,b of the last two),
        // then the one-hot labels.
        let mut args: Vec<ArgValue> = Vec::new();
        let act = if k == 1 { &fwd.act_c1 } else { &fwd.act_c2 };
        args.push(ArgValue::F32(act));
        for &i in &idxs {
            args.push(ArgValue::F32(&params.data[i]));
        }
        args.push(ArgValue::F32(y));
        let out = exe.run(&args)?;
        Ok(idxs
            .into_iter()
            .zip(out)
            .map(|(i, o)| Ok((i, o.as_f32()?.to_vec())))
            .collect::<Result<Vec<_>>>()?)
    }

    fn full_step(
        &mut self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        bsz: usize,
        lr: f32,
    ) -> Result<StepOut> {
        self.check_bsz(bsz)?;
        let name = self.step_name.clone();
        let exe = self.registry.get(&name)?;
        let lr_arr = [lr];
        let mut args: Vec<ArgValue> = params.data.iter().map(|p| ArgValue::F32(p)).collect();
        args.push(ArgValue::F32(x));
        args.push(ArgValue::F32(y));
        args.push(ArgValue::F32(&lr_arr));
        let out = exe.run(&args)?;
        let n = params.num_tensors();
        for (i, o) in out[..n].iter().enumerate() {
            params.data[i].copy_from_slice(o.as_f32()?);
        }
        let loss = out[n].scalar_f32()?;
        // Step artifacts compiled by the current python pipeline emit
        // the pre-step logits after the loss; older artifact sets stop
        // at the loss, in which case Full-BP train accuracy is simply
        // unreported (never wrong).
        let logits = match out.get(n + 1) {
            Some(o) => Some(o.as_f32()?.to_vec()),
            None => None,
        };
        Ok(StepOut { loss, logits })
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
