//! Fast ZO kernels: the chunked, autovectorization-friendly hot path
//! behind `zo::perturb` / the int8 perturb/update, plus the per-step
//! perturbation caches that let one `z` generation serve every leg of a
//! step.
//!
//! Everything here is **bit-identical to the scalar reference** (the
//! naive loops in [`super::zo`] and [`super::int8_trainer`]) — that is
//! the contract `tests/zo_kernel_parity.rs` locks down. Three facts make
//! it possible:
//!
//! 1. **Two-phase Gaussian fill.** `ZoStream::normal` interleaves a
//!    serial rejection-sampled uniform draw with a pure per-pair
//!    transcendental transform. [`ZoStream::raw_pairs`] drains the
//!    (inherently serial) raw draws in one tight pass; [`fill_z`] then
//!    applies the exact Box–Muller float expressions per pair — an
//!    embarrassingly parallel phase that scoped worker threads split in
//!    fixed chunks without moving a single bit.
//! 2. **Per-step replay = one generation.** Within a step every leg
//!    (+ε, −2ε, +ε−ηg / +1, −2, +1, update) replays the SAME `z(seed,
//!    step)`. [`StepZ`]/[`StepZi8`] generate it once and the apply
//!    kernels ([`apply_z`], [`apply_z_i8`], [`zo_update_z_i8`]) replay
//!    the cached copy with the identical per-element mul-then-add the
//!    scalar path performs. The cost is one ZO-prefix-sized buffer
//!    (~0.4 MB fp32 LeNet, ~107 KB int8) — the memory/speed trade is
//!    reverted by `--kernels false`.
//! 3. **Forwards are pure.** Engines never mutate params in `forward`,
//!    so the ±ε pair (and dp shard evals) can run on scoped threads with
//!    unchanged results; only wall-clock moves.
//!
//! The optional structured-perturbation mask ([`mask_blocks`]) is the
//! ONE intentional divergence: it zeroes whole per-layer blocks of `z`
//! after generation, drawing the block decisions from a separate salted
//! stream so the Gaussian stream position never shifts. Off by default
//! (`TrainSpec::sparse_block == 0`).

use super::params::ParamSet;
use crate::int8::layers;
use crate::int8::qtensor::QTensor;
use crate::int8::rounding::clamp_i8;
use crate::rng::{Rng64, ZoStream};
use crate::tensor::ops;
use std::sync::OnceLock;

/// Below this many Box–Muller pairs per worker the spawn overhead beats
/// the transcendental savings and [`fill_z`] stays single-threaded.
const MIN_PAIRS_PER_THREAD: usize = 16 * 1024;

/// Salt for the structured-perturbation mask stream: the block decisions
/// come from `Rng64(seed ^ step·MIX ^ SPARSE_SALT)`, a stream disjoint
/// from the Gaussian draws, so masking cannot shift `z` positions.
const SPARSE_SALT: u64 = 0x5AB5_EB10_0000_B10C;

/// Worker threads available to the kernels. Resolved once per process:
/// the `REPRO_KERNEL_THREADS` env var when set (parity tests force >1
/// on single-core CI runners; `1` forces the sequential paths), else
/// the machine's available parallelism.
pub fn hw_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        if let Some(n) = std::env::var("REPRO_KERNEL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            return n.max(1);
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Fill `out` with the exact `z(seed, step)` sequence the scalar
/// `ZoStream` produces — raw draws serial, Box–Muller transform chunked
/// across scoped threads when the buffer is large enough to pay for
/// them. An odd length drops the final pair's sin half, exactly like a
/// scalar phase that rebuilds the stream afterwards.
pub fn fill_z(seed: u64, step: u64, out: &mut [f32]) {
    if out.is_empty() {
        return;
    }
    let npairs = out.len().div_ceil(2);
    let mut raw: Vec<(f32, f32)> = Vec::new();
    ZoStream::for_step(seed, step).raw_pairs(npairs, &mut raw);
    let threads = (npairs / MIN_PAIRS_PER_THREAD).clamp(1, hw_threads());
    if threads <= 1 {
        pairs_to_z(&raw, out);
        return;
    }
    let per = npairs.div_ceil(threads);
    std::thread::scope(|sc| {
        let mut rest = out;
        let mut start = 0usize;
        while start < npairs {
            let take = per.min(npairs - start);
            let elems = (2 * take).min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(elems);
            let chunk = &raw[start..start + take];
            sc.spawn(move || pairs_to_z(chunk, head));
            rest = tail;
            start += take;
        }
    });
}

/// The pure phase of Box–Muller, per pair — float expressions copied
/// verbatim from `ZoStream::normal` so the bits cannot differ.
fn pairs_to_z(raw: &[(f32, f32)], out: &mut [f32]) {
    for (i, &(u1, u2)) in raw.iter().enumerate() {
        let r = (-2.0 * (u1 as f64).ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2 as f64).sin_cos();
        out[2 * i] = (r * c) as f32;
        if let Some(v) = out.get_mut(2 * i + 1) {
            *v = (r * s) as f32;
        }
    }
}

/// θ[0..boundary] += scale · z over a cached perturbation — the replay
/// half of `zo::perturb`, per-tensor chunked saxpy instead of per-call
/// RNG regeneration. Identical mul-then-add per element.
pub fn apply_z(params: &mut ParamSet, boundary: usize, scale: f32, z: &[f32]) {
    let mut off = 0usize;
    for tensor in &mut params.data[..boundary] {
        let n = tensor.len();
        ops::axpy(scale, &z[off..off + n], tensor);
        off += n;
    }
    debug_assert_eq!(off, z.len(), "z cache length must match the ZO prefix");
}

/// Per-layer block mask description for the structured perturbation.
pub struct SparseMask<'a> {
    /// Element count of each ZO-prefix tensor, in ABI order.
    pub layout: &'a [usize],
    /// Block width in elements (the flag's value; > 0).
    pub block: usize,
    /// Fraction of blocks kept, in (0, 1].
    pub keep: f32,
}

/// Zero dropped blocks of `z` in place. One Bernoulli draw per block
/// from the salted mask stream — drawn unconditionally so the stream
/// position is a pure function of the layout, never of the outcomes.
/// Blocks never span tensors (the remainder of each tensor is its own
/// short block).
pub fn mask_blocks(z: &mut [f32], layout: &[usize], seed: u64, step: u64, block: usize, keep: f32) {
    let mut rng = Rng64::new(seed ^ step.wrapping_mul(0xA076_1D64_78BD_642F) ^ SPARSE_SALT);
    let mut off = 0usize;
    for &n in layout {
        for chunk in z[off..off + n].chunks_mut(block) {
            let keep_block = rng.uniform() < keep;
            if !keep_block {
                chunk.fill(0.0);
            }
        }
        off += n;
    }
    debug_assert_eq!(off, z.len());
}

/// One fp32 step's cached perturbation: `z(seed, step)` is generated
/// once and replayed by every [`apply_z`] leg. `prepare` is idempotent
/// per `(seed, step)` so each leg can call it defensively.
#[derive(Debug, Default)]
pub struct StepZ {
    key: Option<(u64, u64)>,
    z: Vec<f32>,
}

impl StepZ {
    pub fn new() -> StepZ {
        StepZ::default()
    }

    /// Ensure the cache holds `z(seed, step)` over `n` elements,
    /// regenerating (and optionally masking) only on a step change.
    pub fn prepare(&mut self, seed: u64, step: u64, n: usize, sparse: Option<SparseMask<'_>>) {
        if self.key == Some((seed, step)) && self.z.len() == n {
            return;
        }
        self.z.resize(n, 0.0);
        fill_z(seed, step, &mut self.z);
        if let Some(m) = sparse {
            mask_blocks(&mut self.z, m.layout, seed, step, m.block, m.keep);
        }
        self.key = Some((seed, step));
    }

    pub fn z(&self) -> &[f32] {
        &self.z
    }
}

/// Fill `out` with the exact sparse-int8 `z(seed, step)` sequence of
/// `perturb_int8` (paper Alg. 2 lines 15–16). The draws are two cheap
/// uniforms per element — no transcendental phase to parallelize; the
/// win is generating them once per step instead of four times.
pub fn fill_z_i8(seed: u64, step: u64, r_max: i8, p_zero: f32, out: &mut [i8]) {
    let mut stream = ZoStream::for_step(seed, step);
    for v in out {
        *v = stream.sparse_i8(r_max, p_zero);
    }
}

/// θ ← clamp(θ + k·z) over the first `n_zo` tensors from a cached int8
/// perturbation — the replay half of `perturb_int8`, integer-only.
pub fn apply_z_i8(ws: &mut [QTensor], n_zo: usize, k: i32, z: &[i8]) {
    let mut off = 0usize;
    for w in &mut ws[..n_zo] {
        let n = w.numel();
        w.clamp_add_scaled(&z[off..off + n], k);
        off += n;
    }
    debug_assert_eq!(off, z.len(), "z cache length must match the ZO prefix");
}

/// θ ← clamp(θ − PseudoStochasticRound(g·z, b_ZO)) from a cached int8
/// perturbation — `zo_update_int8` without the stream regeneration.
/// `acc`/`upd` are caller-owned scratch buffers (per-tensor i32
/// accumulator and rounded update) so the hot loop never allocates.
/// The rounding shift is per tensor, exactly like the reference.
pub fn zo_update_z_i8(
    ws: &mut [QTensor],
    n_zo: usize,
    g: i32,
    b_zo: u32,
    z: &[i8],
    acc: &mut Vec<i32>,
    upd: &mut Vec<i8>,
) {
    if g == 0 {
        return;
    }
    let mut off = 0usize;
    for w in &mut ws[..n_zo] {
        let n = w.numel();
        acc.clear();
        acc.extend(z[off..off + n].iter().map(|&zv| g * zv as i32));
        layers::round_update_into(acc, b_zo, upd);
        for (v, &uv) in w.data.iter_mut().zip(upd.iter()) {
            *v = clamp_i8(*v as i32 - uv as i32);
        }
        off += n;
    }
    debug_assert_eq!(off, z.len(), "z cache length must match the ZO prefix");
}

/// One int8 step's cached sparse perturbation — the [`StepZ`] of the
/// Alg. 2 path. The `(seed, step)` key is safe against the staged
/// p_zero schedule because the global step counter never repeats.
#[derive(Debug, Default)]
pub struct StepZi8 {
    key: Option<(u64, u64)>,
    z: Vec<i8>,
}

impl StepZi8 {
    pub fn new() -> StepZi8 {
        StepZi8::default()
    }

    /// Ensure the cache holds the step's `z`, regenerating only on a
    /// step change.
    pub fn prepare(&mut self, seed: u64, step: u64, n: usize, r_max: i8, p_zero: f32) {
        if self.key == Some((seed, step)) && self.z.len() == n {
            return;
        }
        self.z.resize(n, 0);
        fill_z_i8(seed, step, r_max, p_zero, &mut self.z);
        self.key = Some((seed, step));
    }

    pub fn z(&self) -> &[i8] {
        &self.z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::Model;
    use crate::coordinator::zo;
    use crate::int8::lenet8;

    fn scalar_z(seed: u64, step: u64, n: usize) -> Vec<f32> {
        let mut s = ZoStream::for_step(seed, step);
        (0..n).map(|_| s.normal()).collect()
    }

    #[test]
    fn fill_z_matches_scalar_stream_bitwise() {
        // cover empty, tiny, odd, even and chunk-boundary lengths
        for n in [0usize, 1, 2, 3, 17, 256, 1023, 4096] {
            let mut out = vec![0.0f32; n];
            fill_z(5, 99, &mut out);
            let want = scalar_z(5, 99, n);
            let got_bits: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "n={n}");
        }
    }

    #[test]
    fn apply_z_equals_scalar_perturb() {
        let mut a = ParamSet::init(Model::LeNet, 3);
        let mut b = a.clone();
        let boundary = a.zo_boundary(1);
        let n: usize = a.data[..boundary].iter().map(|t| t.len()).sum();
        let mut z = vec![0.0f32; n];
        fill_z(7, 42, &mut z);
        apply_z(&mut a, boundary, 1e-3, &z);
        zo::perturb(&mut b, boundary, 7, 42, 1e-3);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn step_z_caches_until_step_changes() {
        let mut kz = StepZ::new();
        kz.prepare(1, 10, 64, None);
        let first = kz.z().to_vec();
        kz.prepare(1, 10, 64, None); // no-op replay
        assert_eq!(kz.z(), &first[..]);
        kz.prepare(1, 11, 64, None);
        assert_ne!(kz.z(), &first[..]);
        assert_eq!(kz.z(), &scalar_z(1, 11, 64)[..]);
    }

    #[test]
    fn mask_blocks_zeroes_roughly_keep_fraction_and_is_deterministic() {
        let layout = [4000usize, 2048, 100];
        let n: usize = layout.iter().sum();
        let mut z = vec![1.0f32; n];
        mask_blocks(&mut z, &layout, 9, 3, 64, 0.25);
        let kept = z.iter().filter(|v| **v != 0.0).count() as f64 / n as f64;
        assert!((kept - 0.25).abs() < 0.1, "kept fraction {kept}");
        let mut z2 = vec![1.0f32; n];
        mask_blocks(&mut z2, &layout, 9, 3, 64, 0.25);
        assert_eq!(z, z2, "same (seed, step) must mask identically");
        // the mask stream is independent of the Gaussian stream
        let mut z3 = vec![1.0f32; n];
        mask_blocks(&mut z3, &layout, 9, 4, 64, 0.25);
        assert_ne!(z, z3, "different steps mask differently");
    }

    #[test]
    fn mask_blocks_never_spans_tensors() {
        // with keep=0 everything zeroes; with per-tensor layouts smaller
        // than the block, each tensor still gets its own draw — verified
        // by comparing against a manual per-tensor walk
        let layout = [10usize, 3, 7];
        let mut z = vec![1.0f32; 20];
        mask_blocks(&mut z, &layout, 2, 2, 8, 0.5);
        let mut rng = Rng64::new(2 ^ 2u64.wrapping_mul(0xA076_1D64_78BD_642F) ^ SPARSE_SALT);
        let mut want = vec![1.0f32; 20];
        let mut off = 0;
        for &n in &layout {
            for chunk in want[off..off + n].chunks_mut(8) {
                if rng.uniform() >= 0.5 {
                    chunk.fill(0.0);
                }
            }
            off += n;
        }
        assert_eq!(z, want);
    }

    #[test]
    fn int8_kernels_match_scalar_reference() {
        use crate::coordinator::int8_trainer::{perturb_int8, zo_update_int8};
        let n_zo = 4;
        let mut a = lenet8::init_params(11, 32);
        let mut b = a.clone();
        let n: usize = a[..n_zo].iter().map(|w| w.numel()).sum();
        let mut kz = StepZi8::new();
        kz.prepare(5, 13, n, 15, 0.5);

        apply_z_i8(&mut a, n_zo, 1, kz.z());
        perturb_int8(&mut b, n_zo, 5, 13, 1, 15, 0.5);
        assert_eq!(a, b, "perturb +1");
        apply_z_i8(&mut a, n_zo, -2, kz.z());
        perturb_int8(&mut b, n_zo, 5, 13, -2, 15, 0.5);
        assert_eq!(a, b, "perturb -2");
        apply_z_i8(&mut a, n_zo, 1, kz.z());
        perturb_int8(&mut b, n_zo, 5, 13, 1, 15, 0.5);
        assert_eq!(a, b, "restore +1");

        let (mut acc, mut upd) = (Vec::new(), Vec::new());
        for g in [-1i32, 0, 1] {
            zo_update_z_i8(&mut a, n_zo, g, 1, kz.z(), &mut acc, &mut upd);
            zo_update_int8(&mut b, n_zo, 5, 13, g, 1, 15, 0.5);
            assert_eq!(a, b, "update g={g}");
        }
    }
}
