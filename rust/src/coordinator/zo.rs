//! The ZO engine: seed-trick perturbation and update, in place, over the
//! ZO-trained prefix of a [`ParamSet`] (paper Alg. 1 lines 12–21).
//!
//! Every call regenerates the SAME Gaussian stream from `(run_seed,
//! step)`, so `z` is never stored — the MeZO memory trick. One step
//! makes four passes over the ZO parameters:
//!
//!   perturb(+ε) → forward(ℓ₊) → perturb(−2ε) → forward(ℓ₋)
//!   → perturb(ε − η·g)   [merged restore + update, as the paper notes]

use super::params::ParamSet;
use crate::rng::ZoStream;

/// θ[0..boundary] += scale · z, with z regenerated from (run_seed, step).
pub fn perturb(params: &mut ParamSet, boundary: usize, run_seed: u64, step: u64, scale: f32) {
    let mut stream = ZoStream::for_step(run_seed, step);
    for tensor in &mut params.data[..boundary] {
        for v in tensor.iter_mut() {
            *v += scale * stream.normal();
        }
    }
}

/// The projected-gradient scalar g = (ℓ₊ − ℓ₋)/2ε, clipped (paper §5.1.1).
pub fn projected_gradient(loss_plus: f32, loss_minus: f32, eps: f32, g_clip: f32) -> f32 {
    let g = (loss_plus - loss_minus) / (2.0 * eps);
    g.clamp(-g_clip, g_clip)
}

/// Data-parallel variant of [`projected_gradient`]: the replicas ship
/// per-shard ℓ₊ − ℓ₋ deltas and the coordinator aggregates them into a
/// single scalar before projecting, so the two losses never exist
/// individually here.
pub fn projected_gradient_from_delta(delta: f32, eps: f32, g_clip: f32) -> f32 {
    (delta / (2.0 * eps)).clamp(-g_clip, g_clip)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::Model;

    #[test]
    fn perturb_restore_roundtrip_exact_stream() {
        // +ε then −ε with the same (seed, step) must reproduce the
        // original parameters to f32 rounding (the same z is re-added).
        let mut p = ParamSet::init(Model::LeNet, 3);
        let orig = p.clone();
        let b = p.zo_boundary(1);
        perturb(&mut p, b, 7, 42, 1e-3);
        perturb(&mut p, b, 7, 42, -1e-3);
        for (t, (a, o)) in p.data.iter().zip(&orig.data).enumerate() {
            for (x, y) in a.iter().zip(o) {
                assert!((x - y).abs() <= 2.0 * f32::EPSILON * (1.0 + y.abs()), "tensor {t}");
            }
        }
    }

    #[test]
    fn mezo_three_phase_replay() {
        // the actual training sequence: +ε, −2ε, +ε  → back to start
        let mut p = ParamSet::init(Model::LeNet, 4);
        let orig = p.clone();
        let b = p.zo_boundary(0);
        let eps = 1e-3;
        perturb(&mut p, b, 9, 100, eps);
        perturb(&mut p, b, 9, 100, -2.0 * eps);
        perturb(&mut p, b, 9, 100, eps);
        for (a, o) in p.data.iter().flatten().zip(orig.data.iter().flatten()) {
            assert!((a - o).abs() <= 4.0 * f32::EPSILON * (1.0 + o.abs()));
        }
    }

    #[test]
    fn bp_suffix_untouched() {
        // perturb exactly the ZO prefix of the Cls1 partition; the four
        // BP-trained suffix tensors (two FC layers × w,b) must not move
        let mut p = ParamSet::init(Model::LeNet, 5);
        let orig = p.clone();
        let b = p.zo_boundary(2);
        assert_eq!(b, p.num_tensors() - 4);
        perturb(&mut p, b, 1, 1, 0.5);
        for i in b..p.num_tensors() {
            assert_eq!(p.data[i], orig.data[i], "tensor {i} must be untouched");
        }
        for i in 0..b {
            assert_ne!(p.data[i], orig.data[i], "tensor {i} must be perturbed");
        }
    }

    #[test]
    fn different_steps_different_z() {
        let mut p1 = ParamSet::init(Model::LeNet, 6);
        let mut p2 = p1.clone();
        perturb(&mut p1, 10, 3, 1, 1e-2);
        perturb(&mut p2, 10, 3, 2, 1e-2);
        assert_ne!(p1.data, p2.data);
    }

    #[test]
    fn projected_gradient_clip() {
        assert_eq!(projected_gradient(1.0, 0.0, 0.001, 100.0), 100.0);
        assert_eq!(projected_gradient(0.0, 1.0, 0.001, 100.0), -100.0);
        let g = projected_gradient(0.5, 0.3, 0.01, 100.0);
        assert!((g - 10.0).abs() < 1e-5);
    }

    #[test]
    fn merged_restore_update_equals_sequential() {
        // θ + (ε − ηg)z  ==  (θ − εz) + εz − ηg·z
        let mut merged = ParamSet::init(Model::LeNet, 8);
        let mut seq = merged.clone();
        let b = merged.zo_boundary(1);
        let (eps, lr, g) = (1e-3f32, 0.01f32, 2.5f32);
        // state right after the second forward is θ − εz for both
        perturb(&mut merged, b, 11, 5, -eps);
        perturb(&mut seq, b, 11, 5, -eps);
        // merged path
        perturb(&mut merged, b, 11, 5, eps - lr * g);
        // sequential path: restore then update
        perturb(&mut seq, b, 11, 5, eps);
        perturb(&mut seq, b, 11, 5, -lr * g);
        for (a, o) in merged.data.iter().flatten().zip(seq.data.iter().flatten()) {
            assert!((a - o).abs() <= 1e-6 * (1.0 + o.abs()));
        }
    }
}
