//! INT8 backend of the unified session API — paper Alg. 2
//! (ElasticZO-INT8) on the native NITI engine, with both gradient
//! modes:
//!
//! * [`ZoGradMode::FloatCE`] — `g = sgn(ℓ₊−ℓ₋)` from float CE of the
//!   int8 logits (the paper's "INT8" columns);
//! * [`ZoGradMode::IntCE`]   — the integer-only Eq. 7–12 sign (the
//!   paper's "INT8*" columns; no FPU anywhere in the step).
//!
//! The epoch loop lives in [`super::session::run`]; this module
//! contributes the per-minibatch INT8 work ([`Int8Session`] owning the
//! NITI weight tensors and the staged p_zero / b_BP schedules) plus the
//! reusable primitives ([`perturb_int8`], [`zo_update_int8`],
//! [`evaluate_int8`]).
//!
//! The sparse int8 perturbation `z = m ⊙ u`, `u ~ U(−r_max, r_max)`,
//! `m ~ Bernoulli(1−p_zero)` is regenerated from the step seed exactly
//! like the FP32 path; p_zero and the BP bitwidth follow the paper's
//! staged schedules.

use super::checkpoint::{self, TrainState};
use super::engine::BpDepth;
use super::kernels;
use super::schedules::{paper_b_bp, paper_p_zero, StagedSchedule};
use super::session::{self, PrecisionSpec, StepOutcome, TrainResult, TrainSession, TrainSpec};
use crate::data::loader::{eval_batches, Batch};
use crate::data::Dataset;
use crate::int8::lenet8::{self, Fwd8};
use crate::int8::qtensor::QTensor;
use crate::int8::rounding::clamp_i8;
use crate::int8::{intce, layers};
use crate::rng::ZoStream;
use crate::telemetry::{Phase, PhaseTimer};
use anyhow::Result;

/// How the ZO gradient sign is computed (paper Table 1 INT8 vs INT8*).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZoGradMode {
    FloatCE,
    IntCE,
}

impl ZoGradMode {
    pub fn parse(s: &str) -> Result<ZoGradMode> {
        match s {
            "float" | "int8" => Ok(ZoGradMode::FloatCE),
            "int" | "int8*" | "intce" => Ok(ZoGradMode::IntCE),
            other => anyhow::bail!("unknown zo grad mode '{other}' (float|int)"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            ZoGradMode::FloatCE => "float",
            ZoGradMode::IntCE => "int",
        }
    }
}

/// Perturb the first `n_zo` weight tensors in place:
/// θ ← clamp(θ + k·z), z regenerated from the step stream.
pub fn perturb_int8(
    ws: &mut [QTensor],
    n_zo: usize,
    seed: u64,
    step: u64,
    k: i32,
    r_max: i8,
    p_zero: f32,
) {
    let mut stream = ZoStream::for_step(seed, step);
    for w in &mut ws[..n_zo] {
        for v in &mut w.data {
            let z = stream.sparse_i8(r_max, p_zero) as i32;
            *v = clamp_i8(*v as i32 + k * z);
        }
    }
}

/// ZO update: θ ← clamp(θ − PseudoStochasticRound(g·z, b_ZO))
/// (paper Alg. 2 lines 18–24). `g ∈ {−1,0,+1}`.
pub fn zo_update_int8(
    ws: &mut [QTensor],
    n_zo: usize,
    seed: u64,
    step: u64,
    g: i32,
    b_zo: u32,
    r_max: i8,
    p_zero: f32,
) {
    if g == 0 {
        return;
    }
    let mut stream = ZoStream::for_step(seed, step);
    for w in &mut ws[..n_zo] {
        // accumulate g·z per tensor, then round to b_ZO bits
        let acc: Vec<i32> = w
            .data
            .iter()
            .map(|_| g * stream.sparse_i8(r_max, p_zero) as i32)
            .collect();
        let u = layers::round_update(&acc, b_zo);
        for (v, &uv) in w.data.iter_mut().zip(&u) {
            *v = clamp_i8(*v as i32 - uv as i32);
        }
    }
}

/// Float CE of int8 logits (eval + the INT8 FloatCE gradient).
pub fn int8_ce(logits: &QTensor, labels: &[u8], bsz: usize) -> f32 {
    let zeros = vec![0i8; logits.data.len()];
    // L(logits) - L(zeros) + L(zeros); L(zeros) = B·ln(10): compute directly
    let diff = intce::loss_diff_f32(&logits.data, logits.exp, &zeros, 0, labels, bsz, 10);
    (diff as f32 + bsz as f32 * (10.0f32).ln()) / bsz as f32
}

/// Accuracy of int8 logits.
pub fn int8_accuracy(fwd: &Fwd8, labels: &[u8], real: usize) -> (usize, usize) {
    let n = lenet8::NCLASS;
    let mut correct = 0;
    for row in 0..real {
        let lg = &fwd.logits.data[row * n..(row + 1) * n];
        let pred = lg.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        if pred == labels[row] as usize {
            correct += 1;
        }
    }
    (correct, real)
}

pub fn evaluate_int8(ws: &[QTensor], data: &Dataset, batch: usize) -> (f32, f32) {
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut loss = 0.0f64;
    let mut nb = 0usize;
    for b in eval_batches(data, batch) {
        let xq = lenet8::quantize_input(&b.x, batch);
        let fwd = lenet8::forward(ws, &xq, batch);
        let (c, t) = int8_accuracy(&fwd, &b.labels, b.bsz);
        correct += c;
        seen += t;
        loss += int8_ce(&fwd.logits, &b.labels, batch) as f64;
        nb += 1;
    }
    (
        (loss / nb.max(1) as f64) as f32,
        correct as f32 / seen.max(1) as f32,
    )
}

/// INT8 implementation of [`TrainSession`] over the NITI weights: pure
/// int8 full-BP (the NITI baseline) or the Alg. 2 ZO(+tail BP) step.
pub struct Int8Session<'a> {
    ws: &'a mut Vec<QTensor>,
    grad_mode: ZoGradMode,
    r_max: i8,
    b_zo: u32,
    seed: u64,
    batch: usize,
    label: String,
    p_zero_sched: StagedSchedule<f32>,
    b_bp_sched: StagedSchedule<u32>,
    /// Current-epoch schedule values (set by `begin_epoch`).
    p_zero: f32,
    b_bp: u32,
    /// `true` for the NITI full-BP baseline (no ZO partition).
    full_bp: bool,
    /// FC layers trained by tail BP (ZO methods only).
    bp_tail: usize,
    /// Weight tensors trained by ZO (prefix of the ABI order).
    n_zo: usize,
    /// Kernel path on/off (`TrainSpec::kernels`): cache the step's `z`
    /// once and replay it, instead of regenerating the stream 4×.
    kernels: bool,
    /// `true` when the ±1 forwards may run on two scoped threads.
    parallel: bool,
    /// Total elements in the ZO prefix (the `z` cache length).
    zo_elems: usize,
    /// Per-step cached perturbation (kernel path).
    kz: kernels::StepZi8,
    /// Reusable θ₊ snapshot for the parallel pair.
    snap_ws: Vec<QTensor>,
    /// Reusable per-tensor update scratch (kernel ZO update).
    acc_scratch: Vec<i32>,
    upd_scratch: Vec<i8>,
}

impl<'a> Int8Session<'a> {
    pub fn new(ws: &'a mut Vec<QTensor>, spec: &TrainSpec) -> Result<Int8Session<'a>> {
        let PrecisionSpec::Int8 { grad_mode, r_max, b_zo } = spec.precision else {
            anyhow::bail!(
                "Int8Session requires an int8 TrainSpec (got precision '{}')",
                spec.precision.token()
            );
        };
        anyhow::ensure!(
            spec.sparse_block == 0,
            "sparse_block is fp32-only (the int8 path has its own p_zero sparsity)"
        );
        let (full_bp, bp_tail, n_zo) = match spec.method.bp_depth() {
            BpDepth::All => (true, 0, 0),
            BpDepth::Tail(k) => (false, k, lenet8::zo_layer_count(k)),
        };
        let zo_elems: usize = ws[..n_zo].iter().map(|w| w.numel()).sum();
        Ok(Int8Session {
            ws,
            grad_mode,
            r_max,
            b_zo,
            seed: spec.seed,
            batch: spec.batch,
            label: spec.label(),
            p_zero_sched: paper_p_zero(spec.epochs),
            b_bp_sched: paper_b_bp(spec.epochs),
            p_zero: 0.0,
            b_bp: 0,
            full_bp,
            bp_tail,
            n_zo,
            kernels: spec.kernels,
            parallel: spec.kernels && n_zo > 0 && kernels::hw_threads() > 1,
            zo_elems,
            kz: kernels::StepZi8::new(),
            snap_ws: Vec::new(),
            acc_scratch: Vec::new(),
            upd_scratch: Vec::new(),
        })
    }
}

impl TrainSession for Int8Session<'_> {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn begin_epoch(&mut self, epoch: usize) -> f32 {
        self.p_zero = self.p_zero_sched.at(epoch);
        self.b_bp = self.b_bp_sched.at(epoch);
        0.0 // the int8 update has no learning rate
    }

    fn step(&mut self, b: &Batch, step_idx: u64, timer: &mut PhaseTimer) -> Result<StepOutcome> {
        let bsz = self.batch;
        let xq = timer.time(Phase::Data, || lenet8::quantize_input(&b.x, bsz));

        if self.full_bp {
            // NITI baseline: pure int8 BP
            let t0 = std::time::Instant::now();
            let fwd = lenet8::forward(self.ws, &xq, bsz);
            timer.add(Phase::Forward, t0.elapsed());
            let loss = int8_ce(&fwd.logits, &b.labels, bsz);
            let (correct, _) = int8_accuracy(&fwd, &b.labels, bsz);
            let t0 = std::time::Instant::now();
            lenet8::full_update(self.ws, &fwd, &b.labels, bsz, self.b_bp);
            timer.add(Phase::BpBackward, t0.elapsed());
            return Ok(StepOutcome { loss, correct, seen: bsz });
        }

        // ZO(+tail BP) step, Alg. 2 — kernel path caches the step's z
        // once and replays it; scalar path regenerates it per leg.
        // Bit-identical either way (tests/zo_kernel_parity.rs).
        let (seed, r_max, p_zero) = (self.seed, self.r_max, self.p_zero);
        let t0 = std::time::Instant::now();
        if self.kernels {
            self.kz.prepare(seed, step_idx, self.zo_elems, r_max, p_zero);
            kernels::apply_z_i8(self.ws, self.n_zo, 1, self.kz.z());
        } else {
            perturb_int8(self.ws, self.n_zo, seed, step_idx, 1, r_max, p_zero);
        }
        timer.add(Phase::ZoPerturb, t0.elapsed());

        let (fwd_plus, fwd_minus) = if self.parallel {
            // snapshot θ₊, flip the live weights to θ₋, then run both
            // forwards concurrently — forwards are pure, bits unchanged
            self.snap_ws.clone_from(self.ws);
            let t0 = std::time::Instant::now();
            kernels::apply_z_i8(self.ws, self.n_zo, -2, self.kz.z());
            timer.add(Phase::ZoPerturb, t0.elapsed());

            let t0 = std::time::Instant::now();
            let ws: &[QTensor] = self.ws;
            let snap: &[QTensor] = &self.snap_ws;
            let xq_ref = &xq;
            let (plus, minus) = std::thread::scope(|sc| {
                let h = sc.spawn(move || lenet8::forward(snap, xq_ref, bsz));
                let minus = lenet8::forward(ws, xq_ref, bsz);
                (h.join().expect("±1 forward worker panicked"), minus)
            });
            timer.add(Phase::Forward, t0.elapsed());
            (plus, minus)
        } else {
            let t0 = std::time::Instant::now();
            let plus = lenet8::forward(self.ws, &xq, bsz);
            timer.add(Phase::Forward, t0.elapsed());

            let t0 = std::time::Instant::now();
            if self.kernels {
                kernels::apply_z_i8(self.ws, self.n_zo, -2, self.kz.z());
            } else {
                perturb_int8(self.ws, self.n_zo, seed, step_idx, -2, r_max, p_zero);
            }
            timer.add(Phase::ZoPerturb, t0.elapsed());

            let t0 = std::time::Instant::now();
            let minus = lenet8::forward(self.ws, &xq, bsz);
            timer.add(Phase::Forward, t0.elapsed());
            (plus, minus)
        };

        let t0 = std::time::Instant::now();
        let g = match self.grad_mode {
            ZoGradMode::IntCE => intce::loss_diff_sign_int(
                &fwd_plus.logits.data,
                fwd_plus.logits.exp,
                &fwd_minus.logits.data,
                fwd_minus.logits.exp,
                &b.labels,
                bsz,
                lenet8::NCLASS,
            ),
            ZoGradMode::FloatCE => {
                let d = intce::loss_diff_f32(
                    &fwd_plus.logits.data,
                    fwd_plus.logits.exp,
                    &fwd_minus.logits.data,
                    fwd_minus.logits.exp,
                    &b.labels,
                    bsz,
                    lenet8::NCLASS,
                );
                d.signum() as i32
            }
        };
        timer.add(Phase::Loss, t0.elapsed());

        // restore
        let t0 = std::time::Instant::now();
        if self.kernels {
            kernels::apply_z_i8(self.ws, self.n_zo, 1, self.kz.z());
        } else {
            perturb_int8(self.ws, self.n_zo, seed, step_idx, 1, r_max, p_zero);
        }
        timer.add(Phase::ZoPerturb, t0.elapsed());

        let t0 = std::time::Instant::now();
        if self.kernels {
            kernels::zo_update_z_i8(
                self.ws,
                self.n_zo,
                g,
                self.b_zo,
                self.kz.z(),
                &mut self.acc_scratch,
                &mut self.upd_scratch,
            );
        } else {
            zo_update_int8(self.ws, self.n_zo, seed, step_idx, g, self.b_zo, r_max, p_zero);
        }
        timer.add(Phase::ZoUpdate, t0.elapsed());

        if self.bp_tail > 0 {
            let t0 = std::time::Instant::now();
            lenet8::tail_update(self.ws, &fwd_minus, &b.labels, self.bp_tail, bsz, self.b_bp);
            timer.add(Phase::BpBackward, t0.elapsed());
        }
        let loss = int8_ce(&fwd_minus.logits, &b.labels, bsz);
        let (correct, _) = int8_accuracy(&fwd_minus, &b.labels, bsz);
        Ok(StepOutcome { loss, correct, seen: bsz })
    }

    fn evaluate(&mut self, data: &Dataset) -> Result<(f32, f32)> {
        Ok(evaluate_int8(self.ws, data, self.batch))
    }

    fn set_bp_tail(&mut self, k: usize) -> Result<()> {
        anyhow::ensure!(
            !self.full_bp,
            "cannot move the ZO/BP boundary of a full-bp run"
        );
        anyhow::ensure!(
            k <= lenet8::MAX_BP_TAIL,
            "bp-tail={k} exceeds the int8 LeNet tail depth {}",
            lenet8::MAX_BP_TAIL
        );
        self.bp_tail = k;
        self.n_zo = lenet8::zo_layer_count(k);
        self.zo_elems = self.ws[..self.n_zo].iter().map(|w| w.numel()).sum();
        // StepZi8 keys on (seed, step, len), so the cache regenerates
        // itself at the next step; only the thread toggle needs care
        self.parallel = self.kernels && self.n_zo > 0 && kernels::hw_threads() > 1;
        Ok(())
    }

    fn verbose_note(&self) -> String {
        // surface the staged-schedule values the epoch ran under (the
        // old int8 loop printed these; lr is meaningless here)
        format!("  p_zero {}  b_bp {}", self.p_zero, self.b_bp)
    }

    fn snapshot(&self) -> Vec<checkpoint::CkptTensor> {
        let names: Vec<&str> = lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
        checkpoint::int8_to_tensors(&names, self.ws)
    }
}

/// Train INT8 LeNet with any method (FullZO / Cls1 / Cls2 / FullBP=NITI).
/// Thin wrapper: builds an [`Int8Session`] and hands it to the one
/// generic loop in [`session::run`].
pub fn train_int8(
    ws: &mut Vec<QTensor>,
    train_data: &Dataset,
    test_data: &Dataset,
    spec: &TrainSpec,
) -> Result<TrainResult> {
    train_int8_from(ws, train_data, test_data, spec, None)
}

/// [`train_int8`], continuing from a checkpoint's training state (the
/// caller has already restored `ws` from the same checkpoint) — the
/// INT8/INT8* leg of `repro train --resume`.
pub fn train_int8_from(
    ws: &mut Vec<QTensor>,
    train_data: &Dataset,
    test_data: &Dataset,
    spec: &TrainSpec,
    resume: Option<&TrainState>,
) -> Result<TrainResult> {
    let mut s = Int8Session::new(ws, spec)?;
    session::run_from(&mut s, spec, train_data, test_data, resume)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Method;
    use crate::data::synth_mnist;

    fn int8_spec(method: Method, grad_mode: ZoGradMode, epochs: usize, batch: usize) -> TrainSpec {
        TrainSpec {
            method,
            precision: PrecisionSpec::int8(grad_mode),
            epochs,
            batch,
            ..Default::default()
        }
    }

    #[test]
    fn grad_mode_tokens_roundtrip() {
        for gm in [ZoGradMode::FloatCE, ZoGradMode::IntCE] {
            assert_eq!(ZoGradMode::parse(gm.token()).unwrap(), gm);
        }
        assert!(ZoGradMode::parse("bf16").is_err());
    }

    #[test]
    fn perturb_restore_roundtrip_without_saturation() {
        // with small weights and r_max, clamp never engages and the
        // +1/−2/+1 sequence restores exactly (the Alg. 2 seed trick)
        let mut ws = lenet8::init_params(1, 8);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        perturb_int8(&mut ws, 5, 3, 7, 1, 15, 0.5);
        perturb_int8(&mut ws, 5, 3, 7, -2, 15, 0.5);
        perturb_int8(&mut ws, 5, 3, 7, 1, 15, 0.5);
        for (w, o) in ws.iter().zip(&orig) {
            assert_eq!(w.data, *o);
        }
    }

    #[test]
    fn perturb_only_touches_zo_prefix() {
        let mut ws = lenet8::init_params(1, 32);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        perturb_int8(&mut ws, 3, 5, 1, 1, 15, 0.33);
        assert_eq!(ws[3].data, orig[3]);
        assert_eq!(ws[4].data, orig[4]);
        assert_ne!(ws[0].data, orig[0]);
    }

    #[test]
    fn zo_update_moves_weights_when_g_nonzero() {
        let mut ws = lenet8::init_params(2, 32);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        zo_update_int8(&mut ws, 5, 4, 9, 1, 1, 15, 0.33);
        let moved = ws.iter().zip(&orig).filter(|(w, o)| w.data != **o).count();
        assert!(moved >= 4, "{moved}/5 moved");
        // g = 0 must be a no-op
        let mut ws2 = lenet8::init_params(2, 32);
        let orig2: Vec<Vec<i8>> = ws2.iter().map(|w| w.data.clone()).collect();
        zo_update_int8(&mut ws2, 5, 4, 9, 0, 1, 15, 0.33);
        for (w, o) in ws2.iter().zip(&orig2) {
            assert_eq!(w.data, *o);
        }
    }

    #[test]
    fn int8_full_bp_learns() {
        let train_d = synth_mnist::generate(256, 21);
        let test_d = synth_mnist::generate(128, 22);
        let mut ws = lenet8::init_params(23, 32);
        let spec = int8_spec(Method::FullBp, ZoGradMode::FloatCE, 3, 32);
        let r = train_int8(&mut ws, &train_d, &test_d, &spec).unwrap();
        assert!(
            r.history.best_test_acc() > 0.3,
            "acc {}",
            r.history.best_test_acc()
        );
    }

    #[test]
    fn int8_cls1_trains_and_times_phases() {
        let train_d = synth_mnist::generate(128, 24);
        let test_d = synth_mnist::generate(64, 25);
        let mut ws = lenet8::init_params(26, 32);
        let spec = int8_spec(Method::CLS1, ZoGradMode::FloatCE, 2, 16);
        let r = train_int8(&mut ws, &train_d, &test_d, &spec).unwrap();
        assert!(r.timer.total(Phase::Forward).as_nanos() > 0);
        assert!(r.timer.total(Phase::ZoUpdate).as_nanos() > 0);
        assert!(r.timer.total(Phase::BpBackward).as_nanos() > 0);
        assert_eq!(r.history.epochs.len(), 2);
        assert_eq!(r.history.label, "ZO-Feat-Cls1 INT8");
    }

    #[test]
    fn int8_train_acc_computed_and_stop_flag_cancels() {
        use crate::coordinator::control::{ProgressSink, StopFlag};
        let train_d = synth_mnist::generate(96, 31);
        let test_d = synth_mnist::generate(48, 32);
        let mut ws = lenet8::init_params(33, 32);
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        let spec = TrainSpec {
            progress: ProgressSink::new(move |e| {
                if e.epoch == 1 {
                    stop2.request_stop();
                }
            }),
            stop,
            ..int8_spec(Method::CLS1, ZoGradMode::FloatCE, 50, 16)
        };
        let r = train_int8(&mut ws, &train_d, &test_d, &spec).unwrap();
        assert!(r.stopped);
        assert_eq!(r.history.epochs.len(), 2, "must stop right after epoch 1");
        let acc = r.history.epochs[1].train_acc;
        assert!(acc > 0.0 && acc <= 1.0, "train_acc {acc}");
    }

    #[test]
    fn intce_mode_runs() {
        let train_d = synth_mnist::generate(64, 27);
        let test_d = synth_mnist::generate(32, 28);
        let mut ws = lenet8::init_params(29, 32);
        let spec = int8_spec(Method::FULL_ZO, ZoGradMode::IntCE, 1, 16);
        let r = train_int8(&mut ws, &train_d, &test_d, &spec).unwrap();
        assert_eq!(r.history.epochs.len(), 1);
        assert!(r.history.epochs[0].train_loss.is_finite());
        assert_eq!(r.history.label, "Full ZO INT8*");
    }

    #[test]
    fn int8_session_rejects_fp32_spec() {
        let mut ws = lenet8::init_params(30, 32);
        let spec = TrainSpec::default(); // fp32 precision
        assert!(Int8Session::new(&mut ws, &spec).is_err());
    }
}
