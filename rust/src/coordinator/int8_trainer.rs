//! INT8 training loop — paper Alg. 2 (ElasticZO-INT8) on the native
//! NITI engine, with both gradient modes:
//!
//! * [`ZoGradMode::FloatCE`] — `g = sgn(ℓ₊−ℓ₋)` from float CE of the
//!   int8 logits (the paper's "INT8" columns);
//! * [`ZoGradMode::IntCE`]   — the integer-only Eq. 7–12 sign (the
//!   paper's "INT8*" columns; no FPU anywhere in the step).
//!
//! The sparse int8 perturbation `z = m ⊙ u`, `u ~ U(−r_max, r_max)`,
//! `m ~ Bernoulli(1−p_zero)` is regenerated from the step seed exactly
//! like the FP32 path; p_zero and the BP bitwidth follow the paper's
//! staged schedules.

use super::control::{ProgressSink, StopFlag};
use super::engine::Method;
use super::metrics::{EpochStats, History};
use super::schedules::{paper_b_bp, paper_p_zero};
use crate::data::loader::{eval_batches, Loader};
use crate::data::Dataset;
use crate::int8::lenet8::{self, Fwd8};
use crate::int8::qtensor::QTensor;
use crate::int8::rounding::clamp_i8;
use crate::int8::{intce, layers};
use crate::rng::ZoStream;
use crate::telemetry::{Phase, PhaseTimer};
use anyhow::Result;

/// How the ZO gradient sign is computed (paper Table 1 INT8 vs INT8*).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZoGradMode {
    FloatCE,
    IntCE,
}

impl ZoGradMode {
    pub fn parse(s: &str) -> Result<ZoGradMode> {
        match s {
            "float" | "int8" => Ok(ZoGradMode::FloatCE),
            "int" | "int8*" | "intce" => Ok(ZoGradMode::IntCE),
            other => anyhow::bail!("unknown zo grad mode '{other}' (float|int)"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Int8TrainConfig {
    pub method: Method,
    pub grad_mode: ZoGradMode,
    pub epochs: usize,
    pub batch: usize,
    /// Perturbation scale r_max (paper tunes in {1,3,7,15,31,63}).
    pub r_max: i8,
    /// ZO update bitwidth (paper fixes b_ZO = 1).
    pub b_zo: u32,
    pub seed: u64,
    pub eval_every: usize,
    pub verbose: bool,
    /// Cooperative cancellation; polled between batches and epochs.
    pub stop: StopFlag,
    /// Live per-epoch progress callback (armed by the `serve` workers).
    pub progress: ProgressSink,
}

impl Default for Int8TrainConfig {
    fn default() -> Self {
        Int8TrainConfig {
            method: Method::Cls1,
            grad_mode: ZoGradMode::FloatCE,
            epochs: 10,
            batch: 32,
            r_max: 15,
            b_zo: 1,
            seed: 1,
            eval_every: 1,
            verbose: false,
            stop: StopFlag::default(),
            progress: ProgressSink::default(),
        }
    }
}

/// Perturb the first `n_zo` weight tensors in place:
/// θ ← clamp(θ + k·z), z regenerated from the step stream.
pub fn perturb_int8(
    ws: &mut [QTensor],
    n_zo: usize,
    seed: u64,
    step: u64,
    k: i32,
    r_max: i8,
    p_zero: f32,
) {
    let mut stream = ZoStream::for_step(seed, step);
    for w in &mut ws[..n_zo] {
        for v in &mut w.data {
            let z = stream.sparse_i8(r_max, p_zero) as i32;
            *v = clamp_i8(*v as i32 + k * z);
        }
    }
}

/// ZO update: θ ← clamp(θ − PseudoStochasticRound(g·z, b_ZO))
/// (paper Alg. 2 lines 18–24). `g ∈ {−1,0,+1}`.
pub fn zo_update_int8(
    ws: &mut [QTensor],
    n_zo: usize,
    seed: u64,
    step: u64,
    g: i32,
    b_zo: u32,
    r_max: i8,
    p_zero: f32,
) {
    if g == 0 {
        return;
    }
    let mut stream = ZoStream::for_step(seed, step);
    for w in &mut ws[..n_zo] {
        // accumulate g·z per tensor, then round to b_ZO bits
        let acc: Vec<i32> = w
            .data
            .iter()
            .map(|_| g * stream.sparse_i8(r_max, p_zero) as i32)
            .collect();
        let u = layers::round_update(&acc, b_zo);
        for (v, &uv) in w.data.iter_mut().zip(&u) {
            *v = clamp_i8(*v as i32 - uv as i32);
        }
    }
}

/// Float CE of int8 logits (eval + the INT8 FloatCE gradient).
pub fn int8_ce(logits: &QTensor, labels: &[u8], bsz: usize) -> f32 {
    let zeros = vec![0i8; logits.data.len()];
    // L(logits) - L(zeros) + L(zeros); L(zeros) = B·ln(10): compute directly
    let diff = intce::loss_diff_f32(&logits.data, logits.exp, &zeros, 0, labels, bsz, 10);
    (diff as f32 + bsz as f32 * (10.0f32).ln()) / bsz as f32
}

/// Accuracy of int8 logits.
pub fn int8_accuracy(fwd: &Fwd8, labels: &[u8], real: usize) -> (usize, usize) {
    let n = lenet8::NCLASS;
    let mut correct = 0;
    for row in 0..real {
        let lg = &fwd.logits.data[row * n..(row + 1) * n];
        let pred = lg.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        if pred == labels[row] as usize {
            correct += 1;
        }
    }
    (correct, real)
}

pub fn evaluate_int8(ws: &[QTensor], data: &Dataset, batch: usize) -> (f32, f32) {
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut loss = 0.0f64;
    let mut nb = 0usize;
    for b in eval_batches(data, batch) {
        let xq = lenet8::quantize_input(&b.x, batch);
        let fwd = lenet8::forward(ws, &xq, batch);
        let (c, t) = int8_accuracy(&fwd, &b.labels, b.bsz);
        correct += c;
        seen += t;
        loss += int8_ce(&fwd.logits, &b.labels, batch) as f64;
        nb += 1;
    }
    (
        (loss / nb.max(1) as f64) as f32,
        correct as f32 / seen.max(1) as f32,
    )
}

pub struct Int8TrainResult {
    pub history: History,
    pub timer: PhaseTimer,
    /// True iff the run ended early because [`Int8TrainConfig::stop`] fired.
    pub stopped: bool,
}

/// Train INT8 LeNet with any method (FullZO / Cls1 / Cls2 / FullBP=NITI).
pub fn train_int8(
    ws: &mut Vec<QTensor>,
    train_data: &Dataset,
    test_data: &Dataset,
    cfg: &Int8TrainConfig,
) -> Result<Int8TrainResult> {
    let label = match cfg.grad_mode {
        ZoGradMode::FloatCE => format!("{} INT8", cfg.method.label()),
        ZoGradMode::IntCE => format!("{} INT8*", cfg.method.label()),
    };
    let mut history = History::new(&label);
    let mut timer = PhaseTimer::new();
    let p_zero_sched = paper_p_zero(cfg.epochs);
    let b_bp_sched = paper_b_bp(cfg.epochs);
    let bp_layers = match cfg.method {
        Method::FullBp => 0, // handled by full_update below
        m => m.bp_layers(),
    };
    let n_zo = match cfg.method {
        Method::FullBp => 0,
        m => lenet8::zo_layer_count(m.bp_layers()),
    };
    let mut step: u64 = 0;
    let mut stopped = false;

    'epochs: for epoch in 0..cfg.epochs {
        if cfg.stop.should_stop() {
            stopped = true;
            break;
        }
        let epoch_t0 = std::time::Instant::now();
        let p_zero = p_zero_sched.at(epoch);
        let b_bp = b_bp_sched.at(epoch);
        let mut epoch_loss = 0.0f64;
        let mut nbatches = 0usize;
        let mut correct = 0usize;
        let mut seen = 0usize;

        for b in Loader::new(train_data, cfg.batch, cfg.seed ^ 0xDA7A, epoch as u64) {
            if cfg.stop.should_stop() {
                stopped = true;
                break 'epochs;
            }
            let xq = timer.time(Phase::Data, || lenet8::quantize_input(&b.x, cfg.batch));

            if cfg.method == Method::FullBp {
                // NITI baseline: pure int8 BP
                let t0 = std::time::Instant::now();
                let fwd = lenet8::forward(ws, &xq, cfg.batch);
                timer.add(Phase::Forward, t0.elapsed());
                epoch_loss += int8_ce(&fwd.logits, &b.labels, cfg.batch) as f64;
                let (c, _) = int8_accuracy(&fwd, &b.labels, cfg.batch);
                correct += c;
                seen += cfg.batch;
                let t0 = std::time::Instant::now();
                lenet8::full_update(ws, &fwd, &b.labels, cfg.batch, b_bp);
                timer.add(Phase::BpBackward, t0.elapsed());
            } else {
                // ZO(+tail BP) step, Alg. 2
                let t0 = std::time::Instant::now();
                perturb_int8(ws, n_zo, cfg.seed, step, 1, cfg.r_max, p_zero);
                timer.add(Phase::ZoPerturb, t0.elapsed());

                let t0 = std::time::Instant::now();
                let fwd_plus = lenet8::forward(ws, &xq, cfg.batch);
                timer.add(Phase::Forward, t0.elapsed());

                let t0 = std::time::Instant::now();
                perturb_int8(ws, n_zo, cfg.seed, step, -2, cfg.r_max, p_zero);
                timer.add(Phase::ZoPerturb, t0.elapsed());

                let t0 = std::time::Instant::now();
                let fwd_minus = lenet8::forward(ws, &xq, cfg.batch);
                timer.add(Phase::Forward, t0.elapsed());

                let t0 = std::time::Instant::now();
                let g = match cfg.grad_mode {
                    ZoGradMode::IntCE => intce::loss_diff_sign_int(
                        &fwd_plus.logits.data,
                        fwd_plus.logits.exp,
                        &fwd_minus.logits.data,
                        fwd_minus.logits.exp,
                        &b.labels,
                        cfg.batch,
                        lenet8::NCLASS,
                    ),
                    ZoGradMode::FloatCE => {
                        let d = intce::loss_diff_f32(
                            &fwd_plus.logits.data,
                            fwd_plus.logits.exp,
                            &fwd_minus.logits.data,
                            fwd_minus.logits.exp,
                            &b.labels,
                            cfg.batch,
                            lenet8::NCLASS,
                        );
                        d.signum() as i32
                    }
                };
                timer.add(Phase::Loss, t0.elapsed());

                // restore
                let t0 = std::time::Instant::now();
                perturb_int8(ws, n_zo, cfg.seed, step, 1, cfg.r_max, p_zero);
                timer.add(Phase::ZoPerturb, t0.elapsed());

                let t0 = std::time::Instant::now();
                zo_update_int8(ws, n_zo, cfg.seed, step, g, cfg.b_zo, cfg.r_max, p_zero);
                timer.add(Phase::ZoUpdate, t0.elapsed());

                if bp_layers > 0 {
                    let t0 = std::time::Instant::now();
                    lenet8::tail_update(ws, &fwd_minus, &b.labels, bp_layers, cfg.batch, b_bp);
                    timer.add(Phase::BpBackward, t0.elapsed());
                }
                epoch_loss += int8_ce(&fwd_minus.logits, &b.labels, cfg.batch) as f64;
                let (c, _) = int8_accuracy(&fwd_minus, &b.labels, cfg.batch);
                correct += c;
                seen += cfg.batch;
            }
            nbatches += 1;
            step += 1;
        }

        let is_last = epoch + 1 == cfg.epochs;
        let (test_loss, test_acc) = if epoch % cfg.eval_every == 0 || is_last {
            let t0 = std::time::Instant::now();
            let r = evaluate_int8(ws, test_data, cfg.batch);
            timer.add(Phase::Eval, t0.elapsed());
            r
        } else {
            let prev = history.epochs.last();
            (
                prev.map(|e| e.test_loss).unwrap_or(f32::NAN),
                prev.map(|e| e.test_acc).unwrap_or(0.0),
            )
        };
        let stats = EpochStats {
            epoch,
            train_loss: (epoch_loss / nbatches.max(1) as f64) as f32,
            test_loss,
            train_acc: if seen > 0 { correct as f32 / seen as f32 } else { 0.0 },
            test_acc,
            lr: 0.0,
            seconds: epoch_t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            println!(
                "[{label}] epoch {:>3}  loss {:.4}  test_loss {:.4}  acc {:.2}%  train_acc {:.2}%  p_zero {p_zero}  b_bp {b_bp}",
                epoch,
                stats.train_loss,
                stats.test_loss,
                stats.test_acc * 100.0,
                stats.train_acc * 100.0,
            );
        }
        cfg.progress.publish(&stats);
        history.push(stats);
    }
    Ok(Int8TrainResult { history, timer, stopped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn perturb_restore_roundtrip_without_saturation() {
        // with small weights and r_max, clamp never engages and the
        // +1/−2/+1 sequence restores exactly (the Alg. 2 seed trick)
        let mut ws = lenet8::init_params(1, 8);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        perturb_int8(&mut ws, 5, 3, 7, 1, 15, 0.5);
        perturb_int8(&mut ws, 5, 3, 7, -2, 15, 0.5);
        perturb_int8(&mut ws, 5, 3, 7, 1, 15, 0.5);
        for (w, o) in ws.iter().zip(&orig) {
            assert_eq!(w.data, *o);
        }
    }

    #[test]
    fn perturb_only_touches_zo_prefix() {
        let mut ws = lenet8::init_params(1, 32);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        perturb_int8(&mut ws, 3, 5, 1, 1, 15, 0.33);
        assert_eq!(ws[3].data, orig[3]);
        assert_eq!(ws[4].data, orig[4]);
        assert_ne!(ws[0].data, orig[0]);
    }

    #[test]
    fn zo_update_moves_weights_when_g_nonzero() {
        let mut ws = lenet8::init_params(2, 32);
        let orig: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        zo_update_int8(&mut ws, 5, 4, 9, 1, 1, 15, 0.33);
        let moved = ws.iter().zip(&orig).filter(|(w, o)| w.data != **o).count();
        assert!(moved >= 4, "{moved}/5 moved");
        // g = 0 must be a no-op
        let mut ws2 = lenet8::init_params(2, 32);
        let orig2: Vec<Vec<i8>> = ws2.iter().map(|w| w.data.clone()).collect();
        zo_update_int8(&mut ws2, 5, 4, 9, 0, 1, 15, 0.33);
        for (w, o) in ws2.iter().zip(&orig2) {
            assert_eq!(w.data, *o);
        }
    }

    #[test]
    fn int8_full_bp_learns() {
        let train_d = synth_mnist::generate(256, 21);
        let test_d = synth_mnist::generate(128, 22);
        let mut ws = lenet8::init_params(23, 32);
        let cfg = Int8TrainConfig {
            method: Method::FullBp,
            epochs: 3,
            batch: 32,
            ..Default::default()
        };
        let r = train_int8(&mut ws, &train_d, &test_d, &cfg).unwrap();
        assert!(
            r.history.best_test_acc() > 0.3,
            "acc {}",
            r.history.best_test_acc()
        );
    }

    #[test]
    fn int8_cls1_trains_and_times_phases() {
        let train_d = synth_mnist::generate(128, 24);
        let test_d = synth_mnist::generate(64, 25);
        let mut ws = lenet8::init_params(26, 32);
        let cfg = Int8TrainConfig {
            method: Method::Cls1,
            epochs: 2,
            batch: 16,
            r_max: 15,
            ..Default::default()
        };
        let r = train_int8(&mut ws, &train_d, &test_d, &cfg).unwrap();
        assert!(r.timer.total(Phase::Forward).as_nanos() > 0);
        assert!(r.timer.total(Phase::ZoUpdate).as_nanos() > 0);
        assert!(r.timer.total(Phase::BpBackward).as_nanos() > 0);
        assert_eq!(r.history.epochs.len(), 2);
    }

    #[test]
    fn int8_train_acc_computed_and_stop_flag_cancels() {
        use crate::coordinator::control::{ProgressSink, StopFlag};
        let train_d = synth_mnist::generate(96, 31);
        let test_d = synth_mnist::generate(48, 32);
        let mut ws = lenet8::init_params(33, 32);
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        let cfg = Int8TrainConfig {
            method: Method::Cls1,
            epochs: 50,
            batch: 16,
            progress: ProgressSink::new(move |e| {
                if e.epoch == 1 {
                    stop2.request_stop();
                }
            }),
            stop,
            ..Default::default()
        };
        let r = train_int8(&mut ws, &train_d, &test_d, &cfg).unwrap();
        assert!(r.stopped);
        assert_eq!(r.history.epochs.len(), 2, "must stop right after epoch 1");
        let acc = r.history.epochs[1].train_acc;
        assert!(acc > 0.0 && acc <= 1.0, "train_acc {acc}");
    }

    #[test]
    fn intce_mode_runs() {
        let train_d = synth_mnist::generate(64, 27);
        let test_d = synth_mnist::generate(32, 28);
        let mut ws = lenet8::init_params(29, 32);
        let cfg = Int8TrainConfig {
            method: Method::FullZo,
            grad_mode: ZoGradMode::IntCE,
            epochs: 1,
            batch: 16,
            ..Default::default()
        };
        let r = train_int8(&mut ws, &train_d, &test_d, &cfg).unwrap();
        assert_eq!(r.history.epochs.len(), 1);
        assert!(r.history.epochs[0].train_loss.is_finite());
    }
}
