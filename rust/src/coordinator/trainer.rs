//! FP32 backend of the unified session API — paper Alg. 1 for all four
//! methods over either engine.
//!
//! The epoch loop itself lives in [`super::session::run`]; this module
//! contributes the per-minibatch FP32 work ([`Fp32Session`] wrapping an
//! [`Engine`] + [`ParamSet`]) and the reusable pieces behind it
//! ([`zo_step`], [`evaluate`]).
//!
//! Per-minibatch ElasticZO step:
//!   1. sample the step seed (just the step counter mixed with the run
//!      seed — the 4-byte random seed of Alg. 1 line 3)
//!   2. perturb θ₁..θ_C by +εz, forward → ℓ₊
//!   3. perturb by −2εz, forward → ℓ₋
//!   4. g = clip((ℓ₊−ℓ₋)/2ε)
//!   5. perturb by (ε − ηg)z — merged restore+update (paper §4)
//!   6. BP the last L−C layers from the partition activation of the ℓ₋
//!      pass and apply SGD.
//!
//! Full BP runs through the engine's fused `full_step`, whose returned
//! logits keep train accuracy live on that path too.

use super::checkpoint::{self, TrainState};
use super::engine::{BpDepth, Engine};
use super::kernels;
use super::params::ParamSet;
use super::schedules::LrSchedule;
use super::session::{self, StepOutcome, TrainResult, TrainSession, TrainSpec};
use super::zo;
use crate::data::loader::{eval_batches, Batch};
use crate::data::Dataset;
use crate::nn::loss::accuracy;
use crate::telemetry::{Phase, PhaseTimer};
use crate::tensor::ops;
use anyhow::Result;

/// Evaluate mean loss and accuracy over a dataset.
pub fn evaluate(
    engine: &mut dyn Engine,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
) -> Result<(f32, f32)> {
    let nclass = data.nclass;
    let mut total_loss = 0.0f64;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut batches = 0usize;
    for b in eval_batches(data, batch) {
        let fwd = engine.forward(params, &b.x, &b.y_onehot, batch)?;
        let (c, t) = accuracy(&fwd.logits, &b.labels, b.bsz, nclass);
        correct += c;
        seen += t;
        total_loss += fwd.loss as f64;
        batches += 1;
    }
    Ok((
        (total_loss / batches.max(1) as f64) as f32,
        correct as f32 / seen.max(1) as f32,
    ))
}

/// One ElasticZO/FullZO minibatch step (`spec.method` must be a ZO
/// method). Returns the step's train loss and the number of correct
/// predictions in this minibatch (from the ℓ₋-pass logits, which the
/// step already produces).
pub fn zo_step(
    engine: &mut dyn Engine,
    params: &mut ParamSet,
    b: &Batch,
    step: u64,
    lr: f32,
    spec: &TrainSpec,
    timer: &mut PhaseTimer,
) -> Result<(f32, usize)> {
    let BpDepth::Tail(bp_tail) = spec.method.bp_depth() else {
        anyhow::bail!("zo_step is undefined for Full BP (use Engine::full_step)");
    };
    let bsz = spec.batch;
    let boundary = params.zo_boundary(bp_tail);
    let (seed, eps) = (spec.seed, spec.eps);
    let (x, y) = (&b.x, &b.y_onehot);

    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, eps);
    timer.add(Phase::ZoPerturb, t0.elapsed());

    let fwd_plus = {
        let t = std::time::Instant::now();
        let f = engine.forward(params, x, y, bsz)?;
        timer.add(Phase::Forward, t.elapsed());
        f
    };

    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, -2.0 * eps);
    timer.add(Phase::ZoPerturb, t0.elapsed());

    let fwd_minus = {
        let t = std::time::Instant::now();
        let f = engine.forward(params, x, y, bsz)?;
        timer.add(Phase::Forward, t.elapsed());
        f
    };

    let g = zo::projected_gradient(fwd_plus.loss, fwd_minus.loss, eps, spec.g_clip);

    // train accuracy from the ℓ₋ logits (θ−εz is within O(ε) of θ, and
    // this pass's outputs are already in hand — no extra forward)
    let nclass = fwd_minus.logits.len() / bsz.max(1);
    let (correct, _) = accuracy(&fwd_minus.logits, &b.labels, bsz, nclass);

    // merged restore + ZO update: θ += (ε − ηg)z
    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, eps - lr * g);
    timer.add(Phase::ZoUpdate, t0.elapsed());

    // BP tail from the ℓ₋ pass activations (paper keeps perturbed-pass
    // activations to avoid a third forward)
    if bp_tail > 0 {
        let t0 = std::time::Instant::now();
        let tails = engine.tail_grads(params, &fwd_minus, y, bp_tail, bsz)?;
        for (idx, grad) in tails {
            ops::axpy(-lr, &grad, &mut params.data[idx]);
        }
        timer.add(Phase::BpBackward, t0.elapsed());
    }

    Ok((0.5 * (fwd_plus.loss + fwd_minus.loss), correct))
}

/// FP32 implementation of [`TrainSession`]: ZO(+tail BP) steps via
/// the chunked kernel path (or [`zo_step`], the scalar reference, when
/// `spec.kernels` is off — bit-identical either way), Full BP via the
/// engine's fused `full_step`.
pub struct Fp32Session<'a> {
    engine: &'a mut dyn Engine,
    params: &'a mut ParamSet,
    spec: TrainSpec,
    lr_sched: LrSchedule,
    lr: f32,
    /// Per-step cached perturbation (kernel path).
    kz: kernels::StepZ,
    /// ZO/BP partition of `spec.method` (0 for Full BP).
    boundary: usize,
    /// FC layers trained by tail BP.
    bp_tail: usize,
    /// Element count of each ZO-prefix tensor / their sum.
    zo_layout: Vec<usize>,
    zo_total: usize,
    /// Second engine handle for the parallel ±ε pair (`None` ⇒
    /// sequential: scalar path, single core, or unforkable engine).
    aux: Option<Box<dyn Engine + Send>>,
    /// Reusable θ₊ snapshot for the parallel pair.
    snap: Option<ParamSet>,
}

impl<'a> Fp32Session<'a> {
    pub fn new(
        engine: &'a mut dyn Engine,
        params: &'a mut ParamSet,
        spec: &TrainSpec,
    ) -> Result<Fp32Session<'a>> {
        anyhow::ensure!(
            matches!(spec.precision, session::PrecisionSpec::Fp32),
            "Fp32Session requires a fp32 TrainSpec (got precision '{}')",
            spec.precision.token()
        );
        if spec.sparse_block > 0 {
            anyhow::ensure!(
                spec.kernels,
                "sparse_block requires the kernel path (kernels=true)"
            );
            anyhow::ensure!(
                spec.method.bp_depth() != BpDepth::All,
                "sparse_block requires a ZO method (full-bp has no perturbation)"
            );
        }
        let (boundary, bp_tail) = match spec.method.bp_depth() {
            BpDepth::All => (0, 0),
            BpDepth::Tail(k) => (params.zo_boundary(k), k),
        };
        let zo_layout: Vec<usize> = params.data[..boundary].iter().map(|t| t.len()).collect();
        let zo_total = zo_layout.iter().sum();
        let aux = if spec.kernels && boundary > 0 && kernels::hw_threads() > 1 {
            engine.fork()
        } else {
            None
        };
        Ok(Fp32Session {
            engine,
            params,
            lr_sched: LrSchedule::paper_fp32(spec.lr0, spec.epochs),
            lr: spec.lr0,
            spec: spec.clone(),
            kz: kernels::StepZ::new(),
            boundary,
            bp_tail,
            zo_layout,
            zo_total,
            aux,
            snap: None,
        })
    }

    /// The kernel-path ZO step: one `z` generation replayed by every
    /// leg, ±ε forwards on two engine handles when a second core and a
    /// forked engine are available. Bit-identical to [`zo_step`] (the
    /// scalar reference) except behind the structured-perturbation
    /// flag — `tests/zo_kernel_parity.rs` holds both equalities.
    fn zo_step_kernels(
        &mut self,
        b: &Batch,
        step: u64,
        timer: &mut PhaseTimer,
    ) -> Result<(f32, usize)> {
        let bsz = self.spec.batch;
        let (seed, eps) = (self.spec.seed, self.spec.eps);
        let (x, y) = (&b.x, &b.y_onehot);

        let t0 = std::time::Instant::now();
        let sparse = (self.spec.sparse_block > 0).then_some(kernels::SparseMask {
            layout: &self.zo_layout,
            block: self.spec.sparse_block,
            keep: self.spec.sparse_keep,
        });
        self.kz.prepare(seed, step, self.zo_total, sparse);
        kernels::apply_z(self.params, self.boundary, eps, self.kz.z());
        timer.add(Phase::ZoPerturb, t0.elapsed());

        let (fwd_plus, fwd_minus) = if let Some(aux) = self.aux.as_mut() {
            // snapshot θ₊, flip the live params to θ₋, then run both
            // forwards concurrently — forwards are pure, so the bits
            // match the sequential order exactly
            match &mut self.snap {
                Some(s) => s.clone_from(self.params),
                None => self.snap = Some(self.params.clone()),
            }
            let t0 = std::time::Instant::now();
            kernels::apply_z(self.params, self.boundary, -2.0 * eps, self.kz.z());
            timer.add(Phase::ZoPerturb, t0.elapsed());

            let t0 = std::time::Instant::now();
            let params: &ParamSet = self.params;
            let snap: &ParamSet = self.snap.as_ref().expect("snapshot just refreshed");
            let engine: &mut dyn Engine = &mut *self.engine;
            let (plus, minus) = std::thread::scope(|sc| {
                let h = sc.spawn(move || aux.forward(snap, x, y, bsz));
                let minus = engine.forward(params, x, y, bsz);
                (h.join().expect("±ε forward worker panicked"), minus)
            });
            timer.add(Phase::Forward, t0.elapsed());
            (plus?, minus?)
        } else {
            let t0 = std::time::Instant::now();
            let plus = self.engine.forward(self.params, x, y, bsz)?;
            timer.add(Phase::Forward, t0.elapsed());

            let t0 = std::time::Instant::now();
            kernels::apply_z(self.params, self.boundary, -2.0 * eps, self.kz.z());
            timer.add(Phase::ZoPerturb, t0.elapsed());

            let t0 = std::time::Instant::now();
            let minus = self.engine.forward(self.params, x, y, bsz)?;
            timer.add(Phase::Forward, t0.elapsed());
            (plus, minus)
        };

        let g = zo::projected_gradient(fwd_plus.loss, fwd_minus.loss, eps, self.spec.g_clip);
        let nclass = fwd_minus.logits.len() / bsz.max(1);
        let (correct, _) = accuracy(&fwd_minus.logits, &b.labels, bsz, nclass);

        // merged restore + ZO update: θ += (ε − ηg)z, replaying the cache
        let t0 = std::time::Instant::now();
        kernels::apply_z(self.params, self.boundary, eps - self.lr * g, self.kz.z());
        timer.add(Phase::ZoUpdate, t0.elapsed());

        if self.bp_tail > 0 {
            let t0 = std::time::Instant::now();
            let tails = self.engine.tail_grads(self.params, &fwd_minus, y, self.bp_tail, bsz)?;
            for (idx, grad) in tails {
                ops::axpy(-self.lr, &grad, &mut self.params.data[idx]);
            }
            timer.add(Phase::BpBackward, t0.elapsed());
        }

        Ok((0.5 * (fwd_plus.loss + fwd_minus.loss), correct))
    }
}

impl TrainSession for Fp32Session<'_> {
    fn label(&self) -> String {
        self.spec.label()
    }

    fn begin_epoch(&mut self, epoch: usize) -> f32 {
        self.lr = self.lr_sched.lr(epoch);
        self.lr
    }

    fn step(&mut self, b: &Batch, step_idx: u64, timer: &mut PhaseTimer) -> Result<StepOutcome> {
        match self.spec.method.bp_depth() {
            BpDepth::All => {
                let t0 = std::time::Instant::now();
                let out = self.engine.full_step(
                    self.params,
                    &b.x,
                    &b.y_onehot,
                    self.spec.batch,
                    self.lr,
                )?;
                timer.add(Phase::BpStep, t0.elapsed());
                let (correct, seen) = match &out.logits {
                    Some(logits) => {
                        let nclass = logits.len() / self.spec.batch.max(1);
                        let (c, t) = accuracy(logits, &b.labels, self.spec.batch, nclass);
                        (c, t)
                    }
                    None => (0, 0),
                };
                Ok(StepOutcome { loss: out.loss, correct, seen })
            }
            BpDepth::Tail(_) => {
                let (loss, correct) = if self.spec.kernels {
                    self.zo_step_kernels(b, step_idx, timer)?
                } else {
                    zo_step(self.engine, self.params, b, step_idx, self.lr, &self.spec, timer)?
                };
                Ok(StepOutcome { loss, correct, seen: self.spec.batch })
            }
        }
    }

    fn evaluate(&mut self, data: &Dataset) -> Result<(f32, f32)> {
        evaluate(self.engine, self.params, data, self.spec.batch)
    }

    fn set_bp_tail(&mut self, k: usize) -> Result<()> {
        use super::engine::Method;
        anyhow::ensure!(
            self.spec.method.bp_depth() != BpDepth::All,
            "cannot move the ZO/BP boundary of a full-bp run"
        );
        anyhow::ensure!(
            2 * k <= self.params.data.len(),
            "bp-tail={k} exceeds the {} tensors of this model",
            self.params.data.len()
        );
        self.spec.method = Method::Tail(k);
        self.boundary = self.params.zo_boundary(k);
        self.bp_tail = k;
        self.zo_layout = self.params.data[..self.boundary].iter().map(|t| t.len()).collect();
        self.zo_total = self.zo_layout.iter().sum();
        // the StepZ cache keys on (seed, step, len) and regenerates
        // itself when zo_total changes; only the fork needs a refresh
        // if the boundary just became nonempty
        if self.aux.is_none() && self.spec.kernels && self.boundary > 0 && kernels::hw_threads() > 1
        {
            self.aux = self.engine.fork();
        }
        Ok(())
    }

    fn snapshot(&self) -> Vec<checkpoint::CkptTensor> {
        checkpoint::params_to_tensors(self.params)
    }
}

/// Train with any method; returns per-epoch history + phase breakdown.
/// Thin wrapper: builds an [`Fp32Session`] and hands it to the one
/// generic loop in [`session::run`].
pub fn train(
    engine: &mut dyn Engine,
    params: &mut ParamSet,
    train_data: &Dataset,
    test_data: &Dataset,
    spec: &TrainSpec,
) -> Result<TrainResult> {
    train_from(engine, params, train_data, test_data, spec, None)
}

/// [`train`], continuing from a checkpoint's training state (the
/// caller has already restored `params` from the same checkpoint) —
/// the FP32 leg of `repro train --resume`.
pub fn train_from(
    engine: &mut dyn Engine,
    params: &mut ParamSet,
    train_data: &Dataset,
    test_data: &Dataset,
    spec: &TrainSpec,
    resume: Option<&TrainState>,
) -> Result<TrainResult> {
    let mut s = Fp32Session::new(engine, params, spec)?;
    session::run_from(&mut s, spec, train_data, test_data, resume)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Method;
    use crate::coordinator::native_engine::NativeEngine;
    use crate::coordinator::params::Model;
    use crate::data::synth_mnist;

    fn tiny_spec(method: Method, epochs: usize) -> TrainSpec {
        TrainSpec {
            method,
            epochs,
            batch: 16,
            lr0: if method == Method::FullBp { 0.02 } else { 1e-3 },
            eps: 1e-2,
            g_clip: 5.0,
            seed: 7,
            eval_every: 1,
            verbose: false,
            ..Default::default()
        }
    }

    #[test]
    fn full_bp_learns_quickly() {
        let train_d = synth_mnist::generate(256, 1);
        let test_d = synth_mnist::generate(128, 2);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::FullBp, 3))
            .unwrap();
        assert!(r.history.best_test_acc() > 0.5, "acc {}", r.history.best_test_acc());
        // loss must fall
        assert!(r.history.epochs[2].train_loss < r.history.epochs[0].train_loss);
    }

    #[test]
    fn full_bp_train_acc_is_live() {
        // regression: the fused full_step now returns logits, so the
        // Full-BP path reports train accuracy like every other cell of
        // the method×precision grid (closes the ROADMAP open item)
        let train_d = synth_mnist::generate(256, 61);
        let test_d = synth_mnist::generate(64, 62);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 63);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::FullBp, 2))
            .unwrap();
        let last = r.history.epochs.last().unwrap();
        assert!(
            last.train_acc > 0.0 && last.train_acc <= 1.0,
            "Full BP train_acc must be live, got {}",
            last.train_acc
        );
    }

    #[test]
    fn zo_step_reduces_loss_in_expectation() {
        // Full ZO is noisy; check the loss trend over a few epochs.
        let train_d = synth_mnist::generate(128, 4);
        let test_d = synth_mnist::generate(64, 5);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 6);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::FULL_ZO, 4))
            .unwrap();
        let first = r.history.epochs.first().unwrap().train_loss;
        let last = r.history.epochs.last().unwrap().train_loss;
        assert!(last < first, "ZO loss should trend down: {first} -> {last}");
    }

    #[test]
    fn cls1_trains_tail_and_zo() {
        let train_d = synth_mnist::generate(192, 8);
        let test_d = synth_mnist::generate(96, 9);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 10);
        let before_fc3 = params.data[8].clone();
        let before_conv1 = params.data[0].clone();
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::CLS1, 2))
            .unwrap();
        assert_ne!(params.data[8], before_fc3, "BP tail must move");
        assert_ne!(params.data[0], before_conv1, "ZO layers must move");
        assert!(r.timer.total(Phase::BpBackward).as_nanos() > 0);
        assert!(r.timer.total(Phase::ZoPerturb).as_nanos() > 0);
    }

    #[test]
    fn full_bp_times_under_bp_step_phase() {
        let train_d = synth_mnist::generate(64, 31);
        let test_d = synth_mnist::generate(32, 32);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 33);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::FullBp, 1))
            .unwrap();
        assert!(r.timer.total(Phase::BpStep).as_nanos() > 0);
        // the fused step must NOT be misfiled under Forward (only eval
        // forwards run in a Full-BP epoch, and those are Phase::Eval)
        assert_eq!(r.timer.total(Phase::Forward).as_nanos(), 0);
    }

    #[test]
    fn train_acc_is_computed_on_zo_paths() {
        let train_d = synth_mnist::generate(192, 41);
        let test_d = synth_mnist::generate(64, 42);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 43);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::CLS1, 2))
            .unwrap();
        let last = r.history.epochs.last().unwrap();
        assert!(
            last.train_acc > 0.0 && last.train_acc <= 1.0,
            "train_acc {}",
            last.train_acc
        );
    }

    #[test]
    fn stop_flag_cancels_between_epochs() {
        use crate::coordinator::control::{ProgressSink, StopFlag};
        let train_d = synth_mnist::generate(64, 51);
        let test_d = synth_mnist::generate(32, 52);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 53);
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        let spec = TrainSpec {
            // fire cancellation as soon as the first epoch reports
            progress: ProgressSink::new(move |e| {
                if e.epoch == 0 {
                    stop2.request_stop();
                }
            }),
            stop,
            ..tiny_spec(Method::FullBp, 50)
        };
        let r = train(&mut eng, &mut params, &train_d, &test_d, &spec).unwrap();
        assert!(r.stopped);
        assert_eq!(r.history.epochs.len(), 1, "must stop right after epoch 0");
    }

    #[test]
    fn forward_dominates_zo_time() {
        // paper Fig. 7: forward passes dominate the step time
        let train_d = synth_mnist::generate(64, 11);
        let test_d = synth_mnist::generate(32, 12);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 13);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_spec(Method::CLS1, 1))
            .unwrap();
        let fwd = r.timer.total(Phase::Forward).as_secs_f64();
        let zo = r.timer.total(Phase::ZoPerturb).as_secs_f64()
            + r.timer.total(Phase::ZoUpdate).as_secs_f64();
        assert!(fwd > zo, "forward {fwd} should dominate zo {zo}");
    }

    #[test]
    fn fp32_session_rejects_int8_spec() {
        use crate::coordinator::int8_trainer::ZoGradMode;
        use crate::coordinator::session::PrecisionSpec;
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 70);
        let spec = TrainSpec {
            precision: PrecisionSpec::int8(ZoGradMode::FloatCE),
            ..Default::default()
        };
        assert!(Fp32Session::new(&mut eng, &mut params, &spec).is_err());
    }
}
