//! FP32 training loop — paper Alg. 1 for all four methods (Full ZO,
//! ZO-Feat-Cls1/2, Full BP) over either engine.
//!
//! Per-minibatch ElasticZO step:
//!   1. sample the step seed (just the step counter mixed with the run
//!      seed — the 4-byte random seed of Alg. 1 line 3)
//!   2. perturb θ₁..θ_C by +εz, forward → ℓ₊
//!   3. perturb by −2εz, forward → ℓ₋
//!   4. g = clip((ℓ₊−ℓ₋)/2ε)
//!   5. perturb by (ε − ηg)z — merged restore+update (paper §4)
//!   6. BP the last L−C layers from the partition activation of the ℓ₋
//!      pass and apply SGD.

use super::control::{ProgressSink, StopFlag};
use super::engine::{Engine, Method};
use super::metrics::{EpochStats, History};
use super::params::ParamSet;
use super::schedules::LrSchedule;
use super::zo;
use crate::data::loader::{eval_batches, Loader};
use crate::data::Dataset;
use crate::nn::loss::accuracy;
use crate::telemetry::{Phase, PhaseTimer};
use crate::tensor::ops;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    pub epochs: usize,
    pub batch: usize,
    pub lr0: f32,
    pub eps: f32,
    pub g_clip: f32,
    pub seed: u64,
    /// Evaluate every N epochs (always evaluates the last).
    pub eval_every: usize,
    pub verbose: bool,
    /// Cooperative cancellation; polled between batches and epochs.
    pub stop: StopFlag,
    /// Live per-epoch progress callback (armed by the `serve` workers).
    pub progress: ProgressSink,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Cls1,
            epochs: 10,
            batch: 32,
            lr0: 1e-3,
            eps: 1e-2,
            // SPSA's projected gradient scales like √d·|∇L| (d ≈ 10⁵
            // here), so a tight clip is essential — the paper clips g
            // to stabilize training (§5.1.1).
            g_clip: 5.0,
            seed: 1,
            eval_every: 1,
            verbose: false,
            stop: StopFlag::default(),
            progress: ProgressSink::default(),
        }
    }
}

/// Outcome of a training run.
pub struct TrainResult {
    pub history: History,
    pub timer: PhaseTimer,
    /// True iff the run ended early because [`TrainConfig::stop`] fired.
    pub stopped: bool,
}

/// Evaluate mean loss and accuracy over a dataset.
pub fn evaluate(
    engine: &mut dyn Engine,
    params: &ParamSet,
    data: &Dataset,
    batch: usize,
) -> Result<(f32, f32)> {
    let nclass = data.nclass;
    let mut total_loss = 0.0f64;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut batches = 0usize;
    for b in eval_batches(data, batch) {
        let fwd = engine.forward(params, &b.x, &b.y_onehot, batch)?;
        let (c, t) = accuracy(&fwd.logits, &b.labels, b.bsz, nclass);
        correct += c;
        seen += t;
        total_loss += fwd.loss as f64;
        batches += 1;
    }
    Ok((
        (total_loss / batches.max(1) as f64) as f32,
        correct as f32 / seen.max(1) as f32,
    ))
}

/// One ElasticZO/FullZO minibatch step. Returns the step's train loss
/// and the number of correct predictions in this minibatch (from the
/// ℓ₋-pass logits, which the step already produces).
#[allow(clippy::too_many_arguments)]
pub fn zo_step(
    engine: &mut dyn Engine,
    params: &mut ParamSet,
    x: &[f32],
    y: &[f32],
    labels: &[u8],
    bsz: usize,
    step: u64,
    lr: f32,
    cfg: &TrainConfig,
    timer: &mut PhaseTimer,
) -> Result<(f32, usize)> {
    let bp_layers = cfg.method.bp_layers();
    let boundary = params.zo_boundary(bp_layers);
    let (seed, eps) = (cfg.seed, cfg.eps);

    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, eps);
    timer.add(Phase::ZoPerturb, t0.elapsed());

    let fwd_plus = {
        let t = std::time::Instant::now();
        let f = engine.forward(params, x, y, bsz)?;
        timer.add(Phase::Forward, t.elapsed());
        f
    };

    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, -2.0 * eps);
    timer.add(Phase::ZoPerturb, t0.elapsed());

    let fwd_minus = {
        let t = std::time::Instant::now();
        let f = engine.forward(params, x, y, bsz)?;
        timer.add(Phase::Forward, t.elapsed());
        f
    };

    let g = zo::projected_gradient(fwd_plus.loss, fwd_minus.loss, eps, cfg.g_clip);

    // train accuracy from the ℓ₋ logits (θ−εz is within O(ε) of θ, and
    // this pass's outputs are already in hand — no extra forward)
    let nclass = fwd_minus.logits.len() / bsz.max(1);
    let (correct, _) = accuracy(&fwd_minus.logits, labels, bsz, nclass);

    // merged restore + ZO update: θ += (ε − ηg)z
    let t0 = std::time::Instant::now();
    zo::perturb(params, boundary, seed, step, eps - lr * g);
    timer.add(Phase::ZoUpdate, t0.elapsed());

    // BP tail from the ℓ₋ pass activations (paper keeps perturbed-pass
    // activations to avoid a third forward)
    if bp_layers > 0 {
        let t0 = std::time::Instant::now();
        let tails = engine.tail_grads(params, &fwd_minus, y, bp_layers, bsz)?;
        for (idx, grad) in tails {
            ops::axpy(-lr, &grad, &mut params.data[idx]);
        }
        timer.add(Phase::BpBackward, t0.elapsed());
    }

    Ok((0.5 * (fwd_plus.loss + fwd_minus.loss), correct))
}

/// Train with any method; returns per-epoch history + phase breakdown.
pub fn train(
    engine: &mut dyn Engine,
    params: &mut ParamSet,
    train_data: &Dataset,
    test_data: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let mut history = History::new(cfg.method.label());
    let mut timer = PhaseTimer::new();
    let lr_sched = LrSchedule::paper_fp32(cfg.lr0, cfg.epochs);
    let mut step: u64 = 0;
    let mut stopped = false;

    'epochs: for epoch in 0..cfg.epochs {
        if cfg.stop.should_stop() {
            stopped = true;
            break;
        }
        let epoch_t0 = std::time::Instant::now();
        let lr = lr_sched.lr(epoch);
        let mut epoch_loss = 0.0f64;
        let mut nbatches = 0usize;
        let mut correct = 0usize;
        let mut seen = 0usize;

        let loader = Loader::new(train_data, cfg.batch, cfg.seed ^ 0xDA7A, epoch as u64);
        for b in loader {
            if cfg.stop.should_stop() {
                stopped = true;
                break 'epochs;
            }
            let loss = match cfg.method {
                Method::FullBp => {
                    let t0 = std::time::Instant::now();
                    let l = engine.full_step(params, &b.x, &b.y_onehot, cfg.batch, lr)?;
                    timer.add(Phase::BpStep, t0.elapsed());
                    l
                }
                _ => {
                    let (l, c) = zo_step(
                        engine, params, &b.x, &b.y_onehot, &b.labels, cfg.batch, step, lr,
                        cfg, &mut timer,
                    )?;
                    correct += c;
                    seen += cfg.batch;
                    l
                }
            };
            epoch_loss += loss as f64;
            nbatches += 1;
            step += 1;
        }

        let is_last = epoch + 1 == cfg.epochs;
        let (test_loss, test_acc) = if epoch % cfg.eval_every == 0 || is_last {
            let t0 = std::time::Instant::now();
            let r = evaluate(engine, params, test_data, cfg.batch)?;
            timer.add(Phase::Eval, t0.elapsed());
            r
        } else {
            let prev = history.epochs.last();
            (
                prev.map(|e| e.test_loss).unwrap_or(f32::NAN),
                prev.map(|e| e.test_acc).unwrap_or(0.0),
            )
        };

        let stats = EpochStats {
            epoch,
            train_loss: (epoch_loss / nbatches.max(1) as f64) as f32,
            test_loss,
            // Full BP steps through a fused engine call that exposes no
            // logits, so train accuracy is only available on ZO paths.
            train_acc: if seen > 0 { correct as f32 / seen as f32 } else { 0.0 },
            test_acc,
            lr,
            seconds: epoch_t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            println!(
                "[{}] epoch {:>3}  loss {:.4}  test_loss {:.4}  acc {:.2}%  train_acc {:.2}%  lr {:.5}",
                cfg.method.label(),
                epoch,
                stats.train_loss,
                stats.test_loss,
                stats.test_acc * 100.0,
                stats.train_acc * 100.0,
                lr
            );
        }
        cfg.progress.publish(&stats);
        history.push(stats);
    }

    Ok(TrainResult { history, timer, stopped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::native_engine::NativeEngine;
    use crate::coordinator::params::Model;
    use crate::data::synth_mnist;

    fn tiny_cfg(method: Method, epochs: usize) -> TrainConfig {
        TrainConfig {
            method,
            epochs,
            batch: 16,
            lr0: if method == Method::FullBp { 0.02 } else { 1e-3 },
            eps: 1e-2,
            g_clip: 5.0,
            seed: 7,
            eval_every: 1,
            verbose: false,
            ..Default::default()
        }
    }

    #[test]
    fn full_bp_learns_quickly() {
        let train_d = synth_mnist::generate(256, 1);
        let test_d = synth_mnist::generate(128, 2);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::FullBp, 3))
            .unwrap();
        assert!(r.history.best_test_acc() > 0.5, "acc {}", r.history.best_test_acc());
        // loss must fall
        assert!(r.history.epochs[2].train_loss < r.history.epochs[0].train_loss);
    }

    #[test]
    fn zo_step_reduces_loss_in_expectation() {
        // Full ZO is noisy; check the loss trend over a few epochs.
        let train_d = synth_mnist::generate(128, 4);
        let test_d = synth_mnist::generate(64, 5);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 6);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::FullZo, 4))
            .unwrap();
        let first = r.history.epochs.first().unwrap().train_loss;
        let last = r.history.epochs.last().unwrap().train_loss;
        assert!(last < first, "ZO loss should trend down: {first} -> {last}");
    }

    #[test]
    fn cls1_trains_tail_and_zo() {
        let train_d = synth_mnist::generate(192, 8);
        let test_d = synth_mnist::generate(96, 9);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 10);
        let before_fc3 = params.data[8].clone();
        let before_conv1 = params.data[0].clone();
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::Cls1, 2))
            .unwrap();
        assert_ne!(params.data[8], before_fc3, "BP tail must move");
        assert_ne!(params.data[0], before_conv1, "ZO layers must move");
        assert!(r.timer.total(Phase::BpBackward).as_nanos() > 0);
        assert!(r.timer.total(Phase::ZoPerturb).as_nanos() > 0);
    }

    #[test]
    fn full_bp_times_under_bp_step_phase() {
        let train_d = synth_mnist::generate(64, 31);
        let test_d = synth_mnist::generate(32, 32);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 33);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::FullBp, 1))
            .unwrap();
        assert!(r.timer.total(Phase::BpStep).as_nanos() > 0);
        // the fused step must NOT be misfiled under Forward (only eval
        // forwards run in a Full-BP epoch, and those are Phase::Eval)
        assert_eq!(r.timer.total(Phase::Forward).as_nanos(), 0);
    }

    #[test]
    fn train_acc_is_computed_on_zo_paths() {
        let train_d = synth_mnist::generate(192, 41);
        let test_d = synth_mnist::generate(64, 42);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 43);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::Cls1, 2))
            .unwrap();
        let last = r.history.epochs.last().unwrap();
        assert!(
            last.train_acc > 0.0 && last.train_acc <= 1.0,
            "train_acc {}",
            last.train_acc
        );
    }

    #[test]
    fn stop_flag_cancels_between_epochs() {
        use crate::coordinator::control::{ProgressSink, StopFlag};
        let train_d = synth_mnist::generate(64, 51);
        let test_d = synth_mnist::generate(32, 52);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 53);
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        let cfg = TrainConfig {
            // fire cancellation as soon as the first epoch reports
            progress: ProgressSink::new(move |e| {
                if e.epoch == 0 {
                    stop2.request_stop();
                }
            }),
            stop,
            ..tiny_cfg(Method::FullBp, 50)
        };
        let r = train(&mut eng, &mut params, &train_d, &test_d, &cfg).unwrap();
        assert!(r.stopped);
        assert_eq!(r.history.epochs.len(), 1, "must stop right after epoch 0");
    }

    #[test]
    fn forward_dominates_zo_time() {
        // paper Fig. 7: forward passes dominate the step time
        let train_d = synth_mnist::generate(64, 11);
        let test_d = synth_mnist::generate(32, 12);
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 13);
        let r = train(&mut eng, &mut params, &train_d, &test_d, &tiny_cfg(Method::Cls1, 1))
            .unwrap();
        let fwd = r.timer.total(Phase::Forward).as_secs_f64();
        let zo = r.timer.total(Phase::ZoPerturb).as_secs_f64()
            + r.timer.total(Phase::ZoUpdate).as_secs_f64();
        assert!(fwd > zo, "forward {fwd} should dominate zo {zo}");
    }
}
