//! Parameter store: the coordinator-owned, engine-agnostic weights.
//!
//! Tensors live in ABI order (the same order the AOT artifacts take
//! them); ZO trains the prefix, BP the suffix (paper Fig. 1).

use crate::rng::Rng64;

/// Which paper model a parameter set belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Model {
    LeNet,
    PointNet { npoints: usize, ncls: usize },
}

impl Model {
    pub fn parse(s: &str, npoints: usize, ncls: usize) -> anyhow::Result<Model> {
        match s {
            "lenet" => Ok(Model::LeNet),
            "pointnet" => Ok(Model::PointNet { npoints, ncls }),
            other => anyhow::bail!("unknown model '{other}'"),
        }
    }

    pub fn nclass(&self) -> usize {
        match self {
            Model::LeNet => 10,
            Model::PointNet { ncls, .. } => *ncls,
        }
    }

    /// `(name, shape)` list in ABI order.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        match self {
            Model::LeNet => crate::nn::lenet::PARAM_SPECS
                .iter()
                .map(|(n, s)| (n.to_string(), s.to_vec()))
                .collect(),
            Model::PointNet { ncls, .. } => crate::nn::pointnet::param_specs(*ncls),
        }
    }

    /// Deepest BP tail the model supports: the classifier (head FC)
    /// stack depth. Both paper models end in a 3-layer FC head; BP
    /// beyond it would cross the flatten/pooling stage, which the
    /// partition-activation ABI does not expose — use `full-bp` there.
    pub fn max_bp_tail(&self) -> usize {
        match self {
            Model::LeNet => crate::coordinator::engine::CLS_STACK,
            Model::PointNet { .. } => crate::coordinator::engine::CLS_STACK,
        }
    }

    /// Memory-model layer table (for Figs. 4–6).
    pub fn memory_layers(&self) -> Vec<crate::memory::LayerInfo> {
        match self {
            Model::LeNet => crate::memory::models::lenet_layers(),
            Model::PointNet { npoints, ncls } => {
                crate::memory::models::pointnet_layers(*npoints, *ncls)
            }
        }
    }
}

/// Named f32 parameter tensors in ABI order.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub model: Model,
    pub specs: Vec<(String, Vec<usize>)>,
    pub data: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Kaiming-uniform initialization (fan_in aware), deterministic.
    pub fn init(model: Model, seed: u64) -> ParamSet {
        let specs = model.param_specs();
        let mut rng = Rng64::new(seed ^ 0x1217);
        let data = specs
            .iter()
            .map(|(_, shape)| {
                let n: usize = shape.iter().product();
                let fan_in = match shape.len() {
                    4 => shape[1] * shape[2] * shape[3], // conv (OC,C,KH,KW)
                    2 => shape[0],                       // fc (K,N)
                    _ => n,
                };
                let mut v = vec![0.0f32; n];
                rng.fill_kaiming_uniform(&mut v, fan_in);
                v
            })
            .collect();
        ParamSet { model, specs, data }
    }

    pub fn num_tensors(&self) -> usize {
        self.data.len()
    }

    pub fn num_params(&self) -> usize {
        self.data.iter().map(|d| d.len()).sum()
    }

    /// Index of the first tensor trained by BP when the last `bp_layers`
    /// FC layers (w+b pairs) are BP-trained. Tensors `0..boundary` are ZO.
    pub fn zo_boundary(&self, bp_layers: usize) -> usize {
        assert!(
            2 * bp_layers <= self.num_tensors(),
            "bp tail {bp_layers} exceeds the {} tensors of {:?}",
            self.num_tensors(),
            self.model
        );
        self.num_tensors() - 2 * bp_layers
    }

    /// Number of scalar parameters trained by ZO for a partition.
    pub fn zo_param_count(&self, bp_layers: usize) -> usize {
        self.data[..self.zo_boundary(bp_layers)]
            .iter()
            .map(|d| d.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_counts_match_paper() {
        let p = ParamSet::init(Model::LeNet, 1);
        assert_eq!(p.num_params(), 107_786);
        assert_eq!(p.num_tensors(), 10);
        // one BP layer leaves 106,936 ZO params (paper's ZO-Feat-Cls2)
        assert_eq!(p.zo_param_count(1), 106_936);
        // two BP layers leave 96,772 (paper's ZO-Feat-Cls1)
        assert_eq!(p.zo_param_count(2), 96_772);
    }

    #[test]
    fn pointnet_tail_counts_match_paper() {
        let p = ParamSet::init(Model::PointNet { npoints: 128, ncls: 40 }, 1);
        let total = p.num_params();
        // BP tails are exact (paper): Cls2 (one layer) = 10,280;
        // Cls1 (two layers) = 141,608
        assert_eq!(total - p.zo_param_count(1), 10_280);
        assert_eq!(total - p.zo_param_count(2), 141_608);
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let a = ParamSet::init(Model::LeNet, 5);
        let b = ParamSet::init(Model::LeNet, 5);
        let c = ParamSet::init(Model::LeNet, 6);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn boundary_edges() {
        let p = ParamSet::init(Model::LeNet, 2);
        assert_eq!(p.zo_boundary(0), 10); // Full ZO: all tensors ZO
        assert_eq!(p.zo_boundary(1), 8);
        assert_eq!(p.zo_boundary(2), 6);
    }
}
