//! Seed-compressed data-parallel ZO — the replica-side driver.
//!
//! The ZO update is a pure function of `(run_seed, step)` plus one
//! loss-delta scalar, so N replicas can evaluate the ±ε perturbation on
//! disjoint shards of each global batch and exchange only
//! `(step, loss_delta)` records: the coordinator aggregates the deltas,
//! commits the projected gradient `g`, and every replica applies the
//! identical update `θ += −η·g·z(seed, step)` from its local RNG
//! stream. Bytes per step instead of parameter vectors.
//!
//! Bit-identity contract (what `tests/dp_e2e.rs` asserts):
//!
//! * Every replica — and the single-process reference run
//!   ([`DpLocalSession`]) — performs exactly ONE perturbation cycle per
//!   step, `+ε, −2ε, +ε`, regardless of how many shards it owns
//!   (forwards never mutate params). The cycle's f32 rounding residue
//!   is therefore identical everywhere, and params stay bitwise equal
//!   across any membership history.
//! * A replica that evaluates additional shards for a step whose cycle
//!   already ran ([`DpWorld::eval_extra`], the failover path) snapshots
//!   the ZO prefix and restores it exactly afterwards.
//! * A late joiner replays `+ε, −2ε, +ε, −η·g` per committed step from
//!   the commit log ([`DpWorld::catch_up`]) — no forwards needed — and
//!   lands on the same bits.
//! * Aggregation order is fixed (shard index ascending, f64
//!   accumulation) because f32 addition is not associative.
//!
//! The coordinator-side bookkeeping (shard leases, step barrier, quorum
//! rules, the `/cluster/dp/*` wire) lives in `serve::dp`; this module
//! is pure training math shared by the local reference, the remote
//! replica loop and the unit tests.

use super::engine::{Engine, Method};
use super::kernels;
use super::native_engine::NativeEngine;
use super::params::{Model, ParamSet};
use super::schedules::LrSchedule;
use super::session::{PrecisionSpec, StepOutcome, TrainResult, TrainSession, TrainSpec};
use super::{checkpoint, trainer, zo};
use crate::data::loader::{Batch, Shard};
use crate::data::Dataset;
use crate::nn::loss::accuracy;
use crate::nn::Forward;
use crate::telemetry::{Phase, PhaseTimer};
use crate::util::json::Value;
use anyhow::{Context, Result};

/// Upper bound on `dp.replicas` — the barrier state is O(replicas) per
/// step and a batch row per shard is required anyway.
pub const DP_MAX_REPLICAS: usize = 64;

/// How per-shard loss deltas combine into the committed gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpAggregate {
    /// Row-weighted mean of shard deltas — the estimator a single node
    /// would compute over the whole batch (up to f32 rounding).
    Mean,
    /// Plain sum of shard deltas (gradient scales with replica count).
    Sum,
}

impl DpAggregate {
    pub fn parse(s: &str) -> Result<DpAggregate> {
        match s {
            "mean" => Ok(DpAggregate::Mean),
            "sum" => Ok(DpAggregate::Sum),
            other => anyhow::bail!("unknown dp aggregate '{other}' (mean|sum)"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            DpAggregate::Mean => "mean",
            DpAggregate::Sum => "sum",
        }
    }
}

/// The dp mode of a job: shipped inside `JobSpec` as a nested
/// `"dp": {replicas, aggregate, min_replicas}` object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DpSpec {
    pub replicas: usize,
    pub aggregate: DpAggregate,
    /// Smallest surviving quorum allowed to absorb a lost replica's
    /// shard and keep the step barrier moving.
    pub min_replicas: usize,
}

impl DpSpec {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("replicas", Value::num(self.replicas as f64)),
            ("aggregate", Value::Str(self.aggregate.token().into())),
            ("min_replicas", Value::num(self.min_replicas as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<DpSpec> {
        let obj = v.as_obj().context("dp must be an object")?;
        let mut dp = DpSpec { replicas: 0, aggregate: DpAggregate::Mean, min_replicas: 1 };
        for (k, val) in obj {
            match k.as_str() {
                "replicas" => {
                    dp.replicas = val.as_i64().context("dp.replicas")? as usize;
                }
                "aggregate" => {
                    dp.aggregate =
                        DpAggregate::parse(val.as_str().context("dp.aggregate")?)?;
                }
                "min_replicas" => {
                    dp.min_replicas = val.as_i64().context("dp.min_replicas")? as usize;
                }
                other => anyhow::bail!("unknown dp key '{other}'"),
            }
        }
        if dp.replicas == 0 || dp.replicas > DP_MAX_REPLICAS {
            anyhow::bail!("dp.replicas must be in 1..={DP_MAX_REPLICAS}");
        }
        if dp.min_replicas == 0 || dp.min_replicas > dp.replicas {
            anyhow::bail!("dp.min_replicas must be in 1..=replicas");
        }
        Ok(dp)
    }
}

/// One shard's ±ε forward pair for one step — besides identifiers, the
/// entire per-step wire payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardEval {
    pub shard: usize,
    /// ℓ₊ − ℓ₋ on this shard's rows (the seed-compressed signal).
    pub delta: f32,
    /// ½(ℓ₊ + ℓ₋) — the shard's train-loss contribution.
    pub loss: f32,
    pub correct: usize,
    pub seen: usize,
}

impl ShardEval {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("shard", Value::num(self.shard as f64)),
            ("delta", Value::num(self.delta as f64)),
            ("loss", Value::num(self.loss as f64)),
            ("correct", Value::num(self.correct as f64)),
            ("seen", Value::num(self.seen as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ShardEval> {
        Ok(ShardEval {
            shard: v.get("shard").as_i64().context("report.shard")? as usize,
            delta: v.get("delta").as_f64().context("report.delta")? as f32,
            loss: v.get("loss").as_f64().context("report.loss")? as f32,
            correct: v.get("correct").as_i64().unwrap_or(0) as usize,
            seen: v.get("seen").as_i64().unwrap_or(0) as usize,
        })
    }
}

/// Aggregated step statistics across all shards of one global batch.
#[derive(Debug, Clone, Copy)]
pub struct DpAgg {
    pub delta: f32,
    pub loss: f32,
    pub correct: usize,
    pub seen: usize,
}

/// Combine a step's shard evals. `evals` MUST be sorted by shard index
/// and cover each shard exactly once — the fixed order plus f64
/// accumulation is what makes aggregation deterministic regardless of
/// which replica evaluated which shard.
pub fn aggregate(evals: &[ShardEval], agg: DpAggregate) -> DpAgg {
    debug_assert!(evals.windows(2).all(|w| w[0].shard < w[1].shard));
    let mut delta = 0.0f64;
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let mut seen = 0usize;
    for e in evals {
        let w = match agg {
            DpAggregate::Mean => e.seen as f64,
            DpAggregate::Sum => 1.0,
        };
        delta += w * e.delta as f64;
        loss += w * e.loss as f64;
        correct += e.correct;
        seen += e.seen;
    }
    if agg == DpAggregate::Mean && seen > 0 {
        delta /= seen as f64;
        loss /= seen as f64;
    }
    DpAgg { delta: delta as f32, loss: loss as f32, correct, seen }
}

/// Replica-side training state: the engine, the full parameter set and
/// the deterministic schedules — everything needed to evaluate shards
/// and apply commits. Identical on every replica by construction.
pub struct DpWorld {
    pub engine: Box<dyn Engine>,
    pub params: ParamSet,
    pub boundary: usize,
    pub spec: TrainSpec,
    pub dp: DpSpec,
    lr_sched: LrSchedule,
    pub steps_per_epoch: u64,
    /// Per-step cached perturbation (kernel path): one `z` generation
    /// serves the cycle's three legs plus the commit.
    kz: kernels::StepZ,
    /// Total elements in the ZO prefix (the `z` cache length).
    zo_len: usize,
}

impl DpWorld {
    /// Build a replica world. dp only supports Full-ZO / FP32 / native
    /// (`Config::validate` enforces the same), so the engine choice is
    /// fixed here.
    pub fn new(model: Model, spec: TrainSpec, dp: DpSpec, train_len: usize) -> Result<DpWorld> {
        // replicas replay the shared RNG stream over the WHOLE net, so a
        // nonzero BP tail would silently diverge across replicas; reject
        // anything but bp-tail=0 (Config::validate mirrors this) and
        // derive the boundary from the spec instead of hardcoding it
        let bp_tail = spec.method.bp_tail();
        if bp_tail != Some(0) || spec.precision != PrecisionSpec::Fp32 {
            anyhow::bail!(
                "dp requires method=full-zo (bp-tail=0) and precision=fp32; got method \
                 '{}', precision '{}'",
                spec.method.token(),
                spec.precision.token()
            );
        }
        anyhow::ensure!(
            spec.elastic.is_none(),
            "dp runs cannot move the ZO/BP boundary (use boundary=fixed)"
        );
        anyhow::ensure!(
            spec.sparse_block == 0,
            "sparse_block is not supported for dp (the commit log assumes dense z)"
        );
        let params = ParamSet::init(model, spec.seed ^ 0xC0FFEE);
        let boundary = params.zo_boundary(bp_tail.expect("checked above"));
        let zo_len: usize = params.data[..boundary].iter().map(|t| t.len()).sum();
        let lr_sched = LrSchedule::paper_fp32(spec.lr0, spec.epochs);
        let steps_per_epoch = train_len.div_ceil(spec.batch) as u64;
        Ok(DpWorld {
            engine: Box::new(NativeEngine::new(model)),
            params,
            boundary,
            spec,
            dp,
            lr_sched,
            steps_per_epoch,
            kz: kernels::StepZ::new(),
            zo_len,
        })
    }

    pub fn total_steps(&self) -> u64 {
        self.spec.epochs as u64 * self.steps_per_epoch
    }

    pub fn epoch_of(&self, step: u64) -> usize {
        (step / self.steps_per_epoch) as usize
    }

    pub fn lr_for_epoch(&self, epoch: usize) -> f32 {
        self.lr_sched.lr(epoch)
    }

    /// One perturbation leg: θ[..boundary] += scale·z(seed, step). The
    /// kernel path (`spec.kernels`) replays the step's cached `z` — one
    /// generation serves the cycle's three legs plus the commit — while
    /// the scalar path regenerates the stream per leg. Bit-identical
    /// either way; callers own the phase timing.
    fn perturb(&mut self, step: u64, scale: f32) {
        if self.spec.kernels {
            self.kz.prepare(self.spec.seed, step, self.zo_len, None);
            kernels::apply_z(&mut self.params, self.boundary, scale, self.kz.z());
        } else {
            zo::perturb(&mut self.params, self.boundary, self.spec.seed, step, scale);
        }
    }

    /// Forward every requested shard of `b` at the current params,
    /// returning each shard's minibatch alongside its forward. With the
    /// kernel path on, spare cores and a forkable engine, the extra
    /// shards run on scoped worker threads — forwards are pure, so the
    /// results match the sequential order bit-for-bit; only the
    /// `Phase::Forward` attribution becomes a joint wall-clock measure.
    fn shard_forwards(
        &mut self,
        b: &Batch,
        shards: &[usize],
        timer: &mut PhaseTimer,
    ) -> Result<Vec<(Batch, Forward)>> {
        let of = self.dp.replicas;
        let mbs: Vec<Batch> =
            shards.iter().map(|&s| b.shard(Shard { index: s, of })).collect();

        if self.spec.kernels && mbs.len() > 1 && kernels::hw_threads() > 1 {
            let mut workers: Vec<Box<dyn Engine + Send>> = Vec::with_capacity(mbs.len() - 1);
            for _ in 1..mbs.len() {
                match self.engine.fork() {
                    Some(w) => workers.push(w),
                    None => break,
                }
            }
            if workers.len() == mbs.len() - 1 {
                let t0 = std::time::Instant::now();
                let params = &self.params;
                let engine = self.engine.as_mut();
                let (first, rest) = std::thread::scope(|sc| {
                    let handles: Vec<_> = workers
                        .iter_mut()
                        .zip(&mbs[1..])
                        .map(|(w, mb)| {
                            sc.spawn(move || w.forward(params, &mb.x, &mb.y_onehot, mb.bsz))
                        })
                        .collect();
                    let first = engine.forward(params, &mbs[0].x, &mbs[0].y_onehot, mbs[0].bsz);
                    let rest: Vec<_> = handles
                        .into_iter()
                        .map(|h| h.join().expect("dp shard forward worker panicked"))
                        .collect();
                    (first, rest)
                });
                timer.add(Phase::Forward, t0.elapsed());
                let mut fwds = Vec::with_capacity(mbs.len());
                fwds.push(first?);
                for r in rest {
                    fwds.push(r?);
                }
                return Ok(mbs.into_iter().zip(fwds).collect());
            }
        }

        let mut out = Vec::with_capacity(mbs.len());
        for mb in mbs {
            let t = std::time::Instant::now();
            let fwd = self.engine.forward(&self.params, &mb.x, &mb.y_onehot, mb.bsz)?;
            timer.add(Phase::Forward, t.elapsed());
            out.push((mb, fwd));
        }
        Ok(out)
    }

    /// The ±ε evaluation cycle for `shards` of global batch `b` at
    /// `step`. Exactly three perturbs regardless of shard count, so
    /// every replica traverses the same f32 rounding path.
    pub fn eval_cycle(
        &mut self,
        b: &Batch,
        step: u64,
        shards: &[usize],
        timer: &mut PhaseTimer,
    ) -> Result<Vec<ShardEval>> {
        let eps = self.spec.eps;

        let t0 = std::time::Instant::now();
        self.perturb(step, eps);
        timer.add(Phase::ZoPerturb, t0.elapsed());
        let plus = self.shard_forwards(b, shards, timer)?;

        let t0 = std::time::Instant::now();
        self.perturb(step, -2.0 * eps);
        timer.add(Phase::ZoPerturb, t0.elapsed());
        let minus = self.shard_forwards(b, shards, timer)?;

        let mut out = Vec::with_capacity(shards.len());
        for (&s, ((mb, fp), (_, fm))) in shards.iter().zip(plus.iter().zip(&minus)) {
            let nclass = fm.logits.len() / mb.bsz.max(1);
            let (correct, seen) = accuracy(&fm.logits, &mb.labels, mb.bsz, nclass);
            out.push(ShardEval {
                shard: s,
                delta: fp.loss - fm.loss,
                loss: 0.5 * (fp.loss + fm.loss),
                correct,
                seen,
            });
        }

        // restore leg of the cycle (the commit applies −η·g·z later,
        // once the aggregated delta comes back)
        let t0 = std::time::Instant::now();
        self.perturb(step, eps);
        timer.add(Phase::ZoPerturb, t0.elapsed());
        Ok(out)
    }

    /// Evaluate additional shards for a step whose cycle already ran
    /// (a just-absorbed shard of a lost replica): snapshot the ZO
    /// prefix, rerun the cycle for the new shards, restore bit-exactly.
    pub fn eval_extra(
        &mut self,
        b: &Batch,
        step: u64,
        shards: &[usize],
        timer: &mut PhaseTimer,
    ) -> Result<Vec<ShardEval>> {
        let saved: Vec<Vec<f32>> = self.params.data[..self.boundary].to_vec();
        let out = self.eval_cycle(b, step, shards, timer)?;
        for (dst, src) in self.params.data[..self.boundary].iter_mut().zip(saved) {
            *dst = src;
        }
        Ok(out)
    }

    /// Apply a committed step: θ += −η(epoch)·g·z(seed, step).
    pub fn apply_commit(&mut self, step: u64, g: f32, timer: &mut PhaseTimer) {
        let lr = self.lr_for_epoch(self.epoch_of(step));
        let t0 = std::time::Instant::now();
        self.perturb(step, -(lr * g));
        timer.add(Phase::ZoUpdate, t0.elapsed());
    }

    /// Replay committed steps `from..from+commits.len()` without any
    /// forwards: each step is the cycle's three perturbs (their rounding
    /// residue is part of the trajectory) plus the commit itself. A late
    /// joiner lands on the same bits as replicas that trained through.
    pub fn catch_up(&mut self, from: u64, commits: &[f32], timer: &mut PhaseTimer) {
        let eps = self.spec.eps;
        for (i, &g) in commits.iter().enumerate() {
            let step = from + i as u64;
            self.perturb(step, eps);
            self.perturb(step, -2.0 * eps);
            self.perturb(step, eps);
            self.apply_commit(step, g, timer);
        }
    }

    pub fn evaluate(&mut self, data: &Dataset) -> Result<(f32, f32)> {
        trainer::evaluate(self.engine.as_mut(), &self.params, data, self.spec.batch)
    }

    pub fn snapshot(&self) -> Vec<checkpoint::CkptTensor> {
        checkpoint::params_to_tensors(&self.params)
    }
}

/// Single-process dp run: all N shards evaluated locally, one cycle per
/// step — the bit-identity reference for the distributed path, and what
/// `launch::run` executes when a dp job lands on a local worker.
pub struct DpLocalSession {
    pub world: DpWorld,
}

impl DpLocalSession {
    pub fn new(world: DpWorld) -> DpLocalSession {
        DpLocalSession { world }
    }
}

impl TrainSession for DpLocalSession {
    fn label(&self) -> String {
        format!("{} dp{}", self.world.spec.label(), self.world.dp.replicas)
    }

    fn begin_epoch(&mut self, epoch: usize) -> f32 {
        self.world.lr_for_epoch(epoch)
    }

    fn step(&mut self, b: &Batch, step_idx: u64, timer: &mut PhaseTimer) -> Result<StepOutcome> {
        let shards: Vec<usize> = (0..self.world.dp.replicas).collect();
        let evals = self.world.eval_cycle(b, step_idx, &shards, timer)?;
        let agg = aggregate(&evals, self.world.dp.aggregate);
        let g = zo::projected_gradient_from_delta(
            agg.delta,
            self.world.spec.eps,
            self.world.spec.g_clip,
        );
        self.world.apply_commit(step_idx, g, timer);
        Ok(StepOutcome { loss: agg.loss, correct: agg.correct, seen: agg.seen })
    }

    fn evaluate(&mut self, data: &Dataset) -> Result<(f32, f32)> {
        self.world.evaluate(data)
    }

    fn verbose_note(&self) -> String {
        format!(
            "dp=local replicas={} agg={}",
            self.world.dp.replicas,
            self.world.dp.aggregate.token()
        )
    }

    fn snapshot(&self) -> Vec<checkpoint::CkptTensor> {
        self.world.snapshot()
    }
}

/// The [`TrainState`](checkpoint::TrainState) a finished dp run saves —
/// shared by the local reference and the distributed primary so final
/// checkpoints compare bit-identically.
pub fn final_dp_state(
    spec: &TrainSpec,
    result: &TrainResult,
) -> checkpoint::TrainState {
    super::session::final_state(spec, result, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::Loader;
    use crate::data::synth_mnist;

    fn spec(epochs: usize, batch: usize) -> TrainSpec {
        TrainSpec {
            method: Method::FULL_ZO,
            epochs,
            batch,
            seed: 11,
            ..TrainSpec::default()
        }
    }

    fn dp(n: usize) -> DpSpec {
        DpSpec { replicas: n, aggregate: DpAggregate::Mean, min_replicas: 1 }
    }

    #[test]
    fn dp_spec_json_roundtrip() {
        let d = DpSpec { replicas: 4, aggregate: DpAggregate::Sum, min_replicas: 2 };
        let back = DpSpec::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
        assert!(DpSpec::from_json(&Value::obj(vec![("replicas", Value::num(0.0))])).is_err());
    }

    #[test]
    fn aggregate_is_order_fixed_and_row_weighted() {
        let evals = [
            ShardEval { shard: 0, delta: 0.4, loss: 1.0, correct: 3, seen: 4 },
            ShardEval { shard: 1, delta: -0.2, loss: 2.0, correct: 1, seen: 2 },
        ];
        let mean = aggregate(&evals, DpAggregate::Mean);
        // row-weighted: (4·0.4 + 2·(−0.2)) / 6
        assert!((mean.delta - 0.2).abs() < 1e-6);
        assert_eq!((mean.correct, mean.seen), (4, 6));
        let sum = aggregate(&evals, DpAggregate::Sum);
        assert!((sum.delta - 0.2f32).abs() < 1e-6);
        assert!((sum.loss - 3.0).abs() < 1e-6);
    }

    /// The heart of the dp design: a world that evaluates only its own
    /// shards (restoring around extra evals) and applies commits stays
    /// bitwise identical to the all-shards reference, and a late joiner
    /// catches up to the same bits from the commit log alone.
    #[test]
    fn shard_subsets_and_catch_up_are_bit_identical() {
        let data = synth_mnist::generate(48, 3);
        let s = spec(1, 16);
        let mut reference = DpWorld::new(Model::LeNet, s.clone(), dp(2), data.len()).unwrap();
        let mut partial = DpWorld::new(Model::LeNet, s.clone(), dp(2), data.len()).unwrap();
        let mut timer = PhaseTimer::new();
        let mut commits = Vec::new();

        for (i, b) in Loader::new(&data, 16, s.seed ^ 0xDA7A, 0).enumerate() {
            let step = i as u64;
            let evals = reference.eval_cycle(&b, step, &[0, 1], &mut timer).unwrap();
            let agg = aggregate(&evals, DpAggregate::Mean);
            let g = zo::projected_gradient_from_delta(agg.delta, s.eps, s.g_clip);
            reference.apply_commit(step, g, &mut timer);
            commits.push(g);

            // replica that owns shard 0, then absorbs shard 1 mid-step
            let e0 = partial.eval_cycle(&b, step, &[0], &mut timer).unwrap();
            let e1 = partial.eval_extra(&b, step, &[1], &mut timer).unwrap();
            assert_eq!(e0[0], evals[0]);
            assert_eq!(e1[0], evals[1]);
            partial.apply_commit(step, g, &mut timer);
        }

        assert_eq!(reference.params.data, partial.params.data);

        let mut joiner = DpWorld::new(Model::LeNet, s, dp(2), data.len()).unwrap();
        joiner.catch_up(0, &commits, &mut timer);
        assert_eq!(reference.params.data, joiner.params.data);
    }

    #[test]
    fn local_session_trains_and_snapshots() {
        let data = synth_mnist::generate(32, 4);
        let test = synth_mnist::generate(16, 5);
        let s = spec(2, 8);
        let world = DpWorld::new(Model::LeNet, s.clone(), dp(4), data.len()).unwrap();
        let mut sess = DpLocalSession::new(world);
        let result = crate::coordinator::session::run(&mut sess, &s, &data, &test).unwrap();
        assert_eq!(result.history.epochs.len(), 2);
        assert_eq!(result.steps_done, 2 * 4, "32 samples / batch 8 over 2 epochs");
        assert!(sess.label().contains("dp4"));
        assert!(!sess.snapshot().is_empty());
    }
}
