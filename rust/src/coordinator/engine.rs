//! The `Engine` abstraction: forward / tail-BP / full-BP execution,
//! implemented twice (XLA artifacts vs native rust) per DESIGN.md §2.

use super::params::ParamSet;
use crate::nn::{Forward, TailGrads};
use anyhow::Result;

/// Outcome of a fused full-BP step ([`Engine::full_step`]).
#[derive(Debug, Clone)]
pub struct StepOut {
    /// Pre-step minibatch loss.
    pub loss: f32,
    /// Pre-step logits (`bsz * nclass`, row-major) when the backend
    /// exposes them. The native engine always does; XLA AOT artifact
    /// sets compiled before the logits output was added return `None`
    /// (train accuracy then stays unreported for Full BP, never wrong).
    pub logits: Option<Vec<f32>>,
}

/// FP32 execution engine.
pub trait Engine {
    /// Forward + loss; also returns the partition activations.
    fn forward(&mut self, params: &ParamSet, x: &[f32], y: &[f32], bsz: usize) -> Result<Forward>;

    /// Gradients of the last `k` ∈ {1,2} FC layers given partition
    /// activations from a previous `forward`.
    fn tail_grads(
        &mut self,
        params: &ParamSet,
        fwd: &Forward,
        y: &[f32],
        k: usize,
        bsz: usize,
    ) -> Result<TailGrads>;

    /// One full-BP SGD step, in place. Returns the pre-step loss and
    /// (when available) the pre-step logits.
    fn full_step(
        &mut self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        bsz: usize,
        lr: f32,
    ) -> Result<StepOut>;

    /// Human-readable engine name (for logs/EXPERIMENTS.md).
    fn name(&self) -> &'static str;

    /// A second, independent handle onto the same compute backend, for
    /// running the ±ε pair (or dp shard evals) on scoped worker threads.
    /// `None` (the default) means the backend cannot be shared and the
    /// caller stays sequential; `Some` guarantees the fork's `forward`
    /// is bit-identical to the original's.
    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        None
    }
}

/// Which engine to instantiate (config-level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Xla,
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "native" => Ok(EngineKind::Native),
            other => anyhow::bail!("unknown engine '{other}' (want xla|native)"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }
}

/// How deep backprop reaches for a method — the ZO/BP partition, made
/// unambiguous (no `usize::MAX` sentinel for "everything").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BpDepth {
    /// BP trains only the last `k` FC layers (`k = 0` ⇒ pure ZO); ZO
    /// trains everything before the partition.
    Tail(usize),
    /// Full backprop over every layer — there is no ZO partition, and
    /// no ZO boundary may be derived from this variant.
    All,
}

/// Number of classifier (head FC) layers the paper's `cls<n>` naming
/// counts against: `cls<n>` trains the feature extractor plus `n` of
/// the 3 head layers by ZO, i.e. BP on the remaining `3 − n`.
pub const CLS_STACK: usize = 3;

/// Training method — the ZO/BP split as a first-class runtime value.
///
/// `Tail(k)` backpropagates through the last `k` classifier FC layers
/// and trains everything before the partition by ZO; `k = 0` is pure
/// ZO and `FullBp` is ordinary backprop over every layer. The paper's
/// four presets are aliases ([`Method::FULL_ZO`], [`Method::CLS2`],
/// [`Method::CLS1`], [`Method::FullBp`]).
///
/// Naming follows the paper §5.1.1: the `cls<n>` suffix counts the
/// *classifier* FC layers trained by **ZO** (together with the feature
/// extractor): ZO-Feat-Cls1 trains conv+fc1 by ZO → BP on the last TWO
/// FC layers (96,772 ZO params for LeNet); ZO-Feat-Cls2 trains
/// conv+fc1+fc2 by ZO → BP on the last ONE (106,936 ZO params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// ZO everywhere except BP on the last `k` classifier FC layers.
    Tail(usize),
    FullBp,
}

impl Method {
    /// Pure ZO (`Tail(0)`): the paper's "Full ZO".
    pub const FULL_ZO: Method = Method::Tail(0);
    /// ZO-Feat-Cls2: BP on the last FC layer only.
    pub const CLS2: Method = Method::Tail(1);
    /// ZO-Feat-Cls1: BP on the last two FC layers.
    pub const CLS1: Method = Method::Tail(2);

    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "full-zo" | "zo" => return Ok(Method::FULL_ZO),
            "zo-feat-cls1" => return Ok(Method::CLS1),
            "zo-feat-cls2" => return Ok(Method::CLS2),
            "full-bp" | "bp" => return Ok(Method::FullBp),
            _ => {}
        }
        if let Some(n) = s.strip_prefix("cls").and_then(|n| n.parse::<usize>().ok()) {
            // paper naming counts ZO-trained head layers: cls<n> ⇒ BP
            // on the remaining CLS_STACK − n
            anyhow::ensure!(
                n < CLS_STACK,
                "cls{n} exceeds the {CLS_STACK}-layer classifier stack (use full-zo for cls{CLS_STACK})"
            );
            return Ok(Method::Tail(CLS_STACK - n));
        }
        if let Some(k) = s.strip_prefix("bp-tail=").and_then(|k| k.parse::<usize>().ok()) {
            return Ok(Method::Tail(k));
        }
        anyhow::bail!("unknown method '{other}' (full-zo|cls<n>|bp-tail=<k>|full-bp)", other = s)
    }

    /// The ZO/BP partition for this method.
    pub fn bp_depth(&self) -> BpDepth {
        match self {
            Method::Tail(k) => BpDepth::Tail(*k),
            Method::FullBp => BpDepth::All,
        }
    }

    /// The BP-tail depth `k`, or `None` for Full BP (no ZO partition).
    pub fn bp_tail(&self) -> Option<usize> {
        match self {
            Method::Tail(k) => Some(*k),
            Method::FullBp => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Method::FULL_ZO => "Full ZO".to_string(),
            Method::CLS1 => "ZO-Feat-Cls1".to_string(),
            Method::CLS2 => "ZO-Feat-Cls2".to_string(),
            Method::FullBp => "Full BP".to_string(),
            Method::Tail(k) => format!("ZO-BP-Tail{k}"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`. The four
    /// paper presets keep their legacy tokens byte-for-byte (checkpoint
    /// spec identity, wire compatibility); deeper tails serialize as
    /// `bp-tail=<k>`.
    pub fn token(&self) -> String {
        match self {
            Method::FULL_ZO => "full-zo".to_string(),
            Method::CLS2 => "cls2".to_string(),
            Method::CLS1 => "cls1".to_string(),
            Method::FullBp => "full-bp".to_string(),
            Method::Tail(k) => format!("bp-tail={k}"),
        }
    }

    /// The paper's four presets, in memory order (shallow → deep BP).
    pub const ALL: [Method; 4] = [Method::FULL_ZO, Method::CLS2, Method::CLS1, Method::FullBp];

    /// Memory-model mapping, derived from the ZO/BP partition.
    pub fn memory_method(&self) -> crate::memory::Method {
        match self.bp_depth() {
            BpDepth::All => crate::memory::Method::FullBp,
            BpDepth::Tail(0) => crate::memory::Method::FullZo,
            BpDepth::Tail(k) => crate::memory::Method::Elastic { bp_layers: k },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_and_depth() {
        assert_eq!(Method::parse("full-zo").unwrap(), Method::FULL_ZO);
        // paper naming: Cls1 -> BP on TWO layers, Cls2 -> BP on ONE
        assert_eq!(Method::parse("cls1").unwrap().bp_depth(), BpDepth::Tail(2));
        assert_eq!(Method::parse("zo-feat-cls2").unwrap().bp_depth(), BpDepth::Tail(1));
        // Full BP is not a ZO boundary — it is its own variant
        assert_eq!(Method::FullBp.bp_depth(), BpDepth::All);
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn generalized_tail_tokens_parse_and_alias_legacy_spellings() {
        // bp-tail=<k> is the canonical generalized spelling; the legacy
        // preset tokens are bitwise-equivalent aliases of k ∈ {0,1,2}
        assert_eq!(Method::parse("bp-tail=0").unwrap(), Method::FULL_ZO);
        assert_eq!(Method::parse("bp-tail=1").unwrap(), Method::CLS2);
        assert_eq!(Method::parse("bp-tail=2").unwrap(), Method::CLS1);
        assert_eq!(Method::parse("bp-tail=3").unwrap(), Method::Tail(3));
        // generalized cls<n>: n head layers trained by ZO ⇒ BP on 3−n;
        // cls3 stays rejected (its canonical spelling is full-zo)
        assert_eq!(Method::parse("cls0").unwrap(), Method::Tail(3));
        assert!(Method::parse("cls3").is_err(), "use full-zo for cls3");
        assert!(Method::parse("cls4").is_err(), "beyond the classifier stack");
        assert!(Method::parse("bp-tail=").is_err());
        // presets keep their legacy tokens byte-for-byte; deep tails
        // serialize canonically
        assert_eq!(Method::Tail(3).token(), "bp-tail=3");
        assert_eq!(Method::parse(&Method::Tail(3).token()).unwrap(), Method::Tail(3));
        assert_eq!(Method::Tail(3).label(), "ZO-BP-Tail3");
        assert_eq!(Method::Tail(3).bp_tail(), Some(3));
        assert_eq!(Method::FullBp.bp_tail(), None);
        assert_eq!(
            Method::Tail(3).memory_method(),
            crate::memory::Method::Elastic { bp_layers: 3 }
        );
    }

    #[test]
    fn zo_param_counts_match_paper_per_method() {
        use crate::coordinator::params::{Model, ParamSet};
        let p = ParamSet::init(Model::LeNet, 1);
        // paper §5.1.1: Cls1 trains 96,772 params by ZO, Cls2 106,936
        assert_eq!(p.zo_param_count(2), 96_772);
        assert_eq!(p.zo_param_count(1), 106_936);
    }

    #[test]
    fn memory_method_follows_partition() {
        use crate::memory;
        assert_eq!(Method::FULL_ZO.memory_method(), memory::Method::FullZo);
        assert_eq!(
            Method::CLS2.memory_method(),
            memory::Method::Elastic { bp_layers: 1 }
        );
        assert_eq!(
            Method::CLS1.memory_method(),
            memory::Method::Elastic { bp_layers: 2 }
        );
        assert_eq!(Method::FullBp.memory_method(), memory::Method::FullBp);
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn labels_match_paper_tables() {
        assert_eq!(Method::FULL_ZO.label(), "Full ZO");
        assert_eq!(Method::CLS1.label(), "ZO-Feat-Cls1");
    }

    #[test]
    fn tokens_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(&m.token()).unwrap(), m);
        }
        for e in [EngineKind::Xla, EngineKind::Native] {
            assert_eq!(EngineKind::parse(e.token()).unwrap(), e);
        }
    }
}
