//! The `Engine` abstraction: forward / tail-BP / full-BP execution,
//! implemented twice (XLA artifacts vs native rust) per DESIGN.md §2.

use super::params::ParamSet;
use crate::nn::{Forward, TailGrads};
use anyhow::Result;

/// FP32 execution engine.
pub trait Engine {
    /// Forward + loss; also returns the partition activations.
    fn forward(&mut self, params: &ParamSet, x: &[f32], y: &[f32], bsz: usize) -> Result<Forward>;

    /// Gradients of the last `k` ∈ {1,2} FC layers given partition
    /// activations from a previous `forward`.
    fn tail_grads(
        &mut self,
        params: &ParamSet,
        fwd: &Forward,
        y: &[f32],
        k: usize,
        bsz: usize,
    ) -> Result<TailGrads>;

    /// One full-BP SGD step, in place. Returns the pre-step loss.
    fn full_step(
        &mut self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        bsz: usize,
        lr: f32,
    ) -> Result<f32>;

    /// Human-readable engine name (for logs/EXPERIMENTS.md).
    fn name(&self) -> &'static str;
}

/// Which engine to instantiate (config-level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Xla,
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "native" => Ok(EngineKind::Native),
            other => anyhow::bail!("unknown engine '{other}' (want xla|native)"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }
}

/// Training method — the paper's four configurations.
///
/// Naming follows the paper §5.1.1: the suffix counts the *classifier*
/// FC layers trained by **ZO** (together with the feature extractor):
/// ZO-Feat-Cls1 trains conv+fc1 by ZO → BP on the last TWO FC layers
/// (96,772 ZO params for LeNet); ZO-Feat-Cls2 trains conv+fc1+fc2 by
/// ZO → BP on the last ONE (106,936 ZO params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    FullZo,
    /// ZO-Feat-Cls1: BP on the last two FC layers.
    Cls1,
    /// ZO-Feat-Cls2: BP on the last FC layer only.
    Cls2,
    FullBp,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "full-zo" | "zo" => Ok(Method::FullZo),
            "cls1" | "zo-feat-cls1" => Ok(Method::Cls1),
            "cls2" | "zo-feat-cls2" => Ok(Method::Cls2),
            "full-bp" | "bp" => Ok(Method::FullBp),
            other => anyhow::bail!("unknown method '{other}' (full-zo|cls1|cls2|full-bp)"),
        }
    }

    /// Number of trailing FC layers trained by BP.
    pub fn bp_layers(&self) -> usize {
        match self {
            Method::FullZo => 0,
            Method::Cls2 => 1,
            Method::Cls1 => 2,
            Method::FullBp => usize::MAX, // all — handled specially
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::FullZo => "Full ZO",
            Method::Cls1 => "ZO-Feat-Cls1",
            Method::Cls2 => "ZO-Feat-Cls2",
            Method::FullBp => "Full BP",
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            Method::FullZo => "full-zo",
            Method::Cls1 => "cls1",
            Method::Cls2 => "cls2",
            Method::FullBp => "full-bp",
        }
    }

    pub const ALL: [Method; 4] = [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp];

    /// Memory-model mapping.
    pub fn memory_method(&self) -> crate::memory::Method {
        match self {
            Method::FullZo => crate::memory::Method::FullZo,
            Method::Cls2 => crate::memory::Method::Elastic { bp_layers: 1 },
            Method::Cls1 => crate::memory::Method::Elastic { bp_layers: 2 },
            Method::FullBp => crate::memory::Method::FullBp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_and_layers() {
        assert_eq!(Method::parse("full-zo").unwrap(), Method::FullZo);
        // paper naming: Cls1 -> BP on TWO layers, Cls2 -> BP on ONE
        assert_eq!(Method::parse("cls1").unwrap().bp_layers(), 2);
        assert_eq!(Method::parse("zo-feat-cls2").unwrap().bp_layers(), 1);
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn zo_param_counts_match_paper_per_method() {
        use crate::coordinator::params::{Model, ParamSet};
        let p = ParamSet::init(Model::LeNet, 1);
        // paper §5.1.1: Cls1 trains 96,772 params by ZO, Cls2 106,936
        assert_eq!(p.zo_param_count(Method::Cls1.bp_layers()), 96_772);
        assert_eq!(p.zo_param_count(Method::Cls2.bp_layers()), 106_936);
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn labels_match_paper_tables() {
        assert_eq!(Method::FullZo.label(), "Full ZO");
        assert_eq!(Method::Cls1.label(), "ZO-Feat-Cls1");
    }

    #[test]
    fn tokens_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.token()).unwrap(), m);
        }
        for e in [EngineKind::Xla, EngineKind::Native] {
            assert_eq!(EngineKind::parse(e.token()).unwrap(), e);
        }
    }
}
