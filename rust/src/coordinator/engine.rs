//! The `Engine` abstraction: forward / tail-BP / full-BP execution,
//! implemented twice (XLA artifacts vs native rust) per DESIGN.md §2.

use super::params::ParamSet;
use crate::nn::{Forward, TailGrads};
use anyhow::Result;

/// Outcome of a fused full-BP step ([`Engine::full_step`]).
#[derive(Debug, Clone)]
pub struct StepOut {
    /// Pre-step minibatch loss.
    pub loss: f32,
    /// Pre-step logits (`bsz * nclass`, row-major) when the backend
    /// exposes them. The native engine always does; XLA AOT artifact
    /// sets compiled before the logits output was added return `None`
    /// (train accuracy then stays unreported for Full BP, never wrong).
    pub logits: Option<Vec<f32>>,
}

/// FP32 execution engine.
pub trait Engine {
    /// Forward + loss; also returns the partition activations.
    fn forward(&mut self, params: &ParamSet, x: &[f32], y: &[f32], bsz: usize) -> Result<Forward>;

    /// Gradients of the last `k` ∈ {1,2} FC layers given partition
    /// activations from a previous `forward`.
    fn tail_grads(
        &mut self,
        params: &ParamSet,
        fwd: &Forward,
        y: &[f32],
        k: usize,
        bsz: usize,
    ) -> Result<TailGrads>;

    /// One full-BP SGD step, in place. Returns the pre-step loss and
    /// (when available) the pre-step logits.
    fn full_step(
        &mut self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        bsz: usize,
        lr: f32,
    ) -> Result<StepOut>;

    /// Human-readable engine name (for logs/EXPERIMENTS.md).
    fn name(&self) -> &'static str;

    /// A second, independent handle onto the same compute backend, for
    /// running the ±ε pair (or dp shard evals) on scoped worker threads.
    /// `None` (the default) means the backend cannot be shared and the
    /// caller stays sequential; `Some` guarantees the fork's `forward`
    /// is bit-identical to the original's.
    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        None
    }
}

/// Which engine to instantiate (config-level).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Xla,
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "native" => Ok(EngineKind::Native),
            other => anyhow::bail!("unknown engine '{other}' (want xla|native)"),
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }
}

/// How deep backprop reaches for a method — the ZO/BP partition, made
/// unambiguous (no `usize::MAX` sentinel for "everything").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BpDepth {
    /// BP trains only the last `k` FC layers (`k = 0` ⇒ pure ZO); ZO
    /// trains everything before the partition.
    Tail(usize),
    /// Full backprop over every layer — there is no ZO partition, and
    /// no ZO boundary may be derived from this variant.
    All,
}

/// Training method — the paper's four configurations.
///
/// Naming follows the paper §5.1.1: the suffix counts the *classifier*
/// FC layers trained by **ZO** (together with the feature extractor):
/// ZO-Feat-Cls1 trains conv+fc1 by ZO → BP on the last TWO FC layers
/// (96,772 ZO params for LeNet); ZO-Feat-Cls2 trains conv+fc1+fc2 by
/// ZO → BP on the last ONE (106,936 ZO params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    FullZo,
    /// ZO-Feat-Cls1: BP on the last two FC layers.
    Cls1,
    /// ZO-Feat-Cls2: BP on the last FC layer only.
    Cls2,
    FullBp,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "full-zo" | "zo" => Ok(Method::FullZo),
            "cls1" | "zo-feat-cls1" => Ok(Method::Cls1),
            "cls2" | "zo-feat-cls2" => Ok(Method::Cls2),
            "full-bp" | "bp" => Ok(Method::FullBp),
            other => anyhow::bail!("unknown method '{other}' (full-zo|cls1|cls2|full-bp)"),
        }
    }

    /// The ZO/BP partition for this method.
    pub fn bp_depth(&self) -> BpDepth {
        match self {
            Method::FullZo => BpDepth::Tail(0),
            Method::Cls2 => BpDepth::Tail(1),
            Method::Cls1 => BpDepth::Tail(2),
            Method::FullBp => BpDepth::All,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::FullZo => "Full ZO",
            Method::Cls1 => "ZO-Feat-Cls1",
            Method::Cls2 => "ZO-Feat-Cls2",
            Method::FullBp => "Full BP",
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            Method::FullZo => "full-zo",
            Method::Cls1 => "cls1",
            Method::Cls2 => "cls2",
            Method::FullBp => "full-bp",
        }
    }

    pub const ALL: [Method; 4] = [Method::FullZo, Method::Cls2, Method::Cls1, Method::FullBp];

    /// Memory-model mapping, derived from the ZO/BP partition.
    pub fn memory_method(&self) -> crate::memory::Method {
        match self.bp_depth() {
            BpDepth::All => crate::memory::Method::FullBp,
            BpDepth::Tail(0) => crate::memory::Method::FullZo,
            BpDepth::Tail(k) => crate::memory::Method::Elastic { bp_layers: k },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_and_depth() {
        assert_eq!(Method::parse("full-zo").unwrap(), Method::FullZo);
        // paper naming: Cls1 -> BP on TWO layers, Cls2 -> BP on ONE
        assert_eq!(Method::parse("cls1").unwrap().bp_depth(), BpDepth::Tail(2));
        assert_eq!(Method::parse("zo-feat-cls2").unwrap().bp_depth(), BpDepth::Tail(1));
        // Full BP is not a ZO boundary — it is its own variant
        assert_eq!(Method::FullBp.bp_depth(), BpDepth::All);
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn zo_param_counts_match_paper_per_method() {
        use crate::coordinator::params::{Model, ParamSet};
        let p = ParamSet::init(Model::LeNet, 1);
        // paper §5.1.1: Cls1 trains 96,772 params by ZO, Cls2 106,936
        assert_eq!(p.zo_param_count(2), 96_772);
        assert_eq!(p.zo_param_count(1), 106_936);
    }

    #[test]
    fn memory_method_follows_partition() {
        use crate::memory;
        assert_eq!(Method::FullZo.memory_method(), memory::Method::FullZo);
        assert_eq!(
            Method::Cls2.memory_method(),
            memory::Method::Elastic { bp_layers: 1 }
        );
        assert_eq!(
            Method::Cls1.memory_method(),
            memory::Method::Elastic { bp_layers: 2 }
        );
        assert_eq!(Method::FullBp.memory_method(), memory::Method::FullBp);
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn labels_match_paper_tables() {
        assert_eq!(Method::FullZo.label(), "Full ZO");
        assert_eq!(Method::Cls1.label(), "ZO-Feat-Cls1");
    }

    #[test]
    fn tokens_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.token()).unwrap(), m);
        }
        for e in [EngineKind::Xla, EngineKind::Native] {
            assert_eq!(EngineKind::parse(e.token()).unwrap(), e);
        }
    }
}
