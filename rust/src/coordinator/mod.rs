//! L3 coordinator — the paper's system contribution.
//!
//! Owns the training loop end to end: parameter store, the seed-trick
//! ZO engine, elastic ZO/BP partitioning, the NITI INT8 driver, the
//! hyper-parameter schedules, metrics and checkpoints. Compute is
//! delegated to an [`engine::Engine`] — either the XLA artifacts
//! ([`xla_engine`]) or the native rust implementation
//! ([`native_engine`]).

pub mod checkpoint;
pub mod control;
pub mod engine;
pub mod int8_trainer;
pub mod metrics;
pub mod native_engine;
pub mod params;
pub mod schedules;
pub mod trainer;
#[cfg(feature = "xla")]
pub mod xla_engine;
pub mod zo;

pub use control::{ProgressSink, StopFlag};
pub use engine::{Engine, EngineKind, Method};
pub use int8_trainer::{Int8TrainConfig, ZoGradMode};
pub use params::{Model, ParamSet};
pub use trainer::{TrainConfig, TrainResult};
