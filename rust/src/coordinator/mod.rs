//! L3 coordinator — the paper's system contribution.
//!
//! Owns the training loop end to end: parameter store, the seed-trick
//! ZO engine, elastic ZO/BP partitioning, the NITI INT8 driver, the
//! hyper-parameter schedules, metrics and checkpoints. Training runs
//! through the precision-agnostic [`session`] API: one [`session::TrainSpec`]
//! describes any method × precision cell of the paper's grid, one
//! generic [`session::run`] epoch loop drives a [`session::TrainSession`]
//! backend — [`trainer::Fp32Session`] (compute delegated to an
//! [`engine::Engine`], either the XLA artifacts in [`xla_engine`] or the
//! native rust implementation in [`native_engine`]) or
//! [`int8_trainer::Int8Session`] (the NITI int8 path).

pub mod checkpoint;
pub mod control;
pub mod dp_session;
pub mod elastic;
pub mod engine;
pub mod int8_trainer;
pub mod kernels;
pub mod metrics;
pub mod native_engine;
pub mod params;
pub mod schedules;
pub mod session;
pub mod trainer;
#[cfg(feature = "xla")]
pub mod xla_engine;
pub mod zo;

pub use checkpoint::{CheckpointPolicy, CkptTensor, TrainState};
pub use control::{ProgressSink, StopFlag};
pub use dp_session::{DpAggregate, DpLocalSession, DpSpec, DpWorld, DP_MAX_REPLICAS};
pub use elastic::{ElasticController, ElasticSpec, ElasticState};
pub use engine::{BpDepth, Engine, EngineKind, Method, StepOut};
pub use int8_trainer::{Int8Session, ZoGradMode};
pub use params::{Model, ParamSet};
pub use session::{PrecisionSpec, StepOutcome, TrainResult, TrainSession, TrainSpec};
pub use trainer::Fp32Session;
