//! Training history: per-epoch loss/accuracy series (the data behind
//! the paper's Figs. 2–3 and Tables 1–2), JSON-dumpable.

use crate::util::json::Value;

#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub test_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
    pub lr: f32,
    pub seconds: f64,
}

impl EpochStats {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("epoch", Value::num(self.epoch as f64)),
            ("train_loss", Value::num(self.train_loss as f64)),
            ("test_loss", Value::num(self.test_loss as f64)),
            ("train_acc", Value::num(self.train_acc as f64)),
            ("test_acc", Value::num(self.test_acc as f64)),
            ("lr", Value::num(self.lr as f64)),
            ("seconds", Value::num(self.seconds)),
        ])
    }

    /// Parse the shape [`EpochStats::to_json`] emits (serve's job
    /// journal replays epoch events through this). Only `epoch` is
    /// required; missing metrics default to zero.
    pub fn from_json(v: &Value) -> anyhow::Result<EpochStats> {
        use anyhow::Context;
        Ok(EpochStats {
            epoch: v
                .get("epoch")
                .as_usize()
                .context("epoch stats: missing 'epoch'")?,
            train_loss: v.get("train_loss").as_f64().unwrap_or(0.0) as f32,
            test_loss: v.get("test_loss").as_f64().unwrap_or(0.0) as f32,
            train_acc: v.get("train_acc").as_f64().unwrap_or(0.0) as f32,
            test_acc: v.get("test_acc").as_f64().unwrap_or(0.0) as f32,
            lr: v.get("lr").as_f64().unwrap_or(0.0) as f32,
            seconds: v.get("seconds").as_f64().unwrap_or(0.0),
        })
    }
}

#[derive(Debug, Clone, Default)]
pub struct History {
    pub label: String,
    pub epochs: Vec<EpochStats>,
}

impl History {
    pub fn new(label: &str) -> History {
        History { label: label.to_string(), epochs: Vec::new() }
    }

    pub fn push(&mut self, e: EpochStats) {
        self.epochs.push(e);
    }

    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    /// Best (max) test accuracy over the run — the number the paper's
    /// tables report.
    pub fn best_test_acc(&self) -> f32 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f32::max)
    }

    pub fn final_train_loss(&self) -> f32 {
        self.epochs.last().map(|e| e.train_loss).unwrap_or(f32::NAN)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(self.label.clone())),
            (
                "epochs",
                Value::Arr(self.epochs.iter().map(EpochStats::to_json).collect()),
            ),
        ])
    }

    /// Render a compact loss-curve table (Fig. 2/3 ASCII form).
    pub fn curve_rows(&self) -> Vec<String> {
        self.epochs
            .iter()
            .map(|e| {
                format!(
                    "epoch {:>3}  train {:.4}  test {:.4}  acc {:.2}%",
                    e.epoch,
                    e.train_loss,
                    e.test_loss,
                    e.test_acc * 100.0
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h() -> History {
        let mut h = History::new("Full ZO");
        h.push(EpochStats { epoch: 0, test_acc: 0.5, train_loss: 2.0, ..Default::default() });
        h.push(EpochStats { epoch: 1, test_acc: 0.8, train_loss: 1.0, ..Default::default() });
        h.push(EpochStats { epoch: 2, test_acc: 0.7, train_loss: 0.9, ..Default::default() });
        h
    }

    #[test]
    fn accessors() {
        let h = h();
        assert_eq!(h.final_test_acc(), 0.7);
        assert_eq!(h.best_test_acc(), 0.8);
        assert_eq!(h.final_train_loss(), 0.9);
    }

    #[test]
    fn json_roundtrip() {
        let v = h().to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("label").as_str(), Some("Full ZO"));
        assert_eq!(back.get("epochs").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn curve_rows_one_per_epoch() {
        assert_eq!(h().curve_rows().len(), 3);
    }

    #[test]
    fn epoch_stats_json_roundtrip() {
        let e = EpochStats {
            epoch: 7,
            train_loss: 1.25,
            test_loss: 1.5,
            train_acc: 0.625,
            test_acc: 0.75,
            lr: 0.001953125,
            seconds: 2.5,
        };
        let back = EpochStats::from_json(&e.to_json()).unwrap();
        assert_eq!(back.to_json(), e.to_json());
        assert!(EpochStats::from_json(&Value::Null).is_err());
    }
}
