//! Training history: per-epoch loss/accuracy series (the data behind
//! the paper's Figs. 2–3 and Tables 1–2), JSON-dumpable.

use crate::telemetry::{Phase, PhaseDelta};
use crate::util::json::Value;

#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub test_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
    pub lr: f32,
    pub seconds: f64,
    /// Per-phase wall-clock deltas for this epoch (Fig. 7's slices).
    /// Empty for histories produced before phase threading existed;
    /// the `phases` JSON key is omitted when empty so old consumers
    /// see an unchanged shape.
    pub phases: Vec<PhaseDelta>,
    /// ZO/BP boundary in effect after this epoch (elastic runs move it
    /// at epoch granularity; fixed `Tail(k)` runs report their constant
    /// k). `None` — and an omitted JSON key — for Full BP and for
    /// histories predating the elastic boundary.
    pub bp_tail: Option<usize>,
}

impl EpochStats {
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("epoch", Value::num(self.epoch as f64)),
            ("train_loss", Value::num(self.train_loss as f64)),
            ("test_loss", Value::num(self.test_loss as f64)),
            ("train_acc", Value::num(self.train_acc as f64)),
            ("test_acc", Value::num(self.test_acc as f64)),
            ("lr", Value::num(self.lr as f64)),
            ("seconds", Value::num(self.seconds)),
        ];
        if let Some(k) = self.bp_tail {
            pairs.push(("bp_tail", Value::num(k as f64)));
        }
        if !self.phases.is_empty() {
            let obj = self
                .phases
                .iter()
                .map(|d| {
                    (
                        d.phase.name(),
                        Value::Arr(vec![Value::num(d.seconds), Value::num(d.calls as f64)]),
                    )
                })
                .collect();
            pairs.push(("phases", Value::obj(obj)));
        }
        Value::obj(pairs)
    }

    /// Parse the shape [`EpochStats::to_json`] emits (serve's job
    /// journal replays epoch events through this, and remote agents
    /// POST it verbatim to `/cluster/.../epoch`). Only `epoch` is
    /// required; missing metrics default to zero and unknown phase
    /// names are skipped, so payloads from other versions stay
    /// readable.
    pub fn from_json(v: &Value) -> anyhow::Result<EpochStats> {
        use anyhow::Context;
        let mut phases = Vec::new();
        if let Some(obj) = v.get("phases").as_obj() {
            for (name, val) in obj {
                let Some(phase) = Phase::parse(name) else { continue };
                let arr = val.as_arr().unwrap_or(&[]);
                phases.push(PhaseDelta {
                    phase,
                    seconds: arr.first().and_then(Value::as_f64).unwrap_or(0.0),
                    calls: arr.get(1).and_then(Value::as_f64).unwrap_or(0.0) as u64,
                });
            }
        }
        Ok(EpochStats {
            epoch: v
                .get("epoch")
                .as_usize()
                .context("epoch stats: missing 'epoch'")?,
            train_loss: v.get("train_loss").as_f64().unwrap_or(0.0) as f32,
            test_loss: v.get("test_loss").as_f64().unwrap_or(0.0) as f32,
            train_acc: v.get("train_acc").as_f64().unwrap_or(0.0) as f32,
            test_acc: v.get("test_acc").as_f64().unwrap_or(0.0) as f32,
            lr: v.get("lr").as_f64().unwrap_or(0.0) as f32,
            seconds: v.get("seconds").as_f64().unwrap_or(0.0),
            phases,
            bp_tail: v.get("bp_tail").as_usize(),
        })
    }
}

#[derive(Debug, Clone, Default)]
pub struct History {
    pub label: String,
    pub epochs: Vec<EpochStats>,
}

impl History {
    pub fn new(label: &str) -> History {
        History { label: label.to_string(), epochs: Vec::new() }
    }

    pub fn push(&mut self, e: EpochStats) {
        self.epochs.push(e);
    }

    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    /// Best (max) test accuracy over the run — the number the paper's
    /// tables report.
    pub fn best_test_acc(&self) -> f32 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f32::max)
    }

    pub fn final_train_loss(&self) -> f32 {
        self.epochs.last().map(|e| e.train_loss).unwrap_or(f32::NAN)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(self.label.clone())),
            (
                "epochs",
                Value::Arr(self.epochs.iter().map(EpochStats::to_json).collect()),
            ),
        ])
    }

    /// Render a compact loss-curve table (Fig. 2/3 ASCII form).
    pub fn curve_rows(&self) -> Vec<String> {
        self.epochs
            .iter()
            .map(|e| {
                format!(
                    "epoch {:>3}  train {:.4}  test {:.4}  acc {:.2}%",
                    e.epoch,
                    e.train_loss,
                    e.test_loss,
                    e.test_acc * 100.0
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h() -> History {
        let mut h = History::new("Full ZO");
        h.push(EpochStats { epoch: 0, test_acc: 0.5, train_loss: 2.0, ..Default::default() });
        h.push(EpochStats { epoch: 1, test_acc: 0.8, train_loss: 1.0, ..Default::default() });
        h.push(EpochStats { epoch: 2, test_acc: 0.7, train_loss: 0.9, ..Default::default() });
        h
    }

    #[test]
    fn accessors() {
        let h = h();
        assert_eq!(h.final_test_acc(), 0.7);
        assert_eq!(h.best_test_acc(), 0.8);
        assert_eq!(h.final_train_loss(), 0.9);
    }

    #[test]
    fn json_roundtrip() {
        let v = h().to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("label").as_str(), Some("Full ZO"));
        assert_eq!(back.get("epochs").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn curve_rows_one_per_epoch() {
        assert_eq!(h().curve_rows().len(), 3);
    }

    #[test]
    fn epoch_stats_json_roundtrip() {
        let e = EpochStats {
            epoch: 7,
            train_loss: 1.25,
            test_loss: 1.5,
            train_acc: 0.625,
            test_acc: 0.75,
            lr: 0.001953125,
            seconds: 2.5,
            ..Default::default()
        };
        let back = EpochStats::from_json(&e.to_json()).unwrap();
        assert_eq!(back.to_json(), e.to_json());
        assert!(EpochStats::from_json(&Value::Null).is_err());
    }

    #[test]
    fn bp_tail_omitted_when_absent_and_roundtrips() {
        let plain = EpochStats { epoch: 1, ..Default::default() };
        assert!(plain.to_json().get("bp_tail").as_usize().is_none());
        let tagged = EpochStats { epoch: 1, bp_tail: Some(2), ..Default::default() };
        let v = tagged.to_json();
        assert_eq!(v.get("bp_tail").as_usize(), Some(2));
        assert_eq!(EpochStats::from_json(&v).unwrap().bp_tail, Some(2));
    }

    #[test]
    fn phases_survive_the_wire_format() {
        let e = EpochStats {
            epoch: 3,
            seconds: 1.0,
            phases: vec![
                PhaseDelta { phase: Phase::Forward, seconds: 0.75, calls: 24 },
                PhaseDelta { phase: Phase::ZoUpdate, seconds: 0.25, calls: 12 },
            ],
            ..Default::default()
        };
        let v = e.to_json();
        assert!(v.get("phases").as_obj().is_some(), "phases key present when non-empty");
        let back = EpochStats::from_json(&v).unwrap();
        assert_eq!(back.phases.len(), 2);
        let fwd = back.phases.iter().find(|d| d.phase == Phase::Forward).unwrap();
        assert_eq!((fwd.seconds, fwd.calls), (0.75, 24));
        assert_eq!(back.to_json(), v);

        // empty phases → key omitted → old shape exactly
        let plain = EpochStats { epoch: 1, ..Default::default() };
        assert!(plain.to_json().get("phases").as_obj().is_none());
        // unknown phase names from a future version are skipped, not fatal
        let fwdcompat = crate::util::json::parse(
            r#"{"epoch": 2, "phases": {"Warp": [1.0, 3], "Eval": [0.5, 1]}}"#,
        )
        .unwrap();
        let got = EpochStats::from_json(&fwdcompat).unwrap();
        assert_eq!(got.phases.len(), 1);
        assert_eq!(got.phases[0].phase, Phase::Eval);
    }
}
