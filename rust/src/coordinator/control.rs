//! Run-control hooks threaded through the training loops: cooperative
//! cancellation ([`StopFlag`]) and live per-epoch progress publishing
//! ([`ProgressSink`]). Both default to no-ops so plain CLI runs are
//! unaffected; the `serve` worker pool arms them per job.

use super::metrics::EpochStats;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Cooperative cancellation handle. Cloning shares the underlying flag;
/// the trainers poll it between batches and between epochs and exit
/// early (marking the run as stopped) once it fires.
#[derive(Debug, Clone, Default)]
pub struct StopFlag(Option<Arc<AtomicBool>>);

impl StopFlag {
    /// An armed (but not yet fired) flag.
    pub fn new() -> StopFlag {
        StopFlag(Some(Arc::new(AtomicBool::new(false))))
    }

    /// A flag that can never fire — the default for plain CLI runs.
    pub fn disabled() -> StopFlag {
        StopFlag(None)
    }

    /// Request cancellation. No-op on a disabled flag.
    pub fn request_stop(&self) {
        if let Some(f) = &self.0 {
            f.store(true, Ordering::SeqCst);
        }
    }

    /// Has cancellation been requested?
    pub fn should_stop(&self) -> bool {
        self.0.as_ref().map_or(false, |f| f.load(Ordering::SeqCst))
    }

    /// True iff `other` is a clone of this flag (shares the underlying
    /// atomic). Lets an owner guard map cleanup against an entry that
    /// was replaced by a newer run's flag; disabled flags share
    /// nothing.
    pub fn shares_state(&self, other: &StopFlag) -> bool {
        match (&self.0, &other.0) {
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Per-epoch progress callback. The trainers invoke it with every
/// [`EpochStats`] they record, before appending to the run history.
#[derive(Clone, Default)]
pub struct ProgressSink(Option<Arc<dyn Fn(&EpochStats) + Send + Sync>>);

impl ProgressSink {
    pub fn new(f: impl Fn(&EpochStats) + Send + Sync + 'static) -> ProgressSink {
        ProgressSink(Some(Arc::new(f)))
    }

    /// A sink that drops everything — the default for plain CLI runs.
    pub fn disabled() -> ProgressSink {
        ProgressSink(None)
    }

    pub fn publish(&self, e: &EpochStats) {
        if let Some(f) = &self.0 {
            f(e);
        }
    }
}

impl fmt::Debug for ProgressSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(if self.0.is_some() { "ProgressSink(on)" } else { "ProgressSink(off)" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn stop_flag_shares_state_across_clones() {
        let a = StopFlag::new();
        let b = a.clone();
        assert!(!a.should_stop() && !b.should_stop());
        b.request_stop();
        assert!(a.should_stop() && b.should_stop());
    }

    #[test]
    fn shares_state_tracks_clone_lineage() {
        let a = StopFlag::new();
        let b = a.clone();
        let c = StopFlag::new();
        assert!(a.shares_state(&b));
        assert!(!a.shares_state(&c));
        assert!(!StopFlag::disabled().shares_state(&StopFlag::disabled()));
    }

    #[test]
    fn disabled_flag_never_fires() {
        let f = StopFlag::disabled();
        f.request_stop();
        assert!(!f.should_stop());
        assert!(!StopFlag::default().should_stop());
    }

    #[test]
    fn progress_sink_delivers() {
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let sink = ProgressSink::new(move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        sink.publish(&EpochStats::default());
        sink.publish(&EpochStats::default());
        assert_eq!(count.load(Ordering::SeqCst), 2);
        ProgressSink::disabled().publish(&EpochStats::default()); // no-op
    }
}
