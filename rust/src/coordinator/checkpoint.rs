//! Checkpointing: save/load parameter sets (FP32 and INT8) in a simple
//! self-describing binary format — used by the fine-tuning experiments
//! (pretrain on clean data → fine-tune on rotated data, paper Table 2).
//!
//! Format: magic "EZOC", version u32, tensor count u32, then per tensor:
//! name (u32 len + utf8), dtype tag u8 (0=f32, 1=i8), exponent i32
//! (int8 only, 0 otherwise), rank u32, dims u64×rank, payload.

use crate::int8::qtensor::QTensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"EZOC";
const VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8 { data: Vec<i8>, exp: i32 },
}

#[derive(Debug, Clone, PartialEq)]
pub struct CkptTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

pub fn save(path: impl AsRef<Path>, tensors: &[CkptTensor]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        f.write_all(&(t.name.len() as u32).to_le_bytes())?;
        f.write_all(t.name.as_bytes())?;
        let (tag, exp): (u8, i32) = match &t.data {
            TensorData::F32(_) => (0, 0),
            TensorData::I8 { exp, .. } => (1, *exp),
        };
        f.write_all(&[tag])?;
        f.write_all(&exp.to_le_bytes())?;
        f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
        for &d in &t.dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I8 { data, .. } => {
                let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
                f.write_all(&bytes)?;
            }
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<CkptTensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ElasticZO checkpoint (bad magic)");
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).context("tensor name utf8")?;
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let mut exp_buf = [0u8; 4];
        f.read_exact(&mut exp_buf)?;
        let exp = i32::from_le_bytes(exp_buf);
        let rank = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut d = [0u8; 8];
            f.read_exact(&mut d)?;
            dims.push(u64::from_le_bytes(d) as usize);
        }
        let numel: usize = dims.iter().product();
        let data = match tag[0] {
            0 => {
                let mut buf = vec![0u8; numel * 4];
                f.read_exact(&mut buf)?;
                TensorData::F32(
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; numel];
                f.read_exact(&mut buf)?;
                TensorData::I8 { data: buf.iter().map(|&b| b as i8).collect(), exp }
            }
            t => bail!("unknown tensor tag {t}"),
        };
        out.push(CkptTensor { name, dims, data });
    }
    Ok(out)
}

/// Save an FP32 [`ParamSet`](super::params::ParamSet).
pub fn save_params(path: impl AsRef<Path>, params: &super::params::ParamSet) -> Result<()> {
    let tensors: Vec<CkptTensor> = params
        .specs
        .iter()
        .zip(&params.data)
        .map(|((name, dims), data)| CkptTensor {
            name: name.clone(),
            dims: dims.clone(),
            data: TensorData::F32(data.clone()),
        })
        .collect();
    save(path, &tensors)
}

/// Load into an existing FP32 ParamSet (shapes must match).
pub fn load_params(path: impl AsRef<Path>, params: &mut super::params::ParamSet) -> Result<()> {
    let tensors = load(path)?;
    if tensors.len() != params.num_tensors() {
        bail!(
            "checkpoint has {} tensors, model wants {}",
            tensors.len(),
            params.num_tensors()
        );
    }
    for (t, ((name, dims), slot)) in tensors
        .iter()
        .zip(params.specs.iter().zip(params.data.iter_mut()))
    {
        if &t.name != name || &t.dims != dims {
            bail!("checkpoint tensor {} {:?} != model {} {:?}", t.name, t.dims, name, dims);
        }
        match &t.data {
            TensorData::F32(v) => slot.copy_from_slice(v),
            _ => bail!("expected f32 tensor for {}", t.name),
        }
    }
    Ok(())
}

/// Save INT8 NITI weights.
pub fn save_int8(path: impl AsRef<Path>, names: &[&str], ws: &[QTensor]) -> Result<()> {
    let tensors: Vec<CkptTensor> = names
        .iter()
        .zip(ws)
        .map(|(name, w)| CkptTensor {
            name: name.to_string(),
            dims: w.dims.clone(),
            data: TensorData::I8 { data: w.data.clone(), exp: w.exp },
        })
        .collect();
    save(path, &tensors)
}

/// Load INT8 NITI weights.
pub fn load_int8(path: impl AsRef<Path>) -> Result<Vec<QTensor>> {
    load(path)?
        .into_iter()
        .map(|t| match t.data {
            TensorData::I8 { data, exp } => Ok(QTensor::from_vec(&t.dims, data, exp)),
            _ => bail!("expected int8 tensor for {}", t.name),
        })
        .collect()
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::{Model, ParamSet};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ezo_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn fp32_roundtrip() {
        let p = ParamSet::init(Model::LeNet, 3);
        let path = tmp("fp32");
        save_params(&path, &p).unwrap();
        let mut q = ParamSet::init(Model::LeNet, 99);
        assert_ne!(p.data, q.data);
        load_params(&path, &mut q).unwrap();
        assert_eq!(p.data, q.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn int8_roundtrip() {
        let ws = crate::int8::lenet8::init_params(5, 32);
        let names: Vec<&str> = crate::int8::lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
        let path = tmp("int8");
        save_int8(&path, &names, &ws).unwrap();
        let back = load_int8(&path).unwrap();
        assert_eq!(ws.len(), back.len());
        for (a, b) in ws.iter().zip(&back) {
            assert_eq!(a.data, b.data);
            assert_eq!(a.exp, b.exp);
            assert_eq!(a.dims, b.dims);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let p = ParamSet::init(Model::LeNet, 3);
        let path = tmp("mismatch");
        save_params(&path, &p).unwrap();
        let mut q = ParamSet::init(Model::PointNet { npoints: 8, ncls: 40 }, 1);
        assert!(load_params(&path, &mut q).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = tmp("corrupt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
