//! Checkpointing: the `EZOC` self-describing binary format for
//! parameter sets (FP32 and INT8), plus — since v2 — an optional
//! trailing **training-state section** that makes a checkpoint
//! resumable (`repro train --resume`, serve-job requeue after a
//! restart). Used by the fine-tuning experiments (pretrain on clean
//! data → fine-tune on rotated data, paper Table 2) and by the
//! durability layer around `coordinator::session::run`.
//!
//! # Binary layout
//!
//! v1 (legacy) and v2 share the header and tensor section; every
//! integer is little-endian:
//!
//! ```text
//!   magic    4 B    b"EZOC"
//!   version  u32    1 | 2
//!   count    u32    number of tensors
//!   per tensor:
//!     name_len u32, name (utf-8, name_len bytes)
//!     dtype    u8     0 = f32, 1 = i8
//!     exp      i32    block exponent (int8 only; 0 for f32)
//!     rank     u32,  dims u64 × rank
//!     payload  numel × 4 B f32 LE  |  numel × 1 B i8
//! ```
//!
//! A v2 file may append **one** training-state section after the last
//! tensor payload (absent ⇒ the file is params-only, exactly like v1):
//!
//! ```text
//!   marker   4 B    b"TRNS"
//!   len      u32    JSON byte length
//!   state    len B  utf-8 JSON — see [`TrainState`]
//! ```
//!
//! Compatibility rules:
//!
//! * v1 files load fine through [`load`]/[`load_full`] (the tensor
//!   section is identical); they simply carry no training state.
//! * A v2 file whose trailer is absent is params-only; a *truncated or
//!   malformed* trailer is a hard error, never a silent params-only
//!   fallback.
//! * Writers always emit v2. [`save`]/[`save_params`]/[`save_int8`]
//!   write params-only files; [`save_with_state`] appends the state
//!   section.
//!
//! # Resumable checkpoints
//!
//! [`TrainState`] records where the epoch loop stood when the tensors
//! were written: the number of completed epochs, the global step
//! counter (the ZO seed-trick stream position — perturbations are a
//! pure function of `(run_seed, step)`), best/last-eval bookkeeping
//! for cadence carry-forward, and the serialized `TrainSpec` the run
//! belonged to. Resume refuses a checkpoint whose spec differs from
//! the current run's (modulo the non-mathematical keys in
//! [`SPEC_IDENTITY_EXEMPT`]) — see [`ensure_spec_matches`].
//!
//! [`CheckpointPolicy`] + [`write_snapshot`] implement the mid-run
//! cadence snapshots `coordinator::session::run` takes at completed
//! epoch boundaries: atomic tmp-file + rename, with optional rotation
//! (`keep_last`) of the previous snapshot generations as
//! `path.1`, `path.2`, ….

use crate::int8::qtensor::QTensor;
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"EZOC";
const STATE_MARKER: &[u8; 4] = b"TRNS";
/// Newest format version written; readers accept `1..=VERSION`.
pub const VERSION: u32 = 2;

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8 { data: Vec<i8>, exp: i32 },
}

#[derive(Debug, Clone, PartialEq)]
pub struct CkptTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

/// Mid-run snapshot policy, threaded through `TrainSpec`/`Config`:
/// where cadence snapshots go, how often, and how many generations of
/// them to keep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Snapshot file; always holds the newest snapshot.
    pub path: String,
    /// Snapshot after every Nth completed epoch (0 disables cadence —
    /// only the final post-run save happens).
    pub every_n_epochs: usize,
    /// Snapshot generations retained (≥ 1). With `keep_last = k`, the
    /// previous k−1 snapshots survive as `path.1` (newest backup) …
    /// `path.{k-1}` (oldest).
    pub keep_last: usize,
}

/// The v2 training-state trailer: everything `session::run_from` needs
/// to continue a run from epoch `epochs_done` with bit-identical batch
/// order and ZO perturbation streams (the tensors in the same file
/// supply the params).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Completed epochs; a resumed run starts at this epoch index.
    pub epochs_done: usize,
    /// Global minibatch counter — the ZO seed-trick stream position.
    pub step: u64,
    /// Best test accuracy seen so far (paper-table bookkeeping).
    pub best_test_acc: f32,
    /// Last evaluated test loss (NaN if never evaluated) — the eval
    /// cadence carry-forward across the resume boundary.
    pub last_test_loss: f32,
    /// Last evaluated test accuracy.
    pub last_test_acc: f32,
    /// The serialized `TrainSpec` (`TrainSpec::to_json`) this state
    /// belongs to; checked on resume via [`ensure_spec_matches`].
    pub spec: Value,
    /// Elastic-boundary controller state (`None` for fixed-boundary
    /// runs — the trailer key is then omitted, so pre-elastic
    /// checkpoints parse and re-serialize unchanged).
    pub elastic: Option<super::elastic::ElasticState>,
}

impl TrainState {
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("epochs_done", Value::num(self.epochs_done as f64)),
            ("step", Value::num(self.step as f64)),
            ("best_test_acc", Value::num(self.best_test_acc as f64)),
            (
                "last_test_loss",
                if self.last_test_loss.is_finite() {
                    Value::num(self.last_test_loss as f64)
                } else {
                    Value::Null
                },
            ),
            ("last_test_acc", Value::num(self.last_test_acc as f64)),
            ("spec", self.spec.clone()),
        ];
        if let Some(e) = &self.elastic {
            pairs.push(("elastic", e.to_json()));
        }
        Value::obj(pairs)
    }

    pub fn from_json(v: &Value) -> Result<TrainState> {
        anyhow::ensure!(v.as_obj().is_some(), "training state must be a JSON object");
        Ok(TrainState {
            epochs_done: v
                .get("epochs_done")
                .as_usize()
                .context("training state: missing 'epochs_done'")?,
            step: v.get("step").as_f64().context("training state: missing 'step'")? as u64,
            best_test_acc: v.get("best_test_acc").as_f64().unwrap_or(0.0) as f32,
            last_test_loss: v.get("last_test_loss").as_f64().map_or(f32::NAN, |n| n as f32),
            last_test_acc: v.get("last_test_acc").as_f64().unwrap_or(0.0) as f32,
            spec: v.get("spec").clone(),
            elastic: match v.get("elastic") {
                Value::Null => None,
                e => Some(super::elastic::ElasticState::from_json(e)?),
            },
        })
    }
}

/// Serialized-`TrainSpec` keys that do NOT affect the math of a run
/// (logging and checkpoint plumbing); [`ensure_spec_matches`] ignores
/// them when deciding whether a checkpoint belongs to the spec being
/// resumed.
pub const SPEC_IDENTITY_EXEMPT: [&str; 4] = ["verbose", "save", "ckpt_every", "ckpt_keep"];

/// A serialized spec with the [`SPEC_IDENTITY_EXEMPT`] keys stripped —
/// the part of a `TrainSpec` that defines the run's identity.
pub fn spec_identity(spec: &Value) -> Value {
    match spec {
        Value::Obj(o) => Value::Obj(
            o.iter()
                .filter(|(k, _)| !SPEC_IDENTITY_EXEMPT.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Hard spec-mismatch check for resume: the stored and current specs
/// must agree on every identity key (method, precision + knobs,
/// epochs, batch, lr/eps/clip, seed, eval cadence). Names the
/// differing keys in the error.
pub fn ensure_spec_matches(stored: &Value, current: &Value) -> Result<()> {
    let (a, b) = (spec_identity(stored), spec_identity(current));
    if a == b {
        return Ok(());
    }
    let mut diffs: Vec<String> = Vec::new();
    if let (Some(ao), Some(bo)) = (a.as_obj(), b.as_obj()) {
        let keys: std::collections::BTreeSet<&String> = ao.keys().chain(bo.keys()).collect();
        for k in keys {
            if ao.get(k) != bo.get(k) {
                diffs.push(k.clone());
            }
        }
    }
    bail!(
        "checkpoint belongs to a different run (differing spec keys: {}); \
         resume requires the original TrainSpec",
        if diffs.is_empty() { "non-object spec".to_string() } else { diffs.join(", ") }
    )
}

/// Write a params-only checkpoint (v2, no training-state trailer).
pub fn save(path: impl AsRef<Path>, tensors: &[CkptTensor]) -> Result<()> {
    save_with_state(path, tensors, None)
}

/// Write a v2 checkpoint, optionally with a training-state trailer.
pub fn save_with_state(
    path: impl AsRef<Path>,
    tensors: &[CkptTensor],
    state: Option<&TrainState>,
) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating checkpoint {}", path.as_ref().display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        f.write_all(&(t.name.len() as u32).to_le_bytes())?;
        f.write_all(t.name.as_bytes())?;
        let (tag, exp): (u8, i32) = match &t.data {
            TensorData::F32(_) => (0, 0),
            TensorData::I8 { exp, .. } => (1, *exp),
        };
        f.write_all(&[tag])?;
        f.write_all(&exp.to_le_bytes())?;
        f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
        for &d in &t.dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I8 { data, .. } => {
                let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
                f.write_all(&bytes)?;
            }
        }
    }
    if let Some(s) = state {
        let text = json::to_string(&s.to_json());
        f.write_all(STATE_MARKER)?;
        f.write_all(&(text.len() as u32).to_le_bytes())?;
        f.write_all(text.as_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Load the tensor section of a v1/v2 checkpoint (any training state
/// is read and discarded — see [`load_full`] to keep it).
pub fn load(path: impl AsRef<Path>) -> Result<Vec<CkptTensor>> {
    Ok(load_full(path)?.0)
}

/// Load a checkpoint: tensors plus the v2 training state when present
/// (`None` for v1 files and params-only v2 files).
pub fn load_full(path: impl AsRef<Path>) -> Result<(Vec<CkptTensor>, Option<TrainState>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ElasticZO checkpoint (bad magic)");
    }
    let version = read_u32(&mut f)?;
    if version == 0 || version > VERSION {
        bail!("unsupported checkpoint version {version} (this build reads 1..={VERSION})");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).context("tensor name utf8")?;
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let mut exp_buf = [0u8; 4];
        f.read_exact(&mut exp_buf)?;
        let exp = i32::from_le_bytes(exp_buf);
        let rank = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut d = [0u8; 8];
            f.read_exact(&mut d)?;
            dims.push(u64::from_le_bytes(d) as usize);
        }
        let numel: usize = dims.iter().product();
        let data = match tag[0] {
            0 => {
                let mut buf = vec![0u8; numel * 4];
                f.read_exact(&mut buf)?;
                TensorData::F32(
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                let mut buf = vec![0u8; numel];
                f.read_exact(&mut buf)?;
                TensorData::I8 { data: buf.iter().map(|&b| b as i8).collect(), exp }
            }
            t => bail!("unknown tensor tag {t}"),
        };
        out.push(CkptTensor { name, dims, data });
    }
    let state = if version >= 2 {
        let mut marker = [0u8; 4];
        match read_fully(&mut f, &mut marker)? {
            0 => None, // params-only: ends cleanly after the tensors
            4 if &marker == STATE_MARKER => {
                let len = read_u32(&mut f)? as usize;
                anyhow::ensure!(len <= 16 << 20, "training-state section too large ({len} B)");
                let mut buf = vec![0u8; len];
                f.read_exact(&mut buf).context("truncated training-state section")?;
                let text =
                    std::str::from_utf8(&buf).context("training-state section utf8")?;
                let v = json::parse(text).context("training-state section json")?;
                Some(TrainState::from_json(&v)?)
            }
            _ => bail!("corrupt checkpoint trailer (expected TRNS marker or EOF)"),
        }
    } else {
        None
    };
    Ok((out, state))
}

/// Atomic cadence snapshot: write to `path.tmp`, rotate previous
/// generations (`keep_last` > 1 ⇒ old `path` becomes `path.1`, which
/// becomes `path.2`, …), then rename into place — a crash mid-write
/// never corrupts the last good snapshot.
pub fn write_snapshot(
    policy: &CheckpointPolicy,
    tensors: &[CkptTensor],
    state: Option<&TrainState>,
) -> Result<()> {
    let tmp = format!("{}.tmp", policy.path);
    save_with_state(&tmp, tensors, state)?;
    if policy.keep_last > 1 {
        for i in (1..policy.keep_last).rev() {
            if i == 1 {
                // the live snapshot is COPIED (not renamed) into .1 so
                // `path` stays present through the whole rotation — a
                // kill here still leaves the last good snapshot live
                if Path::new(&policy.path).exists() {
                    let _ = std::fs::copy(&policy.path, format!("{}.1", policy.path));
                }
            } else {
                let src = format!("{}.{}", policy.path, i - 1);
                if Path::new(&src).exists() {
                    let _ = std::fs::rename(&src, format!("{}.{}", policy.path, i));
                }
            }
        }
    }
    std::fs::rename(&tmp, &policy.path)
        .with_context(|| format!("publishing snapshot {}", policy.path))?;
    Ok(())
}

/// An FP32 [`ParamSet`](super::params::ParamSet) as checkpoint tensors.
pub fn params_to_tensors(params: &super::params::ParamSet) -> Vec<CkptTensor> {
    params
        .specs
        .iter()
        .zip(&params.data)
        .map(|((name, dims), data)| CkptTensor {
            name: name.clone(),
            dims: dims.clone(),
            data: TensorData::F32(data.clone()),
        })
        .collect()
}

/// Save an FP32 [`ParamSet`](super::params::ParamSet) (params-only).
pub fn save_params(path: impl AsRef<Path>, params: &super::params::ParamSet) -> Result<()> {
    save(path, &params_to_tensors(params))
}

/// Copy loaded tensors into an existing FP32 ParamSet (shapes must match).
pub fn params_from_tensors(
    tensors: &[CkptTensor],
    params: &mut super::params::ParamSet,
) -> Result<()> {
    if tensors.len() != params.num_tensors() {
        bail!(
            "checkpoint has {} tensors, model wants {}",
            tensors.len(),
            params.num_tensors()
        );
    }
    for (t, ((name, dims), slot)) in tensors
        .iter()
        .zip(params.specs.iter().zip(params.data.iter_mut()))
    {
        if &t.name != name || &t.dims != dims {
            bail!("checkpoint tensor {} {:?} != model {} {:?}", t.name, t.dims, name, dims);
        }
        match &t.data {
            TensorData::F32(v) => slot.copy_from_slice(v),
            _ => bail!("expected f32 tensor for {}", t.name),
        }
    }
    Ok(())
}

/// Load into an existing FP32 ParamSet (shapes must match).
pub fn load_params(path: impl AsRef<Path>, params: &mut super::params::ParamSet) -> Result<()> {
    params_from_tensors(&load(path)?, params)
}

/// INT8 NITI weights as checkpoint tensors.
pub fn int8_to_tensors(names: &[&str], ws: &[QTensor]) -> Vec<CkptTensor> {
    names
        .iter()
        .zip(ws)
        .map(|(name, w)| CkptTensor {
            name: name.to_string(),
            dims: w.dims.clone(),
            data: TensorData::I8 { data: w.data.clone(), exp: w.exp },
        })
        .collect()
}

/// Save INT8 NITI weights (params-only).
pub fn save_int8(path: impl AsRef<Path>, names: &[&str], ws: &[QTensor]) -> Result<()> {
    save(path, &int8_to_tensors(names, ws))
}

/// Rebuild INT8 NITI weights from loaded tensors.
pub fn int8_from_tensors(tensors: Vec<CkptTensor>) -> Result<Vec<QTensor>> {
    tensors
        .into_iter()
        .map(|t| match t.data {
            TensorData::I8 { data, exp } => Ok(QTensor::from_vec(&t.dims, data, exp)),
            _ => bail!("expected int8 tensor for {}", t.name),
        })
        .collect()
}

/// Load INT8 NITI weights.
pub fn load_int8(path: impl AsRef<Path>) -> Result<Vec<QTensor>> {
    int8_from_tensors(load(path)?)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read up to `buf.len()` bytes; returns how many were available (a
/// clean EOF mid-buffer is reported, not an error).
fn read_fully(f: &mut impl Read, buf: &mut [u8]) -> Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        let k = f.read(&mut buf[n..])?;
        if k == 0 {
            break;
        }
        n += k;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::{Model, ParamSet};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ezo_test_{name}_{}", std::process::id()))
    }

    fn state(epochs_done: usize, step: u64) -> TrainState {
        TrainState {
            epochs_done,
            step,
            best_test_acc: 0.5,
            last_test_loss: 1.25,
            last_test_acc: 0.5,
            spec: Value::obj(vec![("method", Value::str("cls1"))]),
            elastic: None,
        }
    }

    #[test]
    fn fp32_roundtrip() {
        let p = ParamSet::init(Model::LeNet, 3);
        let path = tmp("fp32");
        save_params(&path, &p).unwrap();
        let mut q = ParamSet::init(Model::LeNet, 99);
        assert_ne!(p.data, q.data);
        load_params(&path, &mut q).unwrap();
        assert_eq!(p.data, q.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn int8_roundtrip() {
        let ws = crate::int8::lenet8::init_params(5, 32);
        let names: Vec<&str> = crate::int8::lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
        let path = tmp("int8");
        save_int8(&path, &names, &ws).unwrap();
        let back = load_int8(&path).unwrap();
        assert_eq!(ws.len(), back.len());
        for (a, b) in ws.iter().zip(&back) {
            assert_eq!(a.data, b.data);
            assert_eq!(a.exp, b.exp);
            assert_eq!(a.dims, b.dims);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_state_roundtrip() {
        let p = ParamSet::init(Model::LeNet, 3);
        let path = tmp("v2state");
        let s = state(4, 28);
        save_with_state(&path, &params_to_tensors(&p), Some(&s)).unwrap();
        let (tensors, back) = load_full(&path).unwrap();
        assert_eq!(tensors, params_to_tensors(&p));
        assert_eq!(back.as_ref(), Some(&s));
        // params-only readers still see just the tensors
        let mut q = ParamSet::init(Model::LeNet, 99);
        load_params(&path, &mut q).unwrap();
        assert_eq!(p.data, q.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn non_finite_last_loss_survives_as_null() {
        let path = tmp("nanloss");
        let mut s = state(1, 2);
        s.last_test_loss = f32::NAN;
        save_with_state(&path, &[], Some(&s)).unwrap();
        let (_, back) = load_full(&path).unwrap();
        assert!(back.unwrap().last_test_loss.is_nan());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v1_file_loads_without_state() {
        // hand-rolled v1 file: one f32 tensor "w" of shape [3]
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(b"EZOC");
        b.extend_from_slice(&1u32.to_le_bytes()); // version 1
        b.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        b.extend_from_slice(&1u32.to_le_bytes()); // name len
        b.extend_from_slice(b"w");
        b.push(0); // f32 tag
        b.extend_from_slice(&0i32.to_le_bytes()); // exp
        b.extend_from_slice(&1u32.to_le_bytes()); // rank
        b.extend_from_slice(&3u64.to_le_bytes());
        for x in [1.0f32, -2.5, 0.125] {
            b.extend_from_slice(&x.to_le_bytes());
        }
        let path = tmp("v1");
        std::fs::write(&path, &b).unwrap();
        let (tensors, st) = load_full(&path).unwrap();
        assert!(st.is_none(), "v1 files carry no training state");
        assert_eq!(tensors.len(), 1);
        assert_eq!(tensors[0].name, "w");
        assert_eq!(tensors[0].data, TensorData::F32(vec![1.0, -2.5, 0.125]));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_trailer_rejected_not_silently_dropped() {
        let path = tmp("trailer");
        save(&path, &[]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"JUNK");
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_full(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn snapshot_rotation_keeps_last_k() {
        let base = tmp("rot");
        let policy = CheckpointPolicy {
            path: base.display().to_string(),
            every_n_epochs: 1,
            keep_last: 3,
        };
        let tensor = |v: f32| CkptTensor {
            name: "x".into(),
            dims: vec![1],
            data: TensorData::F32(vec![v]),
        };
        for i in 0..4 {
            write_snapshot(&policy, &[tensor(i as f32)], None).unwrap();
        }
        let read = |p: &str| match &load(p).unwrap()[0].data {
            TensorData::F32(v) => v[0],
            _ => unreachable!(),
        };
        assert_eq!(read(&policy.path), 3.0);
        assert_eq!(read(&format!("{}.1", policy.path)), 2.0);
        assert_eq!(read(&format!("{}.2", policy.path)), 1.0);
        assert!(!Path::new(&format!("{}.3", policy.path)).exists());
        for p in [
            policy.path.clone(),
            format!("{}.1", policy.path),
            format!("{}.2", policy.path),
        ] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn spec_identity_ignores_logging_and_ckpt_keys() {
        let a = Value::obj(vec![
            ("method", Value::str("cls1")),
            ("seed", Value::num(1.0)),
            ("verbose", Value::Bool(true)),
            ("save", Value::str("/tmp/a.ckpt")),
            ("ckpt_every", Value::num(1.0)),
        ]);
        let b = Value::obj(vec![
            ("method", Value::str("cls1")),
            ("seed", Value::num(1.0)),
            ("verbose", Value::Bool(false)),
            ("ckpt_keep", Value::num(3.0)),
        ]);
        ensure_spec_matches(&a, &b).unwrap();
        let c = Value::obj(vec![("method", Value::str("cls2")), ("seed", Value::num(1.0))]);
        let err = ensure_spec_matches(&a, &c).unwrap_err().to_string();
        assert!(err.contains("method"), "{err}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let p = ParamSet::init(Model::LeNet, 3);
        let path = tmp("mismatch");
        save_params(&path, &p).unwrap();
        let mut q = ParamSet::init(Model::PointNet { npoints: 8, ncls: 40 }, 1);
        assert!(load_params(&path, &mut q).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = tmp("corrupt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
