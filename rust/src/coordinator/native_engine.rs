//! Native engine: the pure-rust nn/ implementations behind the `Engine`
//! trait — the stand-in for the paper's C++ on-device build.

use super::engine::{Engine, StepOut};
use super::params::{Model, ParamSet};
use crate::nn::{lenet, pointnet, Forward, TailGrads};
use crate::tensor::ops;
use anyhow::Result;

pub struct NativeEngine {
    model: Model,
}

impl NativeEngine {
    pub fn new(model: Model) -> NativeEngine {
        NativeEngine { model }
    }
}

impl Engine for NativeEngine {
    fn forward(&mut self, params: &ParamSet, x: &[f32], y: &[f32], bsz: usize) -> Result<Forward> {
        Ok(match self.model {
            Model::LeNet => lenet::forward(&params.data, x, y, bsz).0,
            Model::PointNet { npoints, ncls } => {
                pointnet::forward(&params.data, x, y, bsz, npoints, ncls).0
            }
        })
    }

    fn tail_grads(
        &mut self,
        params: &ParamSet,
        fwd: &Forward,
        y: &[f32],
        k: usize,
        bsz: usize,
    ) -> Result<TailGrads> {
        Ok(match self.model {
            Model::LeNet => lenet::tail_grads(&params.data, fwd, y, k, bsz),
            Model::PointNet { ncls, .. } => {
                pointnet::tail_grads(&params.data, fwd, y, k, bsz, ncls)
            }
        })
    }

    fn full_step(
        &mut self,
        params: &mut ParamSet,
        x: &[f32],
        y: &[f32],
        bsz: usize,
        lr: f32,
    ) -> Result<StepOut> {
        let (loss, logits, grads) = match self.model {
            Model::LeNet => {
                let (fwd, cache) = lenet::forward(&params.data, x, y, bsz);
                (fwd.loss, fwd.logits, lenet::full_grads(&params.data, &cache, y))
            }
            Model::PointNet { npoints, ncls } => {
                let (fwd, cache) = pointnet::forward(&params.data, x, y, bsz, npoints, ncls);
                (fwd.loss, fwd.logits, pointnet::full_grads(&params.data, &cache, y))
            }
        };
        for (p, g) in params.data.iter_mut().zip(&grads) {
            ops::axpy(-lr, g, p);
        }
        Ok(StepOut { loss, logits: Some(logits) })
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        // stateless: every forward delegates to pure free functions, so
        // a second handle is trivially bit-identical
        Some(Box::new(NativeEngine::new(self.model)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn forward_and_step_work() {
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 1);
        let d = synth_mnist::generate(8, 2);
        let mut y = vec![0.0f32; 8 * 10];
        for (i, &l) in d.labels.iter().enumerate() {
            y[i * 10 + l as usize] = 1.0;
        }
        let f = eng.forward(&params, &d.x, &y, 8).unwrap();
        assert_eq!(f.logits.len(), 80);
        let s0 = eng.full_step(&mut params, &d.x, &y, 8, 0.05).unwrap();
        // the fused step exposes the pre-step logits for train accuracy
        assert_eq!(s0.logits.as_ref().unwrap().len(), 80);
        assert_eq!(s0.logits.as_deref(), Some(f.logits.as_slice()));
        let f1 = eng.forward(&params, &d.x, &y, 8).unwrap();
        assert!(f1.loss < s0.loss);
        let tails = eng.tail_grads(&params, &f1, &y, 2, 8).unwrap();
        assert_eq!(tails.len(), 4);
    }
}
