//! The precision-agnostic training session API — the one place the
//! epoch loop lives.
//!
//! The paper's four methods (Full ZO / ZO-Feat-Cls1 / ZO-Feat-Cls2 /
//! Full BP) × two precisions (FP32, INT8/INT8*) are a single family on
//! a method×precision grid (Alg. 1 vs Alg. 2); this module gives them a
//! single driver:
//!
//! * [`TrainSpec`] — the unified run description (method, precision and
//!   its knobs, epochs/batch/schedule seeds, eval cadence, stop flag,
//!   progress sink). Subsumes the former `TrainConfig` and
//!   `Int8TrainConfig`, and (de)serializes to the flat JSON shape the
//!   `serve` protocol ships over the wire.
//! * [`TrainSession`] — per-minibatch work (`step`), per-epoch schedule
//!   application (`begin_epoch`) and dataset evaluation (`evaluate`),
//!   implemented once per backend: `trainer::Fp32Session` over an
//!   [`super::engine::Engine`], `int8_trainer::Int8Session` over the
//!   NITI int8 path.
//! * [`run`] — THE epoch loop: shuffled minibatches, cooperative stop
//!   polling, eval cadence with carry-forward, [`EpochStats`]/
//!   [`History`] bookkeeping, [`PhaseTimer`] rollup and [`ProgressSink`]
//!   publishing. No other epoch loop exists in the coordinator.

use super::checkpoint::{self, CheckpointPolicy, TrainState};
use super::control::{ProgressSink, StopFlag};
use super::elastic::{ElasticController, ElasticSpec, ElasticState};
use super::engine::Method;
use super::int8_trainer::ZoGradMode;
use super::metrics::{EpochStats, History};
use crate::data::loader::{Batch, Loader};
use crate::data::Dataset;
use crate::telemetry::{Phase, PhaseTimer};
use crate::util::json::Value;
use anyhow::{Context, Result};

/// Numeric precision of a run, with the precision-specific knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrecisionSpec {
    /// IEEE float32 over an `Engine` (paper Alg. 1).
    Fp32,
    /// NITI int8 (paper Alg. 2).
    Int8 {
        /// ZO gradient sign: float CE ("INT8") or integer-only ("INT8*").
        grad_mode: ZoGradMode,
        /// Perturbation scale r_max (paper tunes in {1,3,7,15,31,63}).
        r_max: i8,
        /// ZO update bitwidth (paper fixes b_ZO = 1).
        b_zo: u32,
    },
}

impl PrecisionSpec {
    /// Paper-default INT8 knobs for a gradient mode (r_max 15, b_ZO 1).
    pub fn int8(grad_mode: ZoGradMode) -> PrecisionSpec {
        PrecisionSpec::Int8 { grad_mode, r_max: 15, b_zo: 1 }
    }

    /// The canonical CLI/JSON token, matching `config::Precision`:
    /// `fp32`, `int8` (float-CE sign) or `int8*` (integer-only sign).
    pub fn token(&self) -> &'static str {
        match self {
            PrecisionSpec::Fp32 => "fp32",
            PrecisionSpec::Int8 { grad_mode: ZoGradMode::FloatCE, .. } => "int8",
            PrecisionSpec::Int8 { grad_mode: ZoGradMode::IntCE, .. } => "int8*",
        }
    }

    /// Paper column label (`FP32`, `INT8`, `INT8*`).
    pub fn label(&self) -> &'static str {
        match self {
            PrecisionSpec::Fp32 => "FP32",
            PrecisionSpec::Int8 { grad_mode: ZoGradMode::FloatCE, .. } => "INT8",
            PrecisionSpec::Int8 { grad_mode: ZoGradMode::IntCE, .. } => "INT8*",
        }
    }
}

/// The unified training-run description — one spec drives every method
/// × precision cell of the paper's grid through the same [`run`] loop.
#[derive(Debug, Clone)]
pub struct TrainSpec {
    pub method: Method,
    pub precision: PrecisionSpec,
    pub epochs: usize,
    pub batch: usize,
    /// Initial learning rate (FP32 paths; the INT8 update is LR-free).
    pub lr0: f32,
    /// ZO perturbation scale ε (FP32 paths).
    pub eps: f32,
    /// Projected-gradient clip (FP32 paths).
    pub g_clip: f32,
    pub seed: u64,
    /// Evaluate every N epochs (the last epoch always evaluates).
    pub eval_every: usize,
    pub verbose: bool,
    /// Use the chunked/parallel ZO kernels ([`super::kernels`]) for the
    /// hot path. On by default; `false` forces the scalar reference.
    /// Bit-identical either way — this is a perf/memory knob, not a
    /// numerics knob.
    pub kernels: bool,
    /// Structured perturbation: zero whole blocks of `z` (per-layer
    /// blocks of this many elements) from a salted side stream. `0`
    /// (default) disables masking; > 0 requires `kernels` and an fp32
    /// ZO method, and *intentionally* changes the trajectory.
    pub sparse_block: usize,
    /// Fraction of blocks kept when `sparse_block > 0`, in (0, 1].
    pub sparse_keep: f32,
    /// Elastic ZO/BP boundary: when set, the plateau controller may
    /// move `method`'s BP tail within `[min, max]` at epoch granularity
    /// (and the serve dispatcher may negotiate the starting k against
    /// an agent's memory budget). `None` (default) keeps the boundary
    /// fixed. Requires a `Tail(k)` method.
    pub elastic: Option<ElasticSpec>,
    /// Mid-run durability: cadence snapshots at completed-epoch
    /// boundaries (`None` disables them). See
    /// [`checkpoint::CheckpointPolicy`] and [`run_from`].
    pub checkpoint: Option<CheckpointPolicy>,
    /// Cooperative cancellation; polled between batches and epochs.
    pub stop: StopFlag,
    /// Live per-epoch progress callback (armed by the `serve` workers).
    pub progress: ProgressSink,
}

impl Default for TrainSpec {
    fn default() -> Self {
        TrainSpec {
            method: Method::CLS1,
            precision: PrecisionSpec::Fp32,
            epochs: 10,
            batch: 32,
            lr0: 1e-3,
            eps: 1e-2,
            // SPSA's projected gradient scales like √d·|∇L| (d ≈ 10⁵
            // here), so a tight clip is essential — the paper clips g
            // to stabilize training (§5.1.1).
            g_clip: 5.0,
            seed: 1,
            eval_every: 1,
            verbose: false,
            kernels: true,
            sparse_block: 0,
            sparse_keep: 1.0,
            elastic: None,
            checkpoint: None,
            stop: StopFlag::default(),
            progress: ProgressSink::default(),
        }
    }
}

impl TrainSpec {
    /// Paper-style row label: the method, suffixed with the int8 column
    /// tag when applicable ("ZO-Feat-Cls1 INT8*", "Full BP", …).
    pub fn label(&self) -> String {
        match self.precision {
            PrecisionSpec::Fp32 => self.method.label().to_string(),
            p => format!("{} {}", self.method.label(), p.label()),
        }
    }

    /// Serialize to the flat JSON shape shared with `repro train` flags
    /// and the `serve` job protocol. The precision is carried by the
    /// combined `precision` token (`fp32`/`int8`/`int8*`); int8 specs
    /// additionally carry the redundant-but-explicit `grad_mode` token
    /// plus their `r_max`/`b_zo` knobs.
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("method", Value::str(self.method.token())),
            ("precision", Value::str(self.precision.token())),
            ("epochs", Value::num(self.epochs as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("lr", Value::num(self.lr0 as f64)),
            ("eps", Value::num(self.eps as f64)),
            ("g_clip", Value::num(self.g_clip as f64)),
            ("seed", Value::num(self.seed as f64)),
            ("eval_every", Value::num(self.eval_every as f64)),
            ("verbose", Value::Bool(self.verbose)),
        ];
        // default-valued kernel knobs are omitted so default specs stay
        // byte-identical to the pre-kernel JSON shape (checkpoint spec
        // matching, serve wire compatibility)
        if !self.kernels {
            pairs.push(("kernels", Value::Bool(false)));
        }
        if self.sparse_block > 0 {
            pairs.push(("sparse_block", Value::num(self.sparse_block as f64)));
            pairs.push(("sparse_keep", Value::num(self.sparse_keep as f64)));
        }
        if let PrecisionSpec::Int8 { grad_mode, r_max, b_zo } = self.precision {
            pairs.push(("grad_mode", Value::str(grad_mode.token())));
            pairs.push(("r_max", Value::num(r_max as f64)));
            pairs.push(("b_zo", Value::num(b_zo as f64)));
        }
        // the fixed boundary is the default: elastic runs add the
        // `boundary` token (and only non-default controller knobs), so
        // pre-elastic specs keep their exact byte shape
        if let Some(e) = &self.elastic {
            pairs.push(("boundary", Value::str(e.boundary_token())));
            if e.patience != super::elastic::DEFAULT_PATIENCE {
                pairs.push(("elastic_patience", Value::num(e.patience as f64)));
            }
            if e.eps != super::elastic::DEFAULT_EPS {
                pairs.push(("elastic_eps", Value::num(e.eps as f64)));
            }
        }
        if let Some(p) = &self.checkpoint {
            pairs.push(("save", Value::str(p.path.clone())));
            pairs.push(("ckpt_every", Value::num(p.every_n_epochs as f64)));
            pairs.push(("ckpt_keep", Value::num(p.keep_last as f64)));
        }
        Value::obj(pairs)
    }

    /// Parse the shape [`TrainSpec::to_json`] emits. One rule, shared
    /// with the serve protocol: a `grad_mode` token may *refine* a plain
    /// `int8` precision to the integer-only sign, but a true conflict
    /// (`grad_mode` on `fp32`, or `"float"` against `"int8*"`) is an
    /// error. Unknown keys are rejected so wire typos surface instead
    /// of silently training a different run.
    pub fn from_json(v: &Value) -> Result<TrainSpec> {
        let obj = v.as_obj().context("train spec must be a JSON object")?;
        let mut spec = TrainSpec::default();
        let mut int8 = false;
        let mut star = false;
        let mut grad_key: Option<ZoGradMode> = None;
        let mut r_max: i8 = 15;
        let mut b_zo: u32 = 1;
        let mut ckpt_path: Option<String> = None;
        let mut ckpt_every: usize = 1;
        let mut ckpt_keep: usize = 1;
        let mut elastic: Option<ElasticSpec> = None;
        let mut el_patience: Option<usize> = None;
        let mut el_eps: Option<f32> = None;
        let str_of = |k: &str, val: &Value| -> Result<String> {
            Ok(val.as_str().with_context(|| format!("'{k}' must be a string"))?.to_string())
        };
        let num_of = |k: &str, val: &Value| -> Result<f64> {
            val.as_f64().with_context(|| format!("'{k}' must be a number"))
        };
        for (k, val) in obj {
            match k.as_str() {
                "method" => spec.method = Method::parse(&str_of(k, val)?)?,
                "precision" => match str_of(k, val)?.as_str() {
                    "fp32" => int8 = false,
                    "int8" => int8 = true,
                    "int8*" | "int8star" => {
                        int8 = true;
                        star = true;
                    }
                    other => anyhow::bail!("unknown precision '{other}' (fp32|int8|int8*)"),
                },
                "grad_mode" | "grad-mode" => {
                    grad_key = Some(ZoGradMode::parse(&str_of(k, val)?)?)
                }
                "epochs" => spec.epochs = num_of(k, val)? as usize,
                "batch" => spec.batch = num_of(k, val)? as usize,
                "lr" | "lr0" => spec.lr0 = num_of(k, val)? as f32,
                "eps" => spec.eps = num_of(k, val)? as f32,
                "g_clip" | "g-clip" => spec.g_clip = num_of(k, val)? as f32,
                "seed" => spec.seed = num_of(k, val)? as u64,
                "eval_every" | "eval-every" => spec.eval_every = num_of(k, val)? as usize,
                "verbose" => {
                    spec.verbose = val.as_bool().context("'verbose' must be a bool")?
                }
                "kernels" => {
                    spec.kernels = val.as_bool().context("'kernels' must be a bool")?
                }
                "sparse_block" | "sparse-block" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!(n >= 0, "sparse_block must be >= 0");
                    spec.sparse_block = n as usize;
                }
                "sparse_keep" | "sparse-keep" => {
                    let f = num_of(k, val)?;
                    anyhow::ensure!(
                        f > 0.0 && f <= 1.0,
                        "sparse_keep must be in (0, 1]"
                    );
                    spec.sparse_keep = f as f32;
                }
                "r_max" | "r-max" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!((1..=127).contains(&n), "r_max must be in 1..=127");
                    r_max = n as i8;
                }
                "b_zo" | "b-zo" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!((1..=7).contains(&n), "b_zo must be in 1..=7");
                    b_zo = n as u32;
                }
                "boundary" => elastic = ElasticSpec::parse_boundary(&str_of(k, val)?)?,
                "elastic_patience" | "elastic-patience" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!(n >= 1, "elastic_patience must be >= 1");
                    el_patience = Some(n as usize);
                }
                "elastic_eps" | "elastic-eps" => {
                    let f = num_of(k, val)?;
                    anyhow::ensure!(f >= 0.0, "elastic_eps must be >= 0");
                    el_eps = Some(f as f32);
                }
                "save" | "save_checkpoint" | "ckpt_path" => {
                    ckpt_path = Some(str_of(k, val)?)
                }
                "ckpt_every" | "ckpt-every" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!(n >= 0, "ckpt_every must be >= 0");
                    ckpt_every = n as usize;
                }
                "ckpt_keep" | "ckpt-keep" => {
                    let n = num_of(k, val)? as i64;
                    anyhow::ensure!(n >= 1, "ckpt_keep must be >= 1");
                    ckpt_keep = n as usize;
                }
                other => anyhow::bail!("unknown train spec key '{other}'"),
            }
        }
        anyhow::ensure!(spec.epochs > 0 && spec.batch > 0, "batch and epochs must be positive");
        anyhow::ensure!(spec.eval_every >= 1, "eval_every must be >= 1");
        if spec.sparse_block > 0 {
            anyhow::ensure!(
                spec.kernels,
                "sparse_block requires the kernel path (kernels=true)"
            );
            anyhow::ensure!(
                !int8,
                "sparse_block is fp32-only (the int8 path has its own p_zero sparsity)"
            );
            anyhow::ensure!(
                spec.method != Method::FullBp,
                "sparse_block requires a ZO method (full-bp has no perturbation)"
            );
        }
        if let Some(e) = &mut elastic {
            if let Some(p) = el_patience {
                e.patience = p;
            }
            if let Some(f) = el_eps {
                e.eps = f;
            }
            let k0 = spec.method.bp_tail().with_context(|| {
                format!("an elastic boundary requires a bp-tail method, not '{}'", spec.method.token())
            })?;
            anyhow::ensure!(
                (e.min..=e.max).contains(&k0),
                "method '{}' starts outside the elastic range {}-{}",
                spec.method.token(),
                e.min,
                e.max
            );
        } else {
            anyhow::ensure!(
                el_patience.is_none() && el_eps.is_none(),
                "elastic_patience/elastic_eps require boundary=elastic:<min>-<max>"
            );
        }
        spec.elastic = elastic;
        let grad_mode = resolve_grad_mode(int8, star, grad_key)?;
        spec.precision = if int8 {
            PrecisionSpec::Int8 { grad_mode, r_max, b_zo }
        } else {
            PrecisionSpec::Fp32
        };
        // a checkpoint path with a nonzero cadence arms mid-run snapshots
        spec.checkpoint = ckpt_path.filter(|_| ckpt_every > 0).map(|path| CheckpointPolicy {
            path,
            every_n_epochs: ckpt_every,
            keep_last: ckpt_keep,
        });
        Ok(spec)
    }
}

/// The one wire rule for the `precision` × `grad_mode` pair, shared by
/// [`TrainSpec::from_json`] and the serve protocol so the two layers
/// can never disagree on the same bytes:
///
/// * `fp32` + any `grad_mode` key → error (meaningless);
/// * plain `int8` + `"int"` → refined to the integer-only sign (INT8*);
/// * `int8*` + `"float"` → error (true conflict);
/// * consistent/absent combinations pass through.
///
/// `star` is whether the precision token itself was `int8*`.
pub fn resolve_grad_mode(
    int8: bool,
    star: bool,
    grad_key: Option<ZoGradMode>,
) -> Result<ZoGradMode> {
    match (int8, star, grad_key) {
        (false, _, Some(gm)) => {
            anyhow::bail!("grad_mode '{}' requires an int8 precision", gm.token())
        }
        (true, true, Some(ZoGradMode::FloatCE)) => {
            anyhow::bail!("grad_mode 'float' conflicts with precision 'int8*'")
        }
        (_, true, _) => Ok(ZoGradMode::IntCE),
        (_, false, Some(gm)) => Ok(gm),
        (_, false, None) => Ok(ZoGradMode::FloatCE),
    }
}

/// What one minibatch update reports back to the loop.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepOutcome {
    /// Minibatch train loss.
    pub loss: f32,
    /// Correct predictions among `seen` (train accuracy numerator).
    pub correct: usize,
    /// Samples the `correct` count covers (0 when the backend exposes
    /// no logits for this step, e.g. logits-less AOT full-BP artifacts).
    pub seen: usize,
}

/// One backend of the unified loop: per-batch work + evaluation.
///
/// Implementations own the model state (an `Engine` + `ParamSet`, or
/// the NITI weight tensors) and any precision-specific schedules; the
/// generic [`run`] owns everything else.
pub trait TrainSession {
    /// Row label for history/logs ("ZO-Feat-Cls1 INT8*", "Full BP", …).
    fn label(&self) -> String;

    /// Apply per-epoch schedules (LR decay, p_zero/b_BP stages).
    /// Returns the effective learning rate for bookkeeping (0.0 where
    /// the update has no LR, as in the int8 path).
    fn begin_epoch(&mut self, epoch: usize) -> f32;

    /// One minibatch update. `step_idx` is the global step counter (the
    /// ZO seed-trick input); phase timings go into `timer`.
    fn step(&mut self, b: &Batch, step_idx: u64, timer: &mut PhaseTimer) -> Result<StepOutcome>;

    /// Mean loss and accuracy over a dataset.
    fn evaluate(&mut self, data: &Dataset) -> Result<(f32, f32)>;

    /// Extra fields for the verbose per-epoch line (current schedule
    /// values etc.); empty by default. Read after the epoch's steps.
    fn verbose_note(&self) -> String {
        String::new()
    }

    /// The model state as checkpoint tensors — what a cadence snapshot
    /// persists ([`TrainSpec::checkpoint`]). The default is empty (a
    /// non-checkpointable session, e.g. test fakes); real backends
    /// return their full parameter set.
    fn snapshot(&self) -> Vec<checkpoint::CkptTensor> {
        Vec::new()
    }

    /// Move the ZO/BP boundary to BP on the last `k` layers, effective
    /// from the next step. Called by the epoch loop when an elastic
    /// spec's controller decides to move (and on resume, to restore a
    /// mid-run boundary). Backends that cannot re-partition reject —
    /// the default — and the loop surfaces the error.
    fn set_bp_tail(&mut self, k: usize) -> Result<()> {
        anyhow::bail!("this session cannot move its ZO/BP boundary (to bp-tail={k}) mid-run")
    }
}

/// Outcome of a training run.
pub struct TrainResult {
    pub history: History,
    pub timer: PhaseTimer,
    /// True iff the run ended early because [`TrainSpec::stop`] fired.
    pub stopped: bool,
    /// Final value of the global step counter (the ZO stream position)
    /// — resumed runs start from the checkpoint's counter, so this is
    /// the all-time count, not just this process's.
    pub steps_done: u64,
    /// Elastic-boundary controller state at the end of the run (`None`
    /// for fixed-boundary specs) — stamped into the final checkpoint's
    /// trailer by [`final_state`].
    pub elastic: Option<ElasticState>,
}

/// Drive a session through `spec.epochs` epochs — the single epoch loop
/// behind every method × precision combination, `repro train`, every
/// `exp` harness and the `serve` workers.
pub fn run(
    session: &mut dyn TrainSession,
    spec: &TrainSpec,
    train_data: &Dataset,
    test_data: &Dataset,
) -> Result<TrainResult> {
    run_from(session, spec, train_data, test_data, None)
}

/// [`run`], optionally continuing from a checkpoint's [`TrainState`]:
/// epochs `state.epochs_done..spec.epochs` execute with the global
/// step counter, eval carry-forward and best-accuracy bookkeeping
/// restored. Because minibatch order is a pure function of
/// `(seed, epoch)` and ZO perturbations of `(seed, step)`, a resumed
/// run replays the exact streams of an uninterrupted one — the caller
/// restores the params from the same checkpoint (`launch::run` does).
pub fn run_from(
    session: &mut dyn TrainSession,
    spec: &TrainSpec,
    train_data: &Dataset,
    test_data: &Dataset,
    resume: Option<&TrainState>,
) -> Result<TrainResult> {
    let mut history = History::new(&session.label());
    let mut timer = PhaseTimer::new();
    let start_epoch = resume.map_or(0, |s| s.epochs_done);
    let mut step: u64 = resume.map_or(0, |s| s.step);
    let mut best = resume.map_or(0.0f32, |s| s.best_test_acc);
    // eval carry-forward across the resume boundary
    let carry = resume.map_or((f32::NAN, 0.0), |s| (s.last_test_loss, s.last_test_acc));
    let mut stopped = false;

    // elastic boundary: rebuild the controller (from the checkpoint
    // trailer when resuming) and restore any mid-run boundary before
    // the first step, so a resumed run replays the k-schedule exactly
    let mut elastic: Option<ElasticController> = spec.elastic.map(|es| {
        match resume.and_then(|s| s.elastic.clone()) {
            Some(st) => ElasticController::from_state(es, st),
            None => ElasticController::new(es, spec.method.bp_tail().unwrap_or(0)),
        }
    });
    if let Some(c) = &elastic {
        if c.k() != spec.method.bp_tail().unwrap_or(0) {
            session.set_bp_tail(c.k())?;
        }
    }

    'epochs: for epoch in start_epoch..spec.epochs {
        if spec.stop.should_stop() {
            stopped = true;
            break;
        }
        let epoch_t0 = std::time::Instant::now();
        // phase deltas for this epoch = cumulative timer minus this mark
        let phase_mark = timer.clone();
        let lr = session.begin_epoch(epoch);
        let mut epoch_loss = 0.0f64;
        let mut nbatches = 0usize;
        let mut correct = 0usize;
        let mut seen = 0usize;

        for b in Loader::new(train_data, spec.batch, spec.seed ^ 0xDA7A, epoch as u64) {
            if spec.stop.should_stop() {
                stopped = true;
                break 'epochs;
            }
            let out = session.step(&b, step, &mut timer)?;
            epoch_loss += out.loss as f64;
            correct += out.correct;
            seen += out.seen;
            nbatches += 1;
            step += 1;
        }

        let is_last = epoch + 1 == spec.epochs;
        let fresh_eval = epoch % spec.eval_every == 0 || is_last;
        let (test_loss, test_acc) = if fresh_eval {
            let t0 = std::time::Instant::now();
            let r = session.evaluate(test_data)?;
            timer.add(Phase::Eval, t0.elapsed());
            r
        } else {
            // off-cadence epochs carry the previous eval forward (the
            // resume state supplies it across a resume boundary)
            let prev = history.epochs.last();
            (
                prev.map_or(carry.0, |e| e.test_loss),
                prev.map_or(carry.1, |e| e.test_acc),
            )
        };

        // the plateau controller sees only fresh evals (carry-forward
        // epochs are invisible); a decision re-partitions the session
        // now, so it takes effect from the next epoch's steps and is
        // captured by this epoch's stats + cadence snapshot
        if fresh_eval {
            if let Some(c) = elastic.as_mut() {
                if let Some(new_k) = c.observe(epoch, test_loss) {
                    session.set_bp_tail(new_k)?;
                    if spec.verbose {
                        println!(
                            "[{}] epoch {epoch}: elastic boundary -> bp-tail={new_k}",
                            history.label
                        );
                    }
                }
            }
        }

        let stats = EpochStats {
            epoch,
            train_loss: (epoch_loss / nbatches.max(1) as f64) as f32,
            test_loss,
            train_acc: if seen > 0 { correct as f32 / seen as f32 } else { 0.0 },
            test_acc,
            lr,
            seconds: epoch_t0.elapsed().as_secs_f64(),
            phases: timer.deltas_since(&phase_mark),
            bp_tail: elastic.as_ref().map(|c| c.k()).or_else(|| spec.method.bp_tail()),
        };
        if spec.verbose {
            println!(
                "[{}] epoch {:>3}  loss {:.4}  test_loss {:.4}  acc {:.2}%  train_acc {:.2}%  lr {:.5}{}",
                history.label,
                epoch,
                stats.train_loss,
                stats.test_loss,
                stats.test_acc * 100.0,
                stats.train_acc * 100.0,
                lr,
                session.verbose_note()
            );
        }
        best = best.max(stats.test_acc);
        spec.progress.publish(&stats);
        history.push(stats);

        // cadence snapshot at the completed-epoch boundary: params +
        // loop state, so a kill after this point loses at most the
        // epochs since the last snapshot
        if let Some(p) = &spec.checkpoint {
            if p.every_n_epochs > 0 && (epoch + 1) % p.every_n_epochs == 0 {
                let last = history.epochs.last().expect("epoch just pushed");
                let state = TrainState {
                    epochs_done: epoch + 1,
                    step,
                    best_test_acc: best,
                    last_test_loss: last.test_loss,
                    last_test_acc: last.test_acc,
                    spec: spec.to_json(),
                    elastic: elastic.as_ref().map(|c| c.state()),
                };
                checkpoint::write_snapshot(p, &session.snapshot(), Some(&state))
                    .with_context(|| format!("writing cadence snapshot {}", p.path))?;
            }
        }
    }

    Ok(TrainResult {
        history,
        timer,
        stopped,
        steps_done: step,
        elastic: elastic.map(|c| c.state()),
    })
}

/// The [`TrainState`] describing a finished run — what `launch::run`
/// embeds in the final checkpoint so even a completed run's file can
/// seed further training (e.g. a spec with more epochs is a mismatch,
/// but listing/inspection tools see the full picture).
pub fn final_state(
    spec: &TrainSpec,
    result: &TrainResult,
    resume: Option<&TrainState>,
) -> TrainState {
    let last = result.history.epochs.last();
    TrainState {
        epochs_done: last
            .map(|e| e.epoch + 1)
            .or(resume.map(|s| s.epochs_done))
            .unwrap_or(0),
        step: result.steps_done,
        best_test_acc: result
            .history
            .best_test_acc()
            .max(resume.map_or(0.0, |s| s.best_test_acc)),
        last_test_loss: last
            .map(|e| e.test_loss)
            .or(resume.map(|s| s.last_test_loss))
            .unwrap_or(f32::NAN),
        last_test_acc: last
            .map(|e| e.test_acc)
            .or(resume.map(|s| s.last_test_acc))
            .unwrap_or(0.0),
        spec: spec.to_json(),
        elastic: result
            .elastic
            .clone()
            .or_else(|| resume.and_then(|s| s.elastic.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    /// A deterministic no-train session for loop-behaviour tests.
    struct FakeSession {
        loss: f32,
        evals: usize,
        steps: usize,
        epochs_begun: Vec<usize>,
    }

    impl FakeSession {
        fn new() -> FakeSession {
            FakeSession { loss: 2.0, evals: 0, steps: 0, epochs_begun: Vec::new() }
        }
    }

    impl TrainSession for FakeSession {
        fn label(&self) -> String {
            "fake".to_string()
        }
        fn begin_epoch(&mut self, epoch: usize) -> f32 {
            self.epochs_begun.push(epoch);
            0.5
        }
        fn step(&mut self, b: &Batch, _s: u64, _t: &mut PhaseTimer) -> Result<StepOutcome> {
            self.steps += 1;
            self.loss *= 0.9;
            Ok(StepOutcome { loss: self.loss, correct: b.bsz / 2, seen: b.bsz })
        }
        fn evaluate(&mut self, _d: &Dataset) -> Result<(f32, f32)> {
            self.evals += 1;
            Ok((1.0 / self.evals as f32, 0.25 * self.evals as f32))
        }
    }

    #[test]
    fn eval_cadence_carries_forward() {
        let d = synth_mnist::generate(64, 1);
        let spec = TrainSpec { epochs: 5, batch: 16, eval_every: 2, ..Default::default() };
        let mut s = FakeSession::new();
        let r = run(&mut s, &spec, &d, &d).unwrap();
        assert_eq!(r.history.epochs.len(), 5);
        // evals at epochs 0, 2, 4 only
        assert_eq!(s.evals, 3);
        let e = &r.history.epochs;
        assert_eq!(e[1].test_acc, e[0].test_acc, "epoch 1 must carry epoch 0's eval");
        assert_eq!(e[1].test_loss, e[0].test_loss);
        assert_ne!(e[2].test_acc, e[1].test_acc);
        assert_eq!(e[3].test_acc, e[2].test_acc);
        // bookkeeping from the session
        assert_eq!(s.epochs_begun, vec![0, 1, 2, 3, 4]);
        assert_eq!(e[0].lr, 0.5);
        assert!((e[0].train_acc - 0.5).abs() < 1e-6);
        assert_eq!(s.steps, 5 * 4); // 64 samples / batch 16 = 4 per epoch
    }

    #[test]
    fn stop_flag_ends_run_after_reporting_epoch() {
        let d = synth_mnist::generate(32, 2);
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        let spec = TrainSpec {
            epochs: 100,
            batch: 16,
            progress: ProgressSink::new(move |e| {
                if e.epoch == 0 {
                    stop2.request_stop();
                }
            }),
            stop,
            ..Default::default()
        };
        let mut s = FakeSession::new();
        let r = run(&mut s, &spec, &d, &d).unwrap();
        assert!(r.stopped);
        assert_eq!(r.history.epochs.len(), 1, "must stop right after epoch 0");
    }

    #[test]
    fn labels_cover_the_paper_grid() {
        let mut spec = TrainSpec { method: Method::CLS1, ..Default::default() };
        assert_eq!(spec.label(), "ZO-Feat-Cls1");
        spec.precision = PrecisionSpec::int8(ZoGradMode::FloatCE);
        assert_eq!(spec.label(), "ZO-Feat-Cls1 INT8");
        spec.precision = PrecisionSpec::int8(ZoGradMode::IntCE);
        assert_eq!(spec.label(), "ZO-Feat-Cls1 INT8*");
    }

    #[test]
    fn spec_json_roundtrips_fp32_and_int8() {
        let fp32 = TrainSpec {
            method: Method::FullBp,
            epochs: 7,
            batch: 64,
            lr0: 0.05,
            eval_every: 3,
            verbose: true,
            ..Default::default()
        };
        let back = TrainSpec::from_json(&fp32.to_json()).unwrap();
        assert_eq!(back.to_json(), fp32.to_json());

        let int8 = TrainSpec {
            method: Method::CLS2,
            precision: PrecisionSpec::Int8 {
                grad_mode: ZoGradMode::IntCE,
                r_max: 31,
                b_zo: 2,
            },
            epochs: 4,
            seed: 9,
            ..Default::default()
        };
        let v = int8.to_json();
        assert_eq!(v.get("precision").as_str(), Some("int8*"));
        assert_eq!(v.get("grad_mode").as_str(), Some("int"));
        let back = TrainSpec::from_json(&v).unwrap();
        assert_eq!(back.to_json(), v);
        assert_eq!(back.precision, int8.precision);
    }

    #[test]
    fn spec_json_kernel_knobs_roundtrip_and_stay_off_the_default_wire() {
        // defaults emit NO kernel keys — byte-compatible with pre-kernel
        // specs (old checkpoints keep matching)
        let v = TrainSpec::default().to_json();
        assert!(v.get("kernels").as_bool().is_none());
        assert!(v.get("sparse_block").as_f64().is_none());

        let scalar = TrainSpec { kernels: false, ..Default::default() };
        let v = scalar.to_json();
        assert_eq!(v.get("kernels").as_bool(), Some(false));
        assert!(!TrainSpec::from_json(&v).unwrap().kernels);

        let sparse = TrainSpec {
            method: Method::FULL_ZO,
            sparse_block: 64,
            sparse_keep: 0.25,
            ..Default::default()
        };
        let v = sparse.to_json();
        let back = TrainSpec::from_json(&v).unwrap();
        assert_eq!(back.sparse_block, 64);
        assert_eq!(back.sparse_keep, 0.25);
        assert_eq!(back.to_json(), v);
    }

    #[test]
    fn spec_json_rejects_bad_sparse_combos() {
        for bad in [
            r#"{"sparse_block": 64, "kernels": false}"#,
            r#"{"sparse_block": 64, "precision": "int8"}"#,
            r#"{"sparse_block": 64, "method": "full-bp"}"#,
            r#"{"sparse_block": 64, "sparse_keep": 0.0}"#,
            r#"{"sparse_keep": 1.5}"#,
            r#"{"kernels": 1}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert!(TrainSpec::from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn spec_json_grad_mode_refines_plain_int8() {
        let v = crate::util::json::parse(
            r#"{"precision": "int8", "grad_mode": "int", "method": "cls1"}"#,
        )
        .unwrap();
        let spec = TrainSpec::from_json(&v).unwrap();
        assert_eq!(spec.precision, PrecisionSpec::int8(ZoGradMode::IntCE));
        assert_eq!(spec.precision.token(), "int8*");
    }

    #[test]
    fn spec_json_rejects_unknown_keys_and_bad_values() {
        for bad in [
            r#"{"optimzer": "adam"}"#,
            r#"{"precision": "bf16"}"#,
            r#"{"epochs": 0}"#,
            r#"{"eval_every": 0}"#,
            r#"{"r_max": 0}"#,
            r#"{"ckpt_keep": 0}"#,
            r#"{"precision": "fp32", "grad_mode": "int"}"#,
            r#"{"precision": "int8*", "grad_mode": "float"}"#,
            r#"[1]"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert!(TrainSpec::from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn spec_json_roundtrips_checkpoint_policy() {
        let spec = TrainSpec {
            checkpoint: Some(CheckpointPolicy {
                path: "/tmp/run.ckpt".into(),
                every_n_epochs: 2,
                keep_last: 3,
            }),
            ..Default::default()
        };
        let v = spec.to_json();
        assert_eq!(v.get("save").as_str(), Some("/tmp/run.ckpt"));
        let back = TrainSpec::from_json(&v).unwrap();
        assert_eq!(back.checkpoint, spec.checkpoint);
        assert_eq!(back.to_json(), v);
        // a zero cadence disarms the policy even with a path
        let v = crate::util::json::parse(r#"{"save": "/tmp/x", "ckpt_every": 0}"#).unwrap();
        assert_eq!(TrainSpec::from_json(&v).unwrap().checkpoint, None);
    }

    #[test]
    fn cadence_snapshots_write_resumable_state() {
        let d = synth_mnist::generate(64, 1);
        let path = std::env::temp_dir()
            .join(format!("ezo_cadence_{}", std::process::id()))
            .display()
            .to_string();
        let spec = TrainSpec {
            epochs: 5,
            batch: 16,
            checkpoint: Some(CheckpointPolicy {
                path: path.clone(),
                every_n_epochs: 2,
                keep_last: 1,
            }),
            ..Default::default()
        };
        let mut s = FakeSession::new();
        let r = run(&mut s, &spec, &d, &d).unwrap();
        assert_eq!(r.steps_done, 5 * 4, "64 samples / batch 16 over 5 epochs");
        let (tensors, state) = checkpoint::load_full(&path).unwrap();
        let state = state.expect("cadence snapshot must carry training state");
        // snapshots fire after epochs 2 and 4; the file holds the last
        assert_eq!(state.epochs_done, 4);
        assert_eq!(state.step, 4 * 4);
        assert!(tensors.is_empty(), "FakeSession has no params");
        checkpoint::ensure_spec_matches(&state.spec, &spec.to_json()).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn run_from_restores_step_carry_and_epoch_range() {
        let d = synth_mnist::generate(64, 1);
        let spec = TrainSpec { epochs: 6, batch: 16, eval_every: 4, ..Default::default() };
        let state = TrainState {
            epochs_done: 3,
            step: 12,
            best_test_acc: 0.9,
            last_test_loss: 1.5,
            last_test_acc: 0.75,
            spec: spec.to_json(),
            elastic: None,
        };
        let mut s = FakeSession::new();
        let r = run_from(&mut s, &spec, &d, &d, Some(&state)).unwrap();
        // epochs 3, 4, 5 run; 3 is off-cadence (3 % 4 != 0) so it
        // carries the resume state's eval forward
        assert_eq!(s.epochs_begun, vec![3, 4, 5]);
        assert_eq!(r.history.epochs.len(), 3);
        assert_eq!(r.history.epochs[0].epoch, 3);
        assert_eq!(r.history.epochs[0].test_loss, 1.5);
        assert_eq!(r.history.epochs[0].test_acc, 0.75);
        // epoch 4 is on-cadence, epoch 5 is last: both evaluate
        assert_eq!(s.evals, 2);
        assert_eq!(r.steps_done, 12 + 3 * 4);
    }
}
