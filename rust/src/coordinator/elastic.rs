//! The elastic ZO/BP boundary: negotiation at assignment time and the
//! mid-run plateau controller.
//!
//! The boundary (`Method::Tail(k)`) is a first-class runtime quantity:
//!
//! * **Negotiation** — given an agent's memory budget, pick the deepest
//!   BP tail whose analytic footprint (paper Eqs. 2–5 / 13–15) fits.
//!   [`candidate_rows`] is the one table both `repro train
//!   --mem-report` and the coordinator's assignment path evaluate, so
//!   what operators see printed is exactly what the dispatcher decides
//!   on.
//! * **Mid-run control** — [`ElasticController`] watches *fresh* eval
//!   losses for a plateau (patience/epsilon from the spec) and deepens
//!   or shallows the boundary at epoch granularity. It is a pure,
//!   deterministic function of the observed loss sequence, so resuming
//!   from a checkpoint (or replaying the journal) reproduces the same
//!   k-schedule — and therefore the same trajectory — bit-identically.

use super::engine::Method;
use super::params::Model;
use crate::memory;
use crate::util::json::Value;
use anyhow::{Context, Result};

/// Default plateau patience (fresh evals without improvement).
pub const DEFAULT_PATIENCE: usize = 2;
/// Default improvement threshold on eval loss.
pub const DEFAULT_EPS: f32 = 1e-3;

/// Spec-level description of an elastic boundary: the k-range the
/// controller (and the assignment negotiation) may move within, plus
/// the plateau detector's knobs. Carried inside [`super::TrainSpec`]
/// and serialized with it (`boundary: "elastic:<min>-<max>"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticSpec {
    /// Shallowest BP tail allowed (inclusive).
    pub min: usize,
    /// Deepest BP tail allowed (inclusive).
    pub max: usize,
    /// Fresh evals without improvement before the controller acts.
    pub patience: usize,
    /// Eval-loss improvement threshold (absolute).
    pub eps: f32,
}

impl ElasticSpec {
    pub fn new(min: usize, max: usize) -> ElasticSpec {
        ElasticSpec { min, max, patience: DEFAULT_PATIENCE, eps: DEFAULT_EPS }
    }

    /// Parse the `boundary` token: `fixed` (no elastic range) or
    /// `elastic:<min>-<max>`.
    pub fn parse_boundary(s: &str) -> Result<Option<ElasticSpec>> {
        if s == "fixed" {
            return Ok(None);
        }
        let range = s
            .strip_prefix("elastic:")
            .with_context(|| format!("boundary must be fixed|elastic:<min>-<max>, got '{s}'"))?;
        let (lo, hi) = range
            .split_once('-')
            .with_context(|| format!("elastic range must be <min>-<max>, got '{range}'"))?;
        let min: usize = lo.parse().with_context(|| format!("elastic min '{lo}'"))?;
        let max: usize = hi.parse().with_context(|| format!("elastic max '{hi}'"))?;
        anyhow::ensure!(min <= max, "elastic range must have min <= max, got {min}-{max}");
        Ok(Some(ElasticSpec::new(min, max)))
    }

    /// The `boundary` token [`parse_boundary`] accepts.
    pub fn boundary_token(&self) -> String {
        format!("elastic:{}-{}", self.min, self.max)
    }
}

/// The controller's resumable state — stamped into the checkpoint
/// trailer ([`super::checkpoint::TrainState::elastic`]) so `--resume`
/// and journal replay reproduce the k-schedule exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticState {
    /// Boundary currently in effect.
    pub k: usize,
    /// Best eval loss seen since the last boundary change.
    pub best: f32,
    /// Fresh evals since the last improvement (or change).
    pub stale: usize,
    /// Applied changes as `(epoch, new_k)`, in order.
    pub events: Vec<(usize, usize)>,
}

impl ElasticState {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("k", Value::num(self.k as f64)),
            (
                "best",
                if self.best.is_finite() { Value::num(self.best as f64) } else { Value::Null },
            ),
            ("stale", Value::num(self.stale as f64)),
            (
                "events",
                Value::Arr(
                    self.events
                        .iter()
                        .map(|(e, k)| {
                            Value::Arr(vec![Value::num(*e as f64), Value::num(*k as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ElasticState> {
        let k = v.get("k").as_f64().context("elastic state needs 'k'")? as usize;
        let best = v.get("best").as_f64().map_or(f32::INFINITY, |b| b as f32);
        let stale = v.get("stale").as_f64().unwrap_or(0.0) as usize;
        let mut events = Vec::new();
        if let Value::Arr(items) = v.get("events") {
            for it in items {
                match it {
                    Value::Arr(pair) if pair.len() == 2 => {
                        let e = pair[0].as_f64().context("event epoch")? as usize;
                        let nk = pair[1].as_f64().context("event k")? as usize;
                        events.push((e, nk));
                    }
                    other => anyhow::bail!("elastic event must be [epoch, k], got {other:?}"),
                }
            }
        }
        Ok(ElasticState { k, best, stale, events })
    }
}

/// Plateau-driven boundary controller. Observes only *fresh* eval
/// losses (carry-forward epochs are invisible to it); on `patience`
/// stale evals it deepens the tail — or shallows it when the loss has
/// actually regressed past `best + eps` — then resets its counters.
#[derive(Debug, Clone)]
pub struct ElasticController {
    spec: ElasticSpec,
    state: ElasticState,
}

impl ElasticController {
    /// Fresh controller starting at boundary `k0` (clamped into range).
    pub fn new(spec: ElasticSpec, k0: usize) -> ElasticController {
        let k = k0.clamp(spec.min, spec.max);
        ElasticController {
            spec,
            state: ElasticState { k, best: f32::INFINITY, stale: 0, events: Vec::new() },
        }
    }

    /// Resume from a checkpoint trailer's state.
    pub fn from_state(spec: ElasticSpec, state: ElasticState) -> ElasticController {
        ElasticController { spec, state }
    }

    /// Boundary currently in effect.
    pub fn k(&self) -> usize {
        self.state.k
    }

    /// The resumable state (for checkpoint trailers).
    pub fn state(&self) -> ElasticState {
        self.state.clone()
    }

    /// Feed one *fresh* eval loss at `epoch`. Returns `Some(new_k)`
    /// when the boundary changes (the caller applies it to the session;
    /// it takes effect from the next epoch's steps).
    pub fn observe(&mut self, epoch: usize, eval_loss: f32) -> Option<usize> {
        if eval_loss.is_finite() && eval_loss < self.state.best - self.spec.eps {
            self.state.best = eval_loss;
            self.state.stale = 0;
            return None;
        }
        self.state.stale += 1;
        if self.state.stale < self.spec.patience {
            return None;
        }
        // plateaued: deepen to buy gradient signal; a genuine
        // regression shallows instead (the deeper tail hurt)
        let regressing =
            eval_loss.is_finite() && eval_loss > self.state.best + self.spec.eps;
        let new_k = if regressing && self.state.k > self.spec.min {
            self.state.k - 1
        } else if self.state.k < self.spec.max {
            self.state.k + 1
        } else {
            // pinned at the range edge: reset the counter and keep going
            self.state.stale = 0;
            return None;
        };
        self.state.k = new_k;
        self.state.best = if eval_loss.is_finite() { eval_loss } else { f32::INFINITY };
        self.state.stale = 0;
        self.state.events.push((epoch, new_k));
        Some(new_k)
    }
}

/// One row of the negotiation table: a candidate method and its
/// analytic memory total (bytes) from the paper's model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemRow {
    pub method: Method,
    pub total: usize,
}

/// Analytic totals for every candidate boundary of `model` — one row
/// per `k ∈ 0..=max_bp_tail` plus Full BP. This is the SAME table
/// `repro train --mem-report` prints and the dispatcher negotiates
/// over.
pub fn candidate_rows(model: Model, batch: usize, int8: bool, adam: bool) -> Vec<MemRow> {
    let mut rows: Vec<Method> =
        (0..=model.max_bp_tail()).map(Method::Tail).collect();
    rows.push(Method::FullBp);
    rows.into_iter()
        .map(|m| MemRow { method: m, total: modeled_total(model, batch, m, int8, adam) })
        .collect()
}

/// Analytic total (bytes) for one method, fp32 or int8.
pub fn modeled_total(model: Model, batch: usize, method: Method, int8: bool, adam: bool) -> usize {
    if int8 {
        // INT8 is lenet-only (as in the paper); its memory-model layer
        // table differs from the fp32 one (no biases, int32 scratch)
        let layers = memory::models::lenet_int8_layers();
        memory::int8(&layers, batch, method.memory_method()).total()
    } else {
        memory::fp32(&model.memory_layers(), batch, method.memory_method(), adam).total()
    }
}

/// The deepest BP tail in `[min, max]` whose modeled total fits
/// `budget` bytes. Falls back to `min` when even the shallowest
/// candidate is over budget (the job still runs; the agent is merely
/// over its stated budget, which the caller can surface).
pub fn negotiate_k(
    model: Model,
    batch: usize,
    int8: bool,
    budget: usize,
    min: usize,
    max: usize,
) -> usize {
    let max = max.min(model.max_bp_tail());
    let mut best = min;
    for k in min..=max {
        if modeled_total(model, batch, Method::Tail(k), int8, false) <= budget {
            best = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_token_roundtrip() {
        let e = ElasticSpec::parse_boundary("elastic:1-3").unwrap().unwrap();
        assert_eq!((e.min, e.max), (1, 3));
        assert_eq!((e.patience, e.eps), (DEFAULT_PATIENCE, DEFAULT_EPS));
        assert_eq!(ElasticSpec::parse_boundary(&e.boundary_token()).unwrap(), Some(e));
        assert_eq!(ElasticSpec::parse_boundary("fixed").unwrap(), None);
        assert!(ElasticSpec::parse_boundary("elastic:3-1").is_err());
        assert!(ElasticSpec::parse_boundary("elastic").is_err());
        assert!(ElasticSpec::parse_boundary("rubber").is_err());
    }

    #[test]
    fn controller_deepens_on_plateau_and_shallows_on_regression() {
        let mut c = ElasticController::new(ElasticSpec::new(0, 3), 1);
        assert_eq!(c.k(), 1);
        // improving: no change
        assert_eq!(c.observe(0, 2.0), None);
        assert_eq!(c.observe(1, 1.5), None);
        // flat for `patience` evals: deepen
        assert_eq!(c.observe(2, 1.5), None);
        assert_eq!(c.observe(3, 1.5), Some(2));
        assert_eq!(c.k(), 2);
        // regression past eps: shallow back
        assert_eq!(c.observe(4, 1.8), None);
        assert_eq!(c.observe(5, 1.9), Some(1));
        assert_eq!(c.k(), 1);
        assert_eq!(c.state().events, vec![(3, 2), (5, 1)]);
    }

    #[test]
    fn controller_is_pinned_at_range_edges() {
        let mut c = ElasticController::new(ElasticSpec::new(2, 2), 0);
        assert_eq!(c.k(), 2, "k0 clamps into range");
        for e in 0..10 {
            assert_eq!(c.observe(e, 1.0), None, "a 1-wide range never moves");
        }
    }

    #[test]
    fn controller_replay_is_deterministic() {
        let losses = [2.0, 1.5, 1.5, 1.5, 1.8, 1.9, 1.2, 1.2, 1.2, 0.9];
        let run = || {
            let mut c = ElasticController::new(ElasticSpec::new(0, 3), 1);
            for (e, l) in losses.iter().enumerate() {
                c.observe(e, *l);
            }
            c.state()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn state_json_roundtrips() {
        let st = ElasticState { k: 2, best: 1.25, stale: 1, events: vec![(3, 2), (7, 1)] };
        let back = ElasticState::from_json(&st.to_json()).unwrap();
        assert_eq!(back, st);
        // a fresh (infinite-best) state survives the Null encoding
        let st = ElasticState { k: 0, best: f32::INFINITY, stale: 0, events: vec![] };
        let back = ElasticState::from_json(&st.to_json()).unwrap();
        assert_eq!(back, st);
    }

    #[test]
    fn negotiation_picks_deepest_fitting_tail() {
        let model = Model::LeNet;
        let rows = candidate_rows(model, 32, false, false);
        // 0..=3 tails plus full-bp
        assert_eq!(rows.len(), 5);
        // totals are monotone in k (deeper BP stores more errors/grads)
        for w in rows.windows(2) {
            assert!(w[0].total <= w[1].total, "{:?}", rows);
        }
        // an unconstrained budget gets the deepest tail...
        assert_eq!(negotiate_k(model, 32, false, usize::MAX, 0, 3), 3);
        // ...a budget below the k=1 row pins to the floor...
        assert_eq!(negotiate_k(model, 32, false, 0, 0, 3), 0);
        // ...and a budget exactly at the k=2 row stops there
        let k2 = modeled_total(model, 32, Method::Tail(2), false, false);
        let k3 = modeled_total(model, 32, Method::Tail(3), false, false);
        assert!(k2 < k3);
        assert_eq!(negotiate_k(model, 32, false, k2, 0, 3), 2);
    }
}
