//! Hyper-parameter schedules (paper §5.1.1).
//!
//! * FP32: learning rate decays ×0.8 every 10 epochs (scaled to the
//!   configured run length so short reproductions keep the same shape).
//! * INT8: BP gradient bitwidth 5→4→3 and update sparsity p_zero
//!   0.33→0.5→0.9 at 20% / 50% of the run (the paper's 20/100 and
//!   50/100 epoch marks).

/// Step-decay learning rate: `lr0 · factor^(epoch / every)`.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub lr0: f32,
    pub factor: f32,
    pub every: usize,
}

impl LrSchedule {
    pub fn paper_fp32(lr0: f32, total_epochs: usize) -> LrSchedule {
        // paper: ×0.8 every 10 of 100 epochs → every 10% of the run
        let every = (total_epochs / 10).max(1);
        LrSchedule { lr0, factor: 0.8, every }
    }

    pub fn lr(&self, epoch: usize) -> f32 {
        self.lr0 * self.factor.powi((epoch / self.every) as i32)
    }
}

/// Piecewise-constant schedule over epoch fractions.
#[derive(Debug, Clone)]
pub struct StagedSchedule<T: Copy> {
    /// `(start_fraction, value)`, ascending; first entry must be 0.0.
    pub stages: Vec<(f32, T)>,
    pub total_epochs: usize,
}

impl<T: Copy> StagedSchedule<T> {
    pub fn new(stages: Vec<(f32, T)>, total_epochs: usize) -> StagedSchedule<T> {
        assert!(!stages.is_empty() && stages[0].0 == 0.0);
        StagedSchedule { stages, total_epochs }
    }

    pub fn at(&self, epoch: usize) -> T {
        let frac = epoch as f32 / self.total_epochs.max(1) as f32;
        let mut v = self.stages[0].1;
        for &(start, val) in &self.stages {
            if frac >= start {
                v = val;
            }
        }
        v
    }
}

/// The paper's p_zero schedule: 0.33 → 0.5 (20%) → 0.9 (50%).
pub fn paper_p_zero(total_epochs: usize) -> StagedSchedule<f32> {
    StagedSchedule::new(vec![(0.0, 0.33), (0.2, 0.5), (0.5, 0.9)], total_epochs)
}

/// The paper's BP gradient bitwidth schedule: 5 → 4 (20%) → 3 (50%).
pub fn paper_b_bp(total_epochs: usize) -> StagedSchedule<u32> {
    StagedSchedule::new(vec![(0.0, 5), (0.2, 4), (0.5, 3)], total_epochs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_decays_by_08_every_tenth() {
        let s = LrSchedule::paper_fp32(0.05, 100);
        assert_eq!(s.lr(0), 0.05);
        assert!((s.lr(10) - 0.04).abs() < 1e-6);
        assert!((s.lr(25) - 0.05 * 0.8f32.powi(2)).abs() < 1e-6);
    }

    #[test]
    fn lr_scales_to_short_runs() {
        let s = LrSchedule::paper_fp32(0.05, 10);
        assert!((s.lr(1) - 0.04).abs() < 1e-6); // decays every epoch
    }

    #[test]
    fn p_zero_stages() {
        let s = paper_p_zero(100);
        assert_eq!(s.at(0), 0.33);
        assert_eq!(s.at(19), 0.33);
        assert_eq!(s.at(20), 0.5);
        assert_eq!(s.at(50), 0.9);
        assert_eq!(s.at(99), 0.9);
    }

    #[test]
    fn b_bp_stages_scaled() {
        let s = paper_b_bp(10);
        assert_eq!(s.at(0), 5);
        assert_eq!(s.at(2), 4);
        assert_eq!(s.at(5), 3);
    }
}
