//! `repro` — the ElasticZO launcher (L3 coordinator CLI).
//!
//! ```text
//! repro train  [--model lenet|pointnet] [--dataset mnist|fashion|modelnet]
//!              [--method full-zo|cls1|cls2|full-bp|bp-tail=<k>] [--engine xla|native]
//!              [--bp-tail K] [--boundary fixed|elastic:<min>-<max>]
//!              [--elastic-patience N] [--elastic-eps F]
//!              [--precision fp32|int8|int8*] [--epochs N] [--batch N]
//!              [--lr F] [--eps F] [--seed N] [--save ckpt] [--load ckpt]
//!              [--resume ckpt] [--ckpt-every N] [--ckpt-keep K]
//!              [--dp N] [--dp-aggregate mean|sum] [--dp-min-replicas M]
//!              [--config file.json] [--verbose] [--mem-report]
//! repro eval   --load ckpt [--dataset ...] [--rotate DEG]
//! repro exp    table1|table2|fig2|fig3|fig4|fig5|fig6|fig7|all
//!              [--fast|--paper] [--engine xla|native]
//! repro memory [--model lenet|pointnet] [--batch N] [--precision fp32|int8]
//! repro inspect            # list AOT artifacts
//! repro bench  [--json] [--out file.json] [--fast]
//!              [--compare OLD.json] [--max-regress PCT]
//!              # measured performance snapshot: ZO-op and end-to-end
//!              # step latencies, serve throughput, dp scaling
//!              # (steps/sec at 1/2/4 replicas over the /cluster/dp
//!              # wire), and measured peak heap per method next to the
//!              # paper's memory model. Snapshots are stamped with
//!              # {schema, rev, created_by}; --compare prints
//!              # per-metric deltas against a committed BENCH_*.json
//!              # and --max-regress PCT fails the run when any
//!              # end-to-end step mean slows down by more than PCT%
//!
//! repro serve  [--port P] [--workers N] [--queue-cap C] [--journal F]
//!              [--cluster] [--lease-ms L] [--events-buffer N]
//!              [--max-sse N] [--reactor-threads N] [--http-idle-ms T]
//!              [--drain-grace-ms T]
//!              # multi-job training server (HTTP/1.1 + JSON); --journal
//!              # persists the job table across restarts (JSONL replay);
//!              # --cluster opens the /cluster/* control plane so remote
//!              # agents can register and pull work (--workers 0 = pure
//!              # coordinator); epoch/state events stream over SSE at
//!              # GET /events and GET /jobs/<id>/events
//! repro agent  --coordinator host:port [--capacity N] [--name S]
//!              [--poll-ms P] [--max-poll-failures N] [--mem-budget BYTES]
//!              # remote worker agent: registers with a cluster
//!              # coordinator, pulls jobs, runs them via the exact
//!              # `repro train` path, streams progress back;
//!              # --mem-budget makes the coordinator pin each
//!              # elastic-boundary job to the deepest BP tail whose
//!              # modeled footprint fits this device
//! repro submit [--addr host:port] [--name S] [--priority N] [train flags...]
//! repro jobs   [--addr host:port]
//! repro job    <id> [--addr host:port] [--cancel]
//! repro watch  <id> [--addr host:port]
//!              # live-tail a job over the server's SSE stream: replayed
//!              # history, then one line per epoch as it lands; exits 0
//!              # when the job completes
//! repro stats  [--addr host:port]
//! ```

use anyhow::Result;
use elasticzo::config::{Config, Precision};
use elasticzo::coordinator::control::{ProgressSink, StopFlag};
use elasticzo::coordinator::int8_trainer;
use elasticzo::coordinator::{checkpoint, trainer, Method, ParamSet};
use elasticzo::data;
use elasticzo::exp::{self, Scale};
use elasticzo::launch;
use elasticzo::serve;
use elasticzo::util::cli::Args;

/// Every allocation in the `repro` binary is tracked, so `GET /metrics`
/// exposes real `repro_mem_*` gauges and `repro train --mem-report` can
/// print the measured peak next to the paper's analytic model. Library
/// consumers (and `cargo test`) keep the default allocator and read
/// zeros from the counters.
#[global_allocator]
static ALLOC: elasticzo::metrics::alloc::TrackedAlloc = elasticzo::metrics::alloc::TrackedAlloc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "exp" => cmd_exp(&args),
        "memory" => cmd_memory(&args),
        "bench" => cmd_bench(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "agent" => cmd_agent(&args),
        "submit" => cmd_submit(&args),
        "jobs" => cmd_jobs(&args),
        "job" => cmd_job(&args),
        "watch" => cmd_watch(&args),
        "stats" => cmd_stats(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — ElasticZO on-device-learning coordinator\n\
         \n  repro train  [--model lenet|pointnet] [--method full-zo|cls1|cls2|full-bp]\n\
         \x20              [--bp-tail K]   generalized ZO/BP split: BP trains the last K layers\n\
         \x20              [--boundary fixed|elastic:<min>-<max>] [--elastic-patience N]\n\
         \x20              [--elastic-eps F]   plateau-driven boundary moves at epoch edges\n\
         \x20              [--dataset mnist|fashion|modelnet] [--engine xla|native]\n\
         \x20              [--precision fp32|int8|int8*] [--epochs N] [--batch N] [--lr F]\n\
         \x20              [--eval-every N] [--save ckpt] [--load ckpt] [--resume ckpt]\n\
         \x20              [--ckpt-every N] [--ckpt-keep K] [--config file.json] [--verbose]\n\
         \x20              [--kernels true|false] [--sparse-block N] [--sparse-keep F]\n\
         \x20              vectorized ZO kernels (default on) + optional block-sparse z\n\
         \x20              [--dp N] [--dp-aggregate mean|sum] [--dp-min-replicas M]\n\
         \x20              train one job across N data-parallel replicas (full-zo/fp32)\n\
         \x20              [--mem-report]   print measured peak heap vs the paper's model\n\
         \x20 repro eval   --load ckpt [--dataset D] [--rotate DEG] [--precision P]\n\
         \x20 repro exp    table1|table2|fig2..fig7|all [--fast|--paper] [--engine E]\n\
         \x20 repro memory [--model M] [--batch N] [--precision fp32|int8] [--adam]\n\
         \x20 repro bench  [--json] [--out file.json] [--fast]   measured perf snapshot\n\
         \x20              [--compare OLD.json] [--max-regress PCT]   deltas vs a baseline\n\
         \x20 repro inspect\n\
         \n  repro serve  [--port P] [--workers N] [--queue-cap C] [--journal F]\n\
         \x20              [--cluster] [--lease-ms L] [--events-buffer N]\n\
         \x20              [--max-sse N] [--reactor-threads N] [--http-idle-ms T]\n\
         \x20              [--drain-grace-ms T]\n\
         \x20              multi-job training server; HTTP/1.1 + JSON on 127.0.0.1:\n\
         \x20              GET /healthz | GET /stats | GET /jobs | POST /jobs\n\
         \x20              GET /jobs/<id> | POST /jobs/<id>/cancel | POST /shutdown\n\
         \x20              SSE: GET /events (firehose) | GET /jobs/<id>/events\n\
         \x20              --cluster adds /cluster/* (agent registry + job fan-out)\n\
         \x20 repro agent  --coordinator host:port [--capacity N] [--name S]\n\
         \x20              [--poll-ms P] [--max-poll-failures N] [--mem-budget BYTES]\n\
         \x20              remote worker: pulls jobs from a --cluster coordinator;\n\
         \x20              --mem-budget pins elastic jobs to the deepest BP tail that fits\n\
         \x20 repro submit [--addr host:port] [--name S] [--priority N] [train flags]\n\
         \x20 repro jobs   [--addr host:port]\n\
         \x20 repro job    <id> [--addr host:port] [--cancel]\n\
         \x20 repro watch  <id> [--addr host:port]   live-tail a job's epochs (SSE)\n\
         \x20 repro stats  [--addr host:port]"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = Config::from_args(args)?;
    cfg.verbose = true; // CLI runs always stream per-epoch lines
    if let Some(dir) = &cfg.artifacts_dir {
        std::env::set_var("REPRO_ARTIFACTS", dir);
    }
    println!(
        "train: model={} dataset={} method={} precision={} engine={:?} epochs={} batch={}",
        cfg.model,
        cfg.dataset.token(),
        cfg.method.label(),
        cfg.precision.label(),
        cfg.engine,
        cfg.epochs,
        cfg.batch
    );
    if let Some(path) = &cfg.load_checkpoint {
        println!("loading checkpoint {path}");
    }
    if let Some(path) = &cfg.resume {
        println!("resuming from checkpoint {path}");
    }

    // the precision dispatch, session setup and checkpoint plumbing all
    // live in launch::run — the exact path the serve workers drive
    let mem_report = args.flag("mem-report");
    let (l, measured) = if mem_report {
        let (r, scope) = elasticzo::metrics::alloc::measure_scope(|| {
            launch::run(&cfg, StopFlag::default(), ProgressSink::default())
        });
        (r?, Some(scope))
    } else {
        (launch::run(&cfg, StopFlag::default(), ProgressSink::default())?, None)
    };
    if let Some(epoch) = l.resumed_from {
        println!("resumed at epoch {epoch}");
    }
    println!(
        "done: best test acc {:.2}% (engine {})",
        l.result.history.best_test_acc() * 100.0,
        l.engine
    );
    println!("{}", l.result.timer.report("phase breakdown"));
    if let Some(scope) = measured {
        print_mem_report(&cfg, scope.peak_net_bytes);
    }
    match (&cfg.save_checkpoint, l.result.stopped) {
        (Some(path), false) => println!("saved checkpoint {path}"),
        // a stopped run keeps its last cadence snapshot instead of a
        // final save (params are mid-epoch at the stop point) — but
        // only if at least one on-cadence epoch completed
        (Some(path), true) if std::path::Path::new(path).exists() => {
            println!("stopped: last completed-epoch snapshot remains at {path}")
        }
        (Some(_), true) => println!("stopped before the first snapshot; nothing saved"),
        _ => {}
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let path = cfg
        .load_checkpoint
        .clone()
        .ok_or_else(|| anyhow::anyhow!("eval requires --load <checkpoint>"))?;
    let (_, mut test_d) =
        data::generate(cfg.dataset, 1, cfg.test_n, cfg.seed, cfg.npoints);
    if let Some(deg) = args.get("rotate") {
        let deg: f32 = deg.parse()?;
        test_d = data::rotate::rotate_dataset(&test_d, deg);
        println!("rotated test set by {deg}°");
    }
    match cfg.precision {
        Precision::Fp32 => {
            let model = cfg.model_enum();
            let mut params = ParamSet::init(model, 0);
            checkpoint::load_params(&path, &mut params)?;
            let mut engine = exp::build_engine(model, cfg.batch, cfg.engine);
            let (loss, acc) = trainer::evaluate(engine.as_mut(), &params, &test_d, cfg.batch)?;
            println!("eval: loss {loss:.4}  acc {:.2}%", acc * 100.0);
        }
        _ => {
            let ws = checkpoint::load_int8(&path)?;
            let (loss, acc) = int8_trainer::evaluate_int8(&ws, &test_d, cfg.batch);
            println!("eval: loss {loss:.4}  acc {:.2}%", acc * 100.0);
        }
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("exp requires an id (table1|table2|fig2..fig7|all)"))?;
    let scale = Scale::from_flags(args.flag("fast"), args.flag("paper"));
    let engine = elasticzo::coordinator::EngineKind::parse(args.get_or("engine", "xla"))?;
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("REPRO_ARTIFACTS", dir);
    }
    if args.flag("verbose") {
        std::env::set_var("REPRO_VERBOSE", "1");
    }
    println!("experiment {id} at scale {scale:?} (engine {engine:?})");
    exp::run(id, scale, engine)
}

fn cmd_memory(args: &Args) -> Result<()> {
    use elasticzo::memory::{self, models};
    use elasticzo::util::table::{bytes, Table};
    let model = args.get_or("model", "lenet");
    let batch = args.get_usize("batch", 32)?;
    let precision = args.get_or("precision", "fp32");
    let adam = args.flag("adam");
    let layers = match model {
        "lenet" if precision == "int8" => models::lenet_int8_layers(),
        "lenet" => models::lenet_layers(),
        "pointnet" => models::pointnet_layers(args.get_usize("npoints", 1024)?, 40),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    let mut t = Table::new(
        &format!("Memory model: {model} {precision} B={batch}{}", if adam { " (Adam)" } else { "" }),
        &["method", "params", "acts", "grads", "errors", "int32", "opt", "total"],
    );
    // one row per candidate boundary (k ∈ 0..=CLS_STACK, then full
    // BP) — the same candidate set the coordinator negotiates a
    // `--mem-budget` over, with the legacy preset labels appearing on
    // their k
    let mut methods: Vec<Method> =
        (0..=elasticzo::coordinator::engine::CLS_STACK).map(Method::Tail).collect();
    methods.push(Method::FullBp);
    for m in methods {
        let b = if precision == "int8" {
            memory::int8(&layers, batch, m.memory_method())
        } else {
            memory::fp32(&layers, batch, m.memory_method(), adam)
        };
        t.row(&[
            m.label().to_string(),
            bytes(b.params),
            bytes(b.acts),
            bytes(b.grads),
            bytes(b.errors),
            bytes(b.int32_scratch),
            bytes(b.opt_state),
            bytes(b.total()),
        ]);
    }
    t.print();
    Ok(())
}

/// Layer table for the paper's analytic memory model, matching the
/// run's model + precision.
fn analytic_layers(cfg: &Config) -> Vec<elasticzo::memory::LayerInfo> {
    use elasticzo::memory::models;
    match (cfg.model.as_str(), cfg.precision) {
        ("lenet", Precision::Fp32) => models::lenet_layers(),
        ("lenet", _) => models::lenet_int8_layers(),
        _ => models::pointnet_layers(cfg.npoints, cfg.ncls),
    }
}

/// Modeled total training-state bytes (paper Eqs. 2–5 fp32 / 13–15
/// int8) for one method under this run's configuration.
fn analytic_total(cfg: &Config, m: Method) -> usize {
    let layers = analytic_layers(cfg);
    if cfg.precision == Precision::Fp32 {
        elasticzo::memory::fp32(&layers, cfg.batch, m.memory_method(), false).total()
    } else {
        elasticzo::memory::int8(&layers, cfg.batch, m.memory_method()).total()
    }
}

/// `repro train --mem-report`: the measured peak of the run we just
/// finished, next to the paper's model for every candidate boundary
/// (`k ∈ 0..=max_bp_tail` plus full BP) at the same
/// model/precision/batch. This is [`elasticzo::coordinator::elastic::
/// candidate_rows`] — the exact table the coordinator negotiates an
/// agent's `--mem-budget` against, so what operators read here is what
/// the dispatcher decides on.
fn print_mem_report(cfg: &Config, measured_peak: usize) {
    use elasticzo::coordinator::elastic;
    use elasticzo::util::table::{bytes, Table};
    let mut t = Table::new(
        &format!(
            "Measured vs modeled peak memory ({} {} B={})",
            cfg.model,
            cfg.precision.label(),
            cfg.batch
        ),
        &["method", "modeled", "measured peak", "measured/modeled"],
    );
    let int8 = cfg.precision != Precision::Fp32;
    for row in elastic::candidate_rows(cfg.model_enum(), cfg.batch, int8, false) {
        let m = row.method;
        let modeled = row.total;
        let this_run = m == cfg.method;
        t.row(&[
            format!("{}{}", m.label(), if this_run { " *" } else { "" }),
            bytes(modeled),
            if this_run { bytes(measured_peak) } else { "-".into() },
            if this_run {
                format!("{:.2}x", measured_peak as f64 / modeled.max(1) as f64)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();
    println!(
        "* this run. measured = peak net-new heap over the whole run (tracked\n\
         allocator): the modeled training state plus dataset, engine scratch and\n\
         history buffers, so a ratio somewhat above 1x is expected."
    );
}

/// `repro bench`: the measured side of the paper's claims in one
/// command — ZO-op and end-to-end step latencies, serve throughput,
/// and per-method measured peak heap vs the analytic model. `--json`
/// prints a machine-readable snapshot; `--out f.json` writes it (the
/// repo's `BENCH_*.json` files); `--fast` caps each timing at ~200 ms
/// (same as `BENCH_FAST=1`).
fn cmd_bench(args: &Args) -> Result<()> {
    use elasticzo::coordinator::int8_trainer::{perturb_int8, zo_update_int8};
    use elasticzo::coordinator::native_engine::NativeEngine;
    use elasticzo::coordinator::trainer::zo_step;
    use elasticzo::coordinator::{kernels, zo, Engine, Fp32Session, Model, TrainSession, TrainSpec};
    use elasticzo::int8::{intce, lenet8};
    use elasticzo::metrics::alloc;
    use elasticzo::telemetry::PhaseTimer;
    use elasticzo::util::bench::{Bencher, Stats};
    use elasticzo::util::json::{self, Value};
    use std::collections::BTreeMap;

    if args.flag("fast") {
        std::env::set_var("BENCH_FAST", "1");
    }
    // `repro bench` has a positional subcommand word; a filtering
    // Bencher would read it as a name filter and skip everything
    let mut b = Bencher::unfiltered();

    // --- ZO micro-ops (Fig. 7 "ZO Perturb"/"ZO Update" slices) ---
    // Default rows run the chunked kernel path; `*_scalar` siblings keep
    // the pre-kernel reference (fused generate+apply, one element at a
    // time) as ungated context. The kernel perturb rows bump the step
    // every call so each iteration pays a fresh `z` fill — comparable
    // work to the scalar rows, which regenerate the stream per call.
    let mut lenet = ParamSet::init(Model::LeNet, 1);
    let nt = lenet.num_tensors();
    let lenet_elems: usize = lenet.data.iter().map(|t| t.len()).sum();
    let mut kzf = kernels::StepZ::new();
    let mut kstep = 0u64;
    b.bench("zo_perturb/lenet_107k", || {
        kstep += 1;
        kzf.prepare(7, kstep, lenet_elems, None);
        kernels::apply_z(&mut lenet, nt, 1e-3, kzf.z());
    });
    b.bench("zo_perturb_scalar/lenet_107k", || {
        zo::perturb(&mut lenet, nt, 7, 1, 1e-3);
    });
    let mut ws = lenet8::init_params(3, 32);
    let zo8_elems: usize = ws[..5].iter().map(|w| w.numel()).sum();
    let mut kz8 = kernels::StepZi8::new();
    let mut kstep8 = 0u64;
    b.bench("int8_perturb/lenet_107k", || {
        kstep8 += 1;
        kz8.prepare(7, kstep8, zo8_elems, 15, 0.5);
        kernels::apply_z_i8(&mut ws, 5, 1, kz8.z());
    });
    b.bench("int8_perturb_scalar/lenet_107k", || {
        perturb_int8(&mut ws, 5, 7, 1, 1, 15, 0.5);
    });
    // the kernel update replays the step's cached `z` (that is the
    // product path: the perturb legs already paid for the fill)
    let (mut acc8, mut upd8) = (Vec::new(), Vec::new());
    b.bench("int8_zo_update/lenet_107k", || {
        kernels::zo_update_z_i8(&mut ws, 5, 1, 1, kz8.z(), &mut acc8, &mut upd8);
    });
    b.bench("int8_zo_update_scalar/lenet_107k", || {
        zo_update_int8(&mut ws, 5, 7, 1, 1, 1, 15, 0.5);
    });
    let zo_end = b.results.len();

    // --- end-to-end training steps, native engine ---
    let d = data::synth_mnist::generate(32, 1);
    let mut y = vec![0.0f32; 32 * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    let batch = elasticzo::data::loader::Batch {
        x: d.x.clone(),
        y_onehot: y.clone(),
        labels: d.labels.clone(),
        bsz: 32,
    };
    // Default ZO rows drive `Fp32Session` (the product path: per-step
    // cached `z`, parallel ±ε pair when a second core is up); the
    // `*_scalar` siblings time [`zo_step`], the scalar reference the
    // parity suite pins the kernels to.
    // `Tail(3)` extends the k-axis one past the paper's presets (the
    // whole FC stack under BP), so BENCH snapshots chart the elastic
    // boundary's cost beyond cls1/cls2
    for method in [Method::FULL_ZO, Method::CLS1, Method::CLS2, Method::Tail(3)] {
        let spec = TrainSpec {
            method,
            epochs: 1,
            batch: 32,
            lr0: 1e-3,
            eps: 1e-2,
            g_clip: 5.0,
            seed: 9,
            eval_every: 1,
            verbose: false,
            ..Default::default()
        };
        let tag = method.label().replace(' ', "_");
        let mut native = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let mut sess = Fp32Session::new(&mut native, &mut params, &spec)?;
        let mut timer = PhaseTimer::new();
        let mut step = 0u64;
        b.bench(&format!("step_{tag}/native"), || {
            step += 1;
            sess.step(&batch, step, &mut timer).unwrap().loss
        });
        drop(sess);
        let mut native = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let mut timer = PhaseTimer::new();
        let mut step = 0u64;
        b.bench(&format!("step_{tag}_scalar/native"), || {
            step += 1;
            zo_step(&mut native, &mut params, &batch, step, 1e-3, &spec, &mut timer).unwrap()
        });
    }
    let mut native = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 4);
    b.bench("step_Full_BP/native", || {
        native.full_step(&mut params, &d.x, &y, 32, 0.01).unwrap().loss
    });
    // int8 composite, kernel path: one `z` fill replayed by all four
    // legs, ±ε forwards side by side when a second core is up — the
    // same shape `Int8Session` runs with `spec.kernels` on.
    let mut ws8 = lenet8::init_params(5, 32);
    let xq = lenet8::quantize_input(&d.x, 32);
    let mut snap8 = ws8.clone();
    let zo8e: usize = ws8[..4].iter().map(|w| w.numel()).sum();
    let mut kz8e = kernels::StepZi8::new();
    let (mut acc8e, mut upd8e) = (Vec::new(), Vec::new());
    let par8 = kernels::hw_threads() > 1;
    let mut step8 = 0u64;
    b.bench("step_Cls1/int8_native", || {
        step8 += 1;
        kz8e.prepare(1, step8, zo8e, 15, 0.5);
        kernels::apply_z_i8(&mut ws8, 4, 1, kz8e.z());
        let (fp, fm) = if par8 {
            snap8.clone_from(&ws8);
            kernels::apply_z_i8(&mut ws8, 4, -2, kz8e.z());
            let (ws_ref, snap_ref, xq_ref) = (&ws8, &snap8, &xq);
            std::thread::scope(|sc| {
                let h = sc.spawn(move || lenet8::forward(snap_ref, xq_ref, 32));
                let fm = lenet8::forward(ws_ref, xq_ref, 32);
                (h.join().expect("±ε int8 bench worker panicked"), fm)
            })
        } else {
            let fp = lenet8::forward(&ws8, &xq, 32);
            kernels::apply_z_i8(&mut ws8, 4, -2, kz8e.z());
            (fp, lenet8::forward(&ws8, &xq, 32))
        };
        let g = intce::loss_diff_sign_int(
            &fp.logits.data,
            fp.logits.exp,
            &fm.logits.data,
            fm.logits.exp,
            &d.labels,
            32,
            10,
        );
        kernels::apply_z_i8(&mut ws8, 4, 1, kz8e.z());
        kernels::zo_update_z_i8(&mut ws8, 4, g, 1, kz8e.z(), &mut acc8e, &mut upd8e);
        lenet8::tail_update(&mut ws8, &fm, &d.labels, 1, 32, 5);
        g
    });
    let mut ws8s = lenet8::init_params(5, 32);
    let mut step8s = 0u64;
    b.bench("step_Cls1_scalar/int8_native", || {
        step8s += 1;
        perturb_int8(&mut ws8s, 4, 1, step8s, 1, 15, 0.5);
        let fp = lenet8::forward(&ws8s, &xq, 32);
        perturb_int8(&mut ws8s, 4, 1, step8s, -2, 15, 0.5);
        let fm = lenet8::forward(&ws8s, &xq, 32);
        let g = intce::loss_diff_sign_int(
            &fp.logits.data,
            fp.logits.exp,
            &fm.logits.data,
            fm.logits.exp,
            &d.labels,
            32,
            10,
        );
        perturb_int8(&mut ws8s, 4, 1, step8s, 1, 15, 0.5);
        zo_update_int8(&mut ws8s, 4, 1, step8s, g, 1, 15, 0.5);
        lenet8::tail_update(&mut ws8s, &fm, &d.labels, 1, 32, 5);
        g
    });

    // --- serve throughput: tiny real jobs through the HTTP stack ---
    const JOBS: usize = 8;
    let run_fleet = |workers: usize| -> Result<f64> {
        use std::time::{Duration, Instant};
        let server = serve::Server::bind(&serve::ServeOptions {
            port: 0,
            workers,
            queue_cap: JOBS + 4,
            ..Default::default()
        })?;
        let addr = server.local_addr()?.to_string();
        let handle = std::thread::spawn(move || server.run());
        let t0 = Instant::now();
        for i in 0..JOBS {
            let body = json::parse(&format!(
                r#"{{"method": "cls1", "precision": "fp32", "engine": "native",
                    "epochs": 1, "batch": 16, "train_n": 64, "test_n": 32, "seed": {i}}}"#
            ))?;
            let (status, v) = serve::request(&addr, "POST", "/jobs", Some(&body))?;
            anyhow::ensure!(status == 200, "submit rejected: {}", json::to_string(&v));
        }
        loop {
            let (_, s) = serve::request(&addr, "GET", "/stats", None)?;
            anyhow::ensure!(
                s.get("jobs_failed").as_usize() == Some(0),
                "jobs failed during bench"
            );
            if s.get("jobs_done").as_usize() == Some(JOBS) {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let secs = t0.elapsed().as_secs_f64();
        serve::request(&addr, "POST", "/shutdown", None)?;
        handle.join().expect("server thread panicked")?;
        Ok(JOBS as f64 / secs)
    };
    let mut serve_rates: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 4] {
        let rate = run_fleet(workers)?;
        b.report_metric(&format!("serve_throughput/workers_{workers}"), rate, "jobs/sec");
        serve_rates.push((workers, rate));
    }

    // --- serve rps: raw request rate through the reactor, keep-alive
    // (one socket, pipeline of sequential requests) vs one connection
    // per request (the old thread-per-connection shape) ---
    let run_rps = |keep_alive: bool| -> Result<f64> {
        use std::io::{Read, Write};
        use std::time::Instant;
        let server = serve::Server::bind(&serve::ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 4,
            ..Default::default()
        })?;
        let addr = server.local_addr()?;
        let handle = std::thread::spawn(move || server.run());
        const REQS: usize = 500;
        let find = |h: &[u8], n: &[u8]| h.windows(n.len()).position(|w| w == n);
        let t0 = Instant::now();
        if keep_alive {
            let mut s = std::net::TcpStream::connect(addr)?;
            s.set_nodelay(true)?;
            let mut buf: Vec<u8> = Vec::new();
            let mut tmp = [0u8; 4096];
            for _ in 0..REQS {
                s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n")?;
                // read exactly one content-length-framed response
                loop {
                    if let Some(he) = find(&buf, b"\r\n\r\n") {
                        let head = std::str::from_utf8(&buf[..he])?;
                        let clen: usize = head
                            .lines()
                            .find_map(|l| {
                                let (k, v) = l.split_once(':')?;
                                k.trim()
                                    .eq_ignore_ascii_case("content-length")
                                    .then(|| v.trim().parse().ok())?
                            })
                            .unwrap_or(0);
                        if buf.len() >= he + 4 + clen {
                            buf.drain(..he + 4 + clen);
                            break;
                        }
                    }
                    let n = s.read(&mut tmp)?;
                    anyhow::ensure!(n > 0, "server closed keep-alive connection");
                    buf.extend_from_slice(&tmp[..n]);
                }
            }
        } else {
            for _ in 0..REQS {
                let mut s = std::net::TcpStream::connect(addr)?;
                s.write_all(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n")?;
                let mut raw = Vec::new();
                s.read_to_end(&mut raw)?;
                anyhow::ensure!(!raw.is_empty(), "empty response");
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        serve::request(&addr.to_string(), "POST", "/shutdown", None)?;
        handle.join().expect("server thread panicked")?;
        Ok(REQS as f64 / secs)
    };
    let rps_keepalive = run_rps(true)?;
    let rps_close = run_rps(false)?;
    b.report_metric("serve_rps/keepalive", rps_keepalive, "req/sec");
    b.report_metric("serve_rps/close", rps_close, "req/sec");
    b.report_metric(
        "serve_rps/keepalive_speedup",
        if rps_close > 0.0 { rps_keepalive / rps_close } else { 0.0 },
        "x",
    );

    // --- SSE fan-out: hundreds of concurrent firehose streams (the
    // pre-reactor server refused anything past 64) ---
    let run_fanout = |streams: usize| -> Result<f64> {
        use std::io::{Read, Write};
        use std::time::{Duration, Instant};
        let server = serve::Server::bind(&serve::ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 4,
            ..Default::default()
        })?;
        let addr = server.local_addr()?;
        let handle = std::thread::spawn(move || server.run());
        let t0 = Instant::now();
        let mut conns = Vec::with_capacity(streams);
        for _ in 0..streams {
            let mut s = std::net::TcpStream::connect(addr)?;
            s.set_read_timeout(Some(Duration::from_secs(10)))?;
            s.write_all(b"GET /events HTTP/1.1\r\nConnection: close\r\n\r\n")?;
            conns.push(s);
        }
        // every stream must answer with the SSE header: each is a live
        // reactor-registered subscriber, not just an accepted socket
        for s in &mut conns {
            let mut got: Vec<u8> = Vec::new();
            let mut tmp = [0u8; 1024];
            while !got.windows(4).any(|w| w == b"\r\n\r\n") {
                let n = s.read(&mut tmp)?;
                anyhow::ensure!(n > 0, "stream closed before the SSE header");
                got.extend_from_slice(&tmp[..n]);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        drop(conns);
        serve::request(&addr.to_string(), "POST", "/shutdown", None)?;
        handle.join().expect("server thread panicked")?;
        Ok(streams as f64 / secs)
    };
    let fanout_streams = 256usize;
    let fanout_rate = run_fanout(fanout_streams)?;
    b.report_metric(
        &format!("serve_rps/sse_fanout_{fanout_streams}"),
        fanout_rate,
        "streams/sec",
    );

    // --- dp scaling: ONE full-zo job split across N replica agents ---
    // A pure coordinator (workers 0) plus N in-process agents measures
    // committed steps/sec of the seed-compressed /cluster/dp wire as
    // the replica count grows. The job itself is identical across rows
    // (same seed, spec and trajectory), so the rows are comparable.
    let run_dp = |replicas: usize| -> Result<f64> {
        use std::time::{Duration, Instant};
        const EPOCHS: usize = 2;
        const TRAIN_N: usize = 256;
        const BATCH: usize = 32;
        let server = serve::Server::bind(&serve::ServeOptions {
            port: 0,
            workers: 0,
            queue_cap: 4,
            cluster: Some(serve::ClusterOptions { lease_ms: 4_000 }),
            ..Default::default()
        })?;
        let addr = server.local_addr()?.to_string();
        let handle = std::thread::spawn(move || server.run());
        let agents: Vec<serve::AgentHandle> = (0..replicas)
            .map(|i| {
                serve::Agent::spawn(serve::AgentOptions {
                    coordinator: addr.clone(),
                    capacity: 1,
                    name: format!("bench-dp-{i}"),
                    poll_ms: 10,
                    max_poll_failures: 100,
                    mem_budget: None,
                })
            })
            .collect::<Result<_>>()?;
        let body = json::parse(&format!(
            r#"{{"method": "full-zo", "precision": "fp32", "engine": "native",
                "epochs": {EPOCHS}, "batch": {BATCH}, "train_n": {TRAIN_N},
                "test_n": 64, "seed": 11,
                "dp": {{"replicas": {replicas}, "aggregate": "mean",
                        "min_replicas": 1}}}}"#
        ))?;
        let t0 = Instant::now();
        let (status, v) = serve::request(&addr, "POST", "/jobs", Some(&body))?;
        anyhow::ensure!(status == 200, "dp submit rejected: {}", json::to_string(&v));
        loop {
            let (_, st) = serve::request(&addr, "GET", "/stats", None)?;
            anyhow::ensure!(
                st.get("jobs_failed").as_usize() == Some(0),
                "dp job failed during bench"
            );
            if st.get("jobs_done").as_usize() == Some(1) {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let secs = t0.elapsed().as_secs_f64();
        for a in agents {
            a.stop();
        }
        serve::request(&addr, "POST", "/shutdown", None)?;
        handle.join().expect("server thread panicked")?;
        let steps = (EPOCHS * TRAIN_N.div_ceil(BATCH)) as f64;
        Ok(steps / secs)
    };
    let mut dp_rates: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let rate = run_dp(replicas)?;
        b.report_metric(&format!("dp_scaling/replicas_{replicas}"), rate, "steps/sec");
        dp_rates.push((replicas, rate));
    }

    // --- measured peak heap per method vs the paper's model ---
    let mut mem = BTreeMap::new();
    for m in [Method::FULL_ZO, Method::CLS2, Method::CLS1, Method::FullBp] {
        let cfg = Config {
            engine: elasticzo::coordinator::EngineKind::Native,
            method: m,
            epochs: 1,
            train_n: 64,
            test_n: 32,
            ..Config::default()
        };
        let (r, scope) = alloc::measure_scope(|| {
            launch::run(&cfg, StopFlag::default(), ProgressSink::default())
        });
        r?;
        let modeled = analytic_total(&cfg, m);
        b.report_metric(
            &format!("peak_heap/{}", m.label().replace(' ', "_")),
            scope.peak_net_bytes as f64 / 1024.0,
            "KiB measured",
        );
        mem.insert(
            m.label().to_string(),
            Value::obj(vec![
                ("modeled_bytes", Value::num(modeled as f64)),
                ("measured_peak_bytes", Value::num(scope.peak_net_bytes as f64)),
            ]),
        );
    }

    // --- machine-readable snapshot ---
    let stats_json = |results: &[Stats]| {
        Value::Obj(
            results
                .iter()
                .map(|s| {
                    (
                        s.name.clone(),
                        Value::obj(vec![
                            ("iters", Value::num(s.iters as f64)),
                            ("mean_s", Value::num(s.mean.as_secs_f64())),
                            ("p50_s", Value::num(s.p50.as_secs_f64())),
                            ("p95_s", Value::num(s.p95.as_secs_f64())),
                            ("min_s", Value::num(s.min.as_secs_f64())),
                        ]),
                    )
                })
                .collect(),
        )
    };
    let base_rate = dp_rates.first().map(|&(_, r)| r).unwrap_or(0.0);
    let snapshot = Value::obj(vec![
        ("schema", Value::str(BENCH_SCHEMA)),
        ("rev", Value::str(git_rev())),
        ("created_by", Value::str("repro bench")),
        ("zo_ops", stats_json(&b.results[..zo_end])),
        ("e2e_step", stats_json(&b.results[zo_end..])),
        (
            "serve_throughput_jobs_per_sec",
            Value::Obj(
                serve_rates
                    .iter()
                    .map(|&(w, r)| (format!("workers_{w}"), Value::num(r)))
                    .collect(),
            ),
        ),
        (
            "serve_rps",
            Value::obj(vec![
                ("keepalive", Value::num(rps_keepalive)),
                ("close", Value::num(rps_close)),
                (
                    "keepalive_speedup",
                    Value::num(if rps_close > 0.0 { rps_keepalive / rps_close } else { 0.0 }),
                ),
            ]),
        ),
        (
            "sse_fanout",
            Value::Obj(
                [(format!("streams_{fanout_streams}_per_sec"), Value::num(fanout_rate))]
                    .into_iter()
                    .collect(),
            ),
        ),
        (
            "dp_scaling",
            Value::Obj(
                dp_rates
                    .iter()
                    .flat_map(|&(n, r)| {
                        [
                            (format!("replicas_{n}/steps_per_sec"), Value::num(r)),
                            (
                                format!("replicas_{n}/speedup_vs_1"),
                                Value::num(if base_rate > 0.0 { r / base_rate } else { 0.0 }),
                            ),
                        ]
                    })
                    .collect(),
            ),
        ),
        ("peak_memory", Value::Obj(mem)),
        (
            "host",
            Value::obj(vec![(
                "parallelism",
                Value::num(
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
                ),
            )]),
        ),
    ]);
    if args.flag("json") {
        println!("{}", json::to_string_pretty(&snapshot));
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, json::to_string_pretty(&snapshot) + "\n")?;
        println!("wrote {path}");
    }
    if let Some(old_path) = args.get("compare") {
        let text = std::fs::read_to_string(old_path)
            .map_err(|e| anyhow::anyhow!("reading baseline {old_path}: {e}"))?;
        let old = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing baseline {old_path}: {e}"))?;
        let max_regress = args.get_f32("max-regress", f32::INFINITY)? as f64;
        compare_bench(&old, &snapshot, max_regress)?;
    }
    Ok(())
}

/// The bench snapshot's schema tag: bump when the JSON shape changes so
/// `--compare` can refuse an incompatible baseline instead of silently
/// reporting every metric as added/removed.
const BENCH_SCHEMA: &str = "repro-bench/v1";

/// `git rev-parse --short HEAD` of the working tree, or "unknown"
/// outside a checkout — provenance for committed BENCH_*.json files.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Print per-metric deltas between a baseline snapshot and the run that
/// just finished, then enforce the regression gate: fail when any ZO
/// micro-op's or end-to-end step's mean latency slowed down by more
/// than `max_regress_pct` percent. Only `zo_ops/*/mean_s` and
/// `e2e_step/*/mean_s` gate — iter counts, host facts and throughput
/// wobble are reported but advisory.
fn compare_bench(
    old: &elasticzo::util::json::Value,
    new: &elasticzo::util::json::Value,
    max_regress_pct: f64,
) -> Result<()> {
    use elasticzo::util::json::Value;
    use std::collections::BTreeMap;

    if let Some(schema) = old.get("schema").as_str() {
        anyhow::ensure!(
            schema == BENCH_SCHEMA,
            "baseline schema {schema:?} != {BENCH_SCHEMA:?}; re-generate the baseline"
        );
    }
    fn leaves(prefix: &str, v: &Value, out: &mut BTreeMap<String, f64>) {
        match v {
            Value::Num(n) => {
                out.insert(prefix.to_string(), *n);
            }
            Value::Obj(o) => {
                for (k, child) in o {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}/{k}")
                    };
                    leaves(&p, child, out);
                }
            }
            _ => {}
        }
    }
    let collect = |v: &Value| -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        leaves("", v, &mut out);
        // iteration counts and host facts are not performance metrics
        out.retain(|k, _| !k.starts_with("host/") && !k.ends_with("/iters"));
        out
    };
    let old_m = collect(old);
    let new_m = collect(new);
    println!(
        "\n--- vs baseline rev {} ---",
        old.get("rev").as_str().unwrap_or("?")
    );
    let mut worst: Option<(String, f64)> = None;
    for (name, new_v) in &new_m {
        match old_m.get(name) {
            None => println!("{name:<56} (new metric)"),
            Some(old_v) if *old_v != 0.0 => {
                let pct = (new_v - old_v) / old_v * 100.0;
                println!("{name:<56} {old_v:>12.6} -> {new_v:>12.6}  {pct:>+7.1}%");
                // time metrics regress when they go up; rate metrics
                // (jobs/requests/streams per second) when they go down
                let gated_time = (name.starts_with("e2e_step/") || name.starts_with("zo_ops/"))
                    && name.ends_with("/mean_s");
                let gated_rate = name.starts_with("serve_throughput_jobs_per_sec/")
                    || name.starts_with("serve_rps/")
                    || name.starts_with("sse_fanout/");
                let regress = if gated_time { pct } else { -pct };
                if (gated_time || gated_rate) && !matches!(&worst, Some((_, w)) if regress <= *w) {
                    worst = Some((name.clone(), regress));
                }
            }
            Some(_) => {}
        }
    }
    for name in old_m.keys() {
        if !new_m.contains_key(name) {
            println!("{name:<56} (removed)");
        }
    }
    if let Some((name, pct)) = worst {
        println!("worst gated delta: {name} {pct:+.1}%");
        anyhow::ensure!(
            pct <= max_regress_pct,
            "{name} regressed {pct:+.1}%, above the --max-regress {max_regress_pct}% gate"
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port = args.get_u64("port", serve::DEFAULT_PORT as u64)?;
    anyhow::ensure!(port <= u16::MAX as u64, "--port must be <= 65535, got {port}");
    let cluster = (args.flag("cluster") || args.get("lease-ms").is_some())
        .then(|| -> Result<serve::ClusterOptions> {
            let lease_ms = args.get_u64("lease-ms", serve::ClusterOptions::default().lease_ms)?;
            // a sub-poll-interval lease would reap every agent on every
            // tick — endless register/reap churn with no error anywhere
            anyhow::ensure!(
                lease_ms >= 100,
                "--lease-ms must be >= 100 (and comfortably above the agents' --poll-ms)"
            );
            Ok(serve::ClusterOptions { lease_ms })
        })
        .transpose()?;
    let events_buffer = args.get_usize(
        "events-buffer",
        elasticzo::serve::events::DEFAULT_SUBSCRIBER_CAP,
    )?;
    anyhow::ensure!(events_buffer >= 1, "--events-buffer must be >= 1");
    let max_sse = args.get_usize("max-sse", serve::http::DEFAULT_MAX_SSE)?;
    anyhow::ensure!(max_sse >= 1, "--max-sse must be >= 1");
    let reactor_threads = args.get_usize("reactor-threads", 0)?;
    let http_idle_ms = args.get_u64("http-idle-ms", 10_000)?;
    anyhow::ensure!(http_idle_ms >= 100, "--http-idle-ms must be >= 100");
    let drain_grace_ms = args.get_u64("drain-grace-ms", 5_000)?;
    let opts = serve::ServeOptions {
        port: port as u16,
        workers: args.get_usize("workers", 2)?,
        queue_cap: args.get_usize("queue-cap", 64)?,
        journal: args.get("journal").map(str::to_string),
        cluster,
        events_buffer,
        max_sse,
        reactor_threads,
        http_idle: std::time::Duration::from_millis(http_idle_ms),
        drain_grace: std::time::Duration::from_millis(drain_grace_ms),
        ..Default::default()
    };
    let server = serve::Server::bind(&opts)?;
    println!(
        "serve: listening on http://{} ({} workers, queue capacity {}, \
         keep-alive reactor, {} SSE streams max)",
        server.local_addr()?,
        opts.workers,
        opts.queue_cap,
        opts.max_sse
    );
    if let Some(j) = &opts.journal {
        println!("journal: {j} (job table replayed on restart; interrupted jobs requeue)");
    }
    println!("endpoints: GET /healthz /stats /jobs /jobs/<id>  POST /jobs /jobs/<id>/cancel /shutdown");
    println!(
        "events: GET /events (firehose, ?since_seq= resume) and GET /jobs/<id>/events \
         (SSE; `repro watch <id>` tails one job live)"
    );
    if let Some(c) = &opts.cluster {
        println!(
            "cluster: agents register at POST /cluster/register (lease {} ms); \
             queued jobs fan out to polling agents",
            c.lease_ms
        );
    }
    server.run()
}

fn cmd_agent(args: &Args) -> Result<()> {
    // the defaults live in ONE place (AgentOptions::default); the CLI
    // only overrides what was passed
    let d = serve::AgentOptions::default();
    let opts = serve::AgentOptions {
        coordinator: args.get_or("coordinator", &d.coordinator).to_string(),
        capacity: args.get_usize("capacity", d.capacity)?,
        name: args.get_or("name", &d.name).to_string(),
        poll_ms: args.get_u64("poll-ms", d.poll_ms)?,
        max_poll_failures: args.get_u64("max-poll-failures", d.max_poll_failures as u64)?
            as u32,
        mem_budget: match args.get_usize("mem-budget", 0)? {
            0 => None,
            b => Some(b),
        },
    };
    anyhow::ensure!(opts.capacity >= 1, "--capacity must be >= 1");
    anyhow::ensure!(opts.poll_ms >= 1, "--poll-ms must be >= 1");
    let coordinator = opts.coordinator.clone();
    let capacity = opts.capacity;
    let budget = opts.mem_budget;
    let handle = serve::Agent::spawn(opts)?;
    match budget {
        Some(b) => println!(
            "agent {} registered with {coordinator} (capacity {capacity}, mem budget {b} B); \
             elastic-boundary jobs will be pinned to the deepest BP tail that fits",
            handle.id()
        ),
        None => println!(
            "agent {} registered with {coordinator} (capacity {capacity}); polling for work",
            handle.id()
        ),
    }
    handle.join()
}

fn server_addr(args: &Args) -> String {
    args.get("addr")
        .map(str::to_string)
        .unwrap_or_else(|| format!("127.0.0.1:{}", serve::DEFAULT_PORT))
}

/// Build a job spec from `repro submit` flags: the client-side keys
/// (`addr`, `name`, `priority`) are stripped, then everything else
/// goes through the exact `repro train` pipeline (`Config::from_args`,
/// including `--config file.json`).
fn submit_spec(args: &Args) -> Result<serve::JobSpec> {
    let mut train_args = args.clone();
    for k in ["addr", "name", "priority"] {
        train_args.options.remove(k);
    }
    let mut spec = serve::JobSpec::new(Config::from_args(&train_args)?);
    spec.name = args.get_or("name", "").to_string();
    if let Some(p) = args.get("priority") {
        spec.priority = p
            .parse()
            .map_err(|_| anyhow::anyhow!("--priority expects an integer, got '{p}'"))?;
    }
    Ok(spec)
}

fn cmd_submit(args: &Args) -> Result<()> {
    let addr = server_addr(args);
    let spec = submit_spec(args)?;
    let (status, v) = serve::request(&addr, "POST", "/jobs", Some(&spec.to_json()))?;
    if status != 200 {
        anyhow::bail!("submit rejected ({status}): {}", elasticzo::util::json::to_string(&v));
    }
    let id = v.get("id").as_usize().unwrap_or(0);
    println!("submitted job {id} ({})", v.get("state").as_str().unwrap_or("?"));
    Ok(())
}

fn cmd_jobs(args: &Args) -> Result<()> {
    use elasticzo::util::table::Table;
    let addr = server_addr(args);
    let (status, v) = serve::request(&addr, "GET", "/jobs", None)?;
    anyhow::ensure!(status == 200, "server returned {status}");
    let mut t = Table::new(
        &format!("jobs @ {addr}"),
        &["id", "name", "state", "method", "precision", "epochs", "best acc"],
    );
    for j in v.get("jobs").as_arr().unwrap_or(&[]) {
        t.row(&[
            format!("{}", j.get("id").as_usize().unwrap_or(0)),
            j.get("name").as_str().unwrap_or("").to_string(),
            j.get("state").as_str().unwrap_or("?").to_string(),
            j.get("method").as_str().unwrap_or("?").to_string(),
            j.get("precision").as_str().unwrap_or("?").to_string(),
            format!(
                "{}/{}",
                j.get("epochs_done").as_usize().unwrap_or(0),
                j.get("epochs_total").as_usize().unwrap_or(0)
            ),
            format!("{:.2}%", j.get("best_test_acc").as_f64().unwrap_or(0.0) * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_job(args: &Args) -> Result<()> {
    let addr = server_addr(args);
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: repro job <id> [--addr A] [--cancel]"))?;
    let id: u64 = id.parse().map_err(|_| anyhow::anyhow!("job id must be an integer"))?;
    let (status, v) = if args.flag("cancel") {
        serve::request(&addr, "POST", &format!("/jobs/{id}/cancel"), None)?
    } else {
        serve::request(&addr, "GET", &format!("/jobs/{id}"), None)?
    };
    anyhow::ensure!(status == 200, "server returned {status}: {}",
        elasticzo::util::json::to_string(&v));
    println!("{}", elasticzo::util::json::to_string_pretty(&v));
    Ok(())
}

/// `repro watch <id>`: live-tail one job over `GET /jobs/<id>/events` —
/// the replayed history first, then one line per epoch as it lands.
/// Exits 0 iff the job completes (`done`); a job that ends failed /
/// cancelled / interrupted exits nonzero so `repro watch <id> &&
/// next-step` is safe to script, and so does a server that dies
/// mid-run (the stream ends without a terminal state).
fn cmd_watch(args: &Args) -> Result<()> {
    let addr = server_addr(args);
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: repro watch <id> [--addr host:port]"))?;
    let id: u64 = id.parse().map_err(|_| anyhow::anyhow!("job id must be an integer"))?;
    println!("watching job {id} on {addr} (detaching does not stop the job)");
    let state = serve::watch_job(&addr, id, |frame| match frame {
        serve::WatchFrame::Epoch { replay, stats } => {
            println!(
                "epoch {:>4}  train {:.4}  test {:.4}  acc {:>6.2}%  ({:.1}s){}",
                stats.epoch,
                stats.train_loss,
                stats.test_loss,
                stats.test_acc * 100.0,
                stats.seconds,
                if *replay { "  [replay]" } else { "" }
            );
        }
        serve::WatchFrame::State { replay, state, error } => {
            let tag = if *replay { "  [replay]" } else { "" };
            match error {
                Some(e) => println!("state: {state}{tag}  error: {e}"),
                None => println!("state: {state}{tag}"),
            }
        }
        serve::WatchFrame::Lagged { next_seq } => {
            println!(
                "… lagged: this watcher fell behind and events were dropped \
                 (resumed at seq {next_seq}; `repro job {id}` has the full history)"
            );
        }
    })?;
    println!("job {id} finished: {}", state.as_str());
    // exit 0 only for a completed run: `watch && deploy` must not
    // proceed on a failed or cancelled job
    anyhow::ensure!(
        state == elasticzo::serve::JobState::Done,
        "job {id} did not complete (terminal state: {})",
        state.as_str()
    );
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let addr = server_addr(args);
    let (status, v) = serve::request(&addr, "GET", "/stats", None)?;
    anyhow::ensure!(status == 200, "server returned {status}");
    println!("{}", elasticzo::util::json::to_string_pretty(&v));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("REPRO_ARTIFACTS", dir);
    }
    let manifest = elasticzo::runtime::Manifest::load(
        elasticzo::runtime::manifest::default_dir(),
    )?;
    println!("artifacts in {}:", manifest.dir.display());
    for e in &manifest.entries {
        let ins: Vec<String> = e.inputs.iter().map(|i| format!("{:?}{:?}", i.dtype, i.shape)).collect();
        println!(
            "  {:<28} {} inputs, {} outputs  [{}...]",
            e.name,
            e.inputs.len(),
            e.outputs.len(),
            ins.first().cloned().unwrap_or_default()
        );
    }
    Ok(())
}
