//! Layer tables for the memory model: the paper's LeNet-5 variant and
//! vanilla PointNet, with ReLU as standalone layers (paper accounting).

use super::LayerInfo;

/// LeNet-5 (paper variant: 5×5 convs with pad 2): 107,786 params.
pub fn lenet_layers() -> Vec<LayerInfo> {
    vec![
        LayerInfo { name: "conv1", params: 6 * 1 * 5 * 5 + 6, act: 6 * 28 * 28 },
        LayerInfo { name: "relu1", params: 0, act: 6 * 28 * 28 },
        LayerInfo { name: "pool1", params: 0, act: 6 * 14 * 14 },
        LayerInfo { name: "conv2", params: 16 * 6 * 5 * 5 + 16, act: 16 * 14 * 14 },
        LayerInfo { name: "relu2", params: 0, act: 16 * 14 * 14 },
        LayerInfo { name: "pool2", params: 0, act: 16 * 7 * 7 },
        LayerInfo { name: "fc1", params: 784 * 120 + 120, act: 120 },
        LayerInfo { name: "relu3", params: 0, act: 120 },
        LayerInfo { name: "fc2", params: 120 * 84 + 84, act: 84 },
        LayerInfo { name: "relu4", params: 0, act: 84 },
        LayerInfo { name: "fc3", params: 84 * 10 + 10, act: 10 },
    ]
}

/// INT8 LeNet-5: NITI carries no biases.
pub fn lenet_int8_layers() -> Vec<LayerInfo> {
    vec![
        LayerInfo { name: "conv1", params: 6 * 1 * 5 * 5, act: 6 * 28 * 28 },
        LayerInfo { name: "relu1", params: 0, act: 6 * 28 * 28 },
        LayerInfo { name: "pool1", params: 0, act: 6 * 14 * 14 },
        LayerInfo { name: "conv2", params: 16 * 6 * 5 * 5, act: 16 * 14 * 14 },
        LayerInfo { name: "relu2", params: 0, act: 16 * 14 * 14 },
        LayerInfo { name: "pool2", params: 0, act: 16 * 7 * 7 },
        LayerInfo { name: "fc1", params: 784 * 120, act: 120 },
        LayerInfo { name: "relu3", params: 0, act: 120 },
        LayerInfo { name: "fc2", params: 120 * 84, act: 84 },
        LayerInfo { name: "relu4", params: 0, act: 84 },
        LayerInfo { name: "fc3", params: 84 * 10, act: 10 },
    ]
}

/// PointNet with `n` points and `ncls` classes (~816k params at ncls=40).
pub fn pointnet_layers(n: usize, ncls: usize) -> Vec<LayerInfo> {
    let feat = [3usize, 64, 64, 64, 128, 1024];
    let mut out = Vec::new();
    for i in 0..feat.len() - 1 {
        let (k, m) = (feat[i], feat[i + 1]);
        out.push(LayerInfo {
            name: match i {
                0 => "feat1",
                1 => "feat2",
                2 => "feat3",
                3 => "feat4",
                _ => "feat5",
            },
            params: k * m + m,
            act: m * n,
        });
        out.push(LayerInfo {
            name: match i {
                0 => "frelu1",
                1 => "frelu2",
                2 => "frelu3",
                3 => "frelu4",
                _ => "frelu5",
            },
            params: 0,
            act: m * n,
        });
    }
    out.push(LayerInfo { name: "maxpool", params: 0, act: 1024 });
    let head = [1024usize, 512, 256, ncls];
    for i in 0..3 {
        let (k, m) = (head[i], head[i + 1]);
        out.push(LayerInfo {
            name: match i {
                0 => "head1",
                1 => "head2",
                _ => "head3",
            },
            params: k * m + m,
            act: m,
        });
        if i < 2 {
            out.push(LayerInfo {
                name: if i == 0 { "hrelu1" } else { "hrelu2" },
                params: 0,
                act: m,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_param_total_matches_paper() {
        let total: usize = lenet_layers().iter().map(|l| l.params).sum();
        assert_eq!(total, 107_786);
    }

    #[test]
    fn pointnet_param_total_near_paper() {
        let total: usize = pointnet_layers(1024, 40).iter().map(|l| l.params).sum();
        assert!((total as f64 - 816_744.0).abs() / 816_744.0 < 0.005, "{total}");
    }

    #[test]
    fn pointnet_biggest_activation_is_feat5() {
        // paper: the last feat FC produces (B,N,1024) — dominates memory
        let layers = pointnet_layers(1024, 40);
        let max = layers.iter().max_by_key(|l| l.act).unwrap();
        assert_eq!(max.act, 1024 * 1024);
    }

    #[test]
    fn int8_lenet_has_no_biases() {
        let fp: usize = lenet_layers().iter().map(|l| l.params).sum();
        let i8_: usize = lenet_int8_layers().iter().map(|l| l.params).sum();
        assert_eq!(fp - i8_, 6 + 16 + 120 + 84 + 10);
    }
}
