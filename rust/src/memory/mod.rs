//! Analytic memory model — paper Eqs. 2–5 (FP32) and 13–15 (INT8).
//!
//! Layer conventions match the paper's accounting exactly (validated
//! against its reported numbers in `tests` and EXPERIMENTS.md):
//! * ReLU counts as its own layer with its own activation buffer (no
//!   in-place/lifetime optimization, as the paper assumes);
//!   this reproduces the paper's "activations+errors are 42.9× the
//!   parameters at B=256" for LeNet-5 exactly.
//! * A layer `l ∈ T` (trainable: conv/FC) stores `θ_l` and, when trained
//!   by BP, its gradient `g_l`; every layer stores its activation `a_l`
//!   and, when error flows through it, `e_l`.
//! * INT8: 1-byte `θ/a/g/e` plus int32 scratch: `a^int32` for every
//!   trainable layer, `g^int32`/`e^int32` for BP-trained layers (Eq. 13).

pub mod models;

/// One network layer in the memory model.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: &'static str,
    /// Parameter element count (0 for relu/pool).
    pub params: usize,
    /// Activation element count PER SAMPLE.
    pub act: usize,
}

impl LayerInfo {
    pub fn trainable(&self) -> bool {
        self.params > 0
    }
}

/// A memory breakdown in bytes (the stacked-bar components of Figs 4–6).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Breakdown {
    pub params: usize,
    pub acts: usize,
    pub grads: usize,
    pub errors: usize,
    /// INT8 only: int32 scratch accumulators.
    pub int32_scratch: usize,
    /// Optimizer state (Eq. 5; 0 for plain SGD).
    pub opt_state: usize,
}

impl Breakdown {
    pub fn total(&self) -> usize {
        self.params + self.acts + self.grads + self.errors + self.int32_scratch + self.opt_state
    }
}

/// Training method, parameterized by the ZO/BP partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    FullZo,
    /// BP on the last `bp_layers` trainable (FC) layers, ZO on the rest.
    Elastic { bp_layers: usize },
    FullBp,
}

/// Index of the first layer trained by BP (layers `c..L` are BP).
/// `Method::FullZo` → L (none), `FullBp` → 0 (all).
fn bp_start(layers: &[LayerInfo], method: Method) -> usize {
    match method {
        Method::FullZo => layers.len(),
        Method::FullBp => 0,
        Method::Elastic { bp_layers } => {
            // count back `bp_layers` trainable layers from the end
            let mut remaining = bp_layers;
            for i in (0..layers.len()).rev() {
                if layers[i].trainable() {
                    remaining -= 1;
                    if remaining == 0 {
                        return i;
                    }
                }
            }
            0
        }
    }
}

/// FP32 memory (Eqs. 2–4). `adam` adds Eq. 5's two moment buffers.
pub fn fp32(layers: &[LayerInfo], batch: usize, method: Method, adam: bool) -> Breakdown {
    const W: usize = 4; // f32 bytes
    let start = bp_start(layers, method);
    let mut b = Breakdown::default();
    for (i, l) in layers.iter().enumerate() {
        b.params += l.params * W;
        b.acts += l.act * batch * W;
        if i >= start {
            if l.trainable() {
                b.grads += l.params * W;
                if adam {
                    b.opt_state += 2 * l.params * W;
                }
            }
            b.errors += l.act * batch * W;
        }
    }
    b
}

/// INT8 memory (Eqs. 13–15): 1-byte tensors + int32 scratch.
pub fn int8(layers: &[LayerInfo], batch: usize, method: Method) -> Breakdown {
    let start = bp_start(layers, method);
    let mut b = Breakdown::default();
    let mut prev_act = 0usize; // a_{l-1} for the e^int32 term
    for (i, l) in layers.iter().enumerate() {
        b.params += l.params;
        b.acts += l.act * batch;
        if l.trainable() {
            // int32 accumulator while computing a_l (Eq. 13 Σ_{l∈T} a^int32)
            b.int32_scratch += l.act * batch * 4;
        }
        if i >= start {
            if l.trainable() {
                b.grads += l.params;
                b.int32_scratch += l.params * 4; // g^int32
                if i > 0 {
                    b.int32_scratch += prev_act * batch * 4; // e_{l-1}^int32
                }
            }
            b.errors += l.act * batch;
        }
        prev_act = l.act;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::models::{lenet_layers, pointnet_layers};
    use super::*;

    #[test]
    fn ordering_invariant_fullzo_le_elastic_le_fullbp() {
        let layers = lenet_layers();
        for batch in [1usize, 32, 256] {
            let zo = fp32(&layers, batch, Method::FullZo, false).total();
            let e1 = fp32(&layers, batch, Method::Elastic { bp_layers: 1 }, false).total();
            let e2 = fp32(&layers, batch, Method::Elastic { bp_layers: 2 }, false).total();
            let bp = fp32(&layers, batch, Method::FullBp, false).total();
            assert!(zo <= e1 && e1 <= e2 && e2 <= bp, "batch {batch}");
        }
    }

    #[test]
    fn full_bp_is_twice_inference() {
        // Eq. 2 vs Eq. 3: Full BP keeps g,e mirroring θ,a exactly.
        let layers = lenet_layers();
        let zo = fp32(&layers, 32, Method::FullZo, false);
        let bp = fp32(&layers, 32, Method::FullBp, false);
        assert_eq!(bp.total(), 2 * zo.total());
    }

    #[test]
    fn paper_ratio_acts_to_params_b256() {
        // paper Sec 5.3: a+e is 42.9x params at B=256 for LeNet
        let layers = lenet_layers();
        let bp = fp32(&layers, 256, Method::FullBp, false);
        let ratio = (bp.acts + bp.errors) as f64 / (bp.params + bp.grads) as f64;
        assert!((ratio - 42.9).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn paper_cls2_overhead_b32() {
        // paper Fig 4: ZO-Feat-Cls2 (BP on ONE layer) adds ~4.6 KB over
        // Full ZO at B=32
        let layers = lenet_layers();
        let zo = fp32(&layers, 32, Method::FullZo, false).total();
        let e1 = fp32(&layers, 32, Method::Elastic { bp_layers: 1 }, false).total();
        let overhead = e1 - zo;
        assert!(
            (4_000..6_000).contains(&overhead),
            "Cls2 overhead {overhead} B"
        );
    }

    #[test]
    fn paper_cls1_overhead_b32() {
        // paper Fig 4: ZO-Feat-Cls1 (BP on TWO layers) adds ~65 KB over
        // Full ZO at B=32
        let layers = lenet_layers();
        let zo = fp32(&layers, 32, Method::FullZo, false).total();
        let e2 = fp32(&layers, 32, Method::Elastic { bp_layers: 2 }, false).total();
        let overhead = e2 - zo;
        assert!(
            (55_000..75_000).contains(&overhead),
            "Cls1 overhead {overhead} B"
        );
    }

    #[test]
    fn int8_saves_1_4_to_1_7x_vs_fp32() {
        // paper: INT8 ZO methods need 1.46-1.60x less memory than FP32
        let layers = lenet_layers();
        for method in [
            Method::FullZo,
            Method::Elastic { bp_layers: 1 },
            Method::Elastic { bp_layers: 2 },
        ] {
            for batch in [32usize, 256] {
                let f = fp32(&layers, batch, method, false).total();
                let i = int8(&layers, batch, method).total();
                let ratio = f as f64 / i as f64;
                assert!(
                    (1.35..1.75).contains(&ratio),
                    "{method:?} batch {batch}: ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn int8_ordering_invariant() {
        let layers = lenet_layers();
        let zo = int8(&layers, 32, Method::FullZo).total();
        let e1 = int8(&layers, 32, Method::Elastic { bp_layers: 1 }).total();
        let e2 = int8(&layers, 32, Method::Elastic { bp_layers: 2 }).total();
        let bp = int8(&layers, 32, Method::FullBp).total();
        assert!(zo <= e1 && e1 <= e2 && e2 <= bp);
    }

    #[test]
    fn adam_adds_two_param_copies() {
        let layers = lenet_layers();
        let sgd = fp32(&layers, 32, Method::FullBp, false);
        let adam = fp32(&layers, 32, Method::FullBp, true);
        assert_eq!(adam.opt_state, 2 * sgd.grads);
    }

    #[test]
    fn pointnet_activations_dominate() {
        // paper Fig 6: activations+errors are >99% for ElasticZO PointNet
        let layers = pointnet_layers(1024, 40);
        let e2 = fp32(&layers, 32, Method::Elastic { bp_layers: 2 }, false);
        let frac = (e2.acts + e2.errors) as f64 / e2.total() as f64;
        assert!(frac > 0.985, "act fraction {frac}");
    }

    #[test]
    fn pointnet_tail_grads_negligible() {
        // paper: Cls2/Cls1 grads+errors are 0.0087%/0.12% of the total
        let layers = pointnet_layers(1024, 40);
        let e1 = fp32(&layers, 32, Method::Elastic { bp_layers: 1 }, false);
        let frac = (e1.grads + e1.errors) as f64 / e1.total() as f64;
        assert!(frac < 0.002, "tail fraction {frac}");
    }
}
