//! The persistent job journal: an append-only JSONL file that lets a
//! restarted `repro serve` remember every job the previous process
//! knew about.
//!
//! # Event stream
//!
//! While the server runs, the registry appends one JSON object per
//! line (all built on the in-tree `util::json`, no serde):
//!
//! ```text
//! {"event":"submit","id":N,"ts":UNIX,"spec":{JobSpec}}   submission (pre-queue)
//! {"event":"forget","id":N}                              queue push rejected: void it
//! {"event":"start","id":N,"worker":W}                    local worker claimed the job
//! {"event":"start","id":N,"agent":A}                     cluster agent was assigned the job
//! {"event":"start","id":N,"dp":true}                     dp run adopted (no single owner)
//! {"event":"dp_member","id":N,"action":A,"agent":G,"shards":[..]}
//!                                                        dp membership change (join/leave/
//!                                                        lost) — audit only, folds to no-op
//! {"event":"epoch","id":N,"stats":{EpochStats}}          one epoch reported
//! {"event":"boundary","id":N,"k":K,"reason":R,...}       ZO/BP boundary moved: a
//!                                                        "negotiated" pin folds into the
//!                                                        replayed spec; a mid-run
//!                                                        "elastic" move is audit-only
//! {"event":"requeue","id":N}                             agent lease expired / deregistered:
//!                                                        the job went back to Queued
//! {"event":"terminal","id":N,"state":S,...}              Done/Failed/Cancelled/Interrupted
//! {"event":"job",...}                                    compacted full record (below)
//! ```
//!
//! The submit line is written *before* the queue push makes the job
//! claimable, so a worker's start/epoch/terminal events always replay
//! after it; a push rejected with backpressure (429) appends the
//! compensating `forget` event instead.
//!
//! Each line is flushed as it is written, so a hard kill loses at most
//! the line being appended; [`replay`] skips a torn trailing line
//! instead of refusing the whole journal.
//!
//! # Replay and requeue
//!
//! On startup the server folds the event stream into one [`Replayed`]
//! record per job. Terminal jobs (Done/Failed/Cancelled) are restored
//! for listing only; Queued/Running/Interrupted jobs go back on the
//! queue — and when the job's checkpoint file carries a v2 training
//! state, [`prepare_requeue`] arms `resume` on its config so the job
//! continues from its last completed-epoch snapshot rather than
//! restarting from scratch.
//!
//! # Compaction
//!
//! On clean shutdown (and again right after a replay) the journal is
//! rewritten as one consolidated `{"event":"job",...}` line per job —
//! spec, state, per-epoch history, best accuracy — via tmp-file +
//! rename, so the file stays bounded by the job table instead of
//! growing with every epoch ever trained.

use super::protocol::{JobSpec, JobState};
use crate::coordinator::checkpoint;
use crate::coordinator::metrics::EpochStats;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Append handle to the journal file. Shared by the registry (events)
/// and the server (compaction) behind an `Arc`.
pub struct Journal {
    path: PathBuf,
    w: Mutex<BufWriter<File>>,
}

impl Journal {
    /// Open (creating if needed) the journal for appending.
    pub fn open(path: impl AsRef<Path>) -> Result<Journal> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating journal dir {}", dir.display()))?;
            }
        }
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening job journal {}", path.display()))?;
        Ok(Journal { path, w: Mutex::new(BufWriter::new(f)) })
    }

    /// Append one event line (flushed immediately). Best-effort: an
    /// un-writable journal must not take down training, so failures
    /// are logged, not propagated.
    pub fn append(&self, ev: &Value) {
        let mut w = self.w.lock().unwrap_or_else(|e| e.into_inner());
        let line = json::to_string(ev);
        if writeln!(w, "{line}").and_then(|_| w.flush()).is_err() {
            eprintln!("serve: failed to append to job journal {}", self.path.display());
        }
        crate::metrics::global()
            .counter("repro_journal_appends_total", "Lines appended to the job journal", &[])
            .inc();
    }

    /// Rewrite the journal as the given consolidated `job` records
    /// (atomic tmp + rename), then re-point the append handle at the
    /// fresh file.
    pub fn compact(&self, jobs: &[Value]) -> Result<()> {
        let tmp = PathBuf::from(format!("{}.tmp", self.path.display()));
        {
            let mut f = BufWriter::new(
                File::create(&tmp)
                    .with_context(|| format!("creating {}", tmp.display()))?,
            );
            for j in jobs {
                writeln!(f, "{}", json::to_string(j))?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publishing compacted journal {}", self.path.display()))?;
        let f = OpenOptions::new().create(true).append(true).open(&self.path)?;
        *self.w.lock().unwrap_or_else(|e| e.into_inner()) = BufWriter::new(f);
        Ok(())
    }
}

/// One job folded out of the journal's event stream.
#[derive(Debug, Clone)]
pub struct Replayed {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub submitted_unix: f64,
    pub run_seconds: f64,
    pub best_test_acc: f32,
    pub error: Option<String>,
    pub epochs: Vec<EpochStats>,
}

/// Fold a journal file into per-job records (empty when the file does
/// not exist yet). Unparseable lines — e.g. a torn tail from a hard
/// kill — are skipped with a warning.
pub fn replay(path: impl AsRef<Path>) -> Result<Vec<Replayed>> {
    let path = path.as_ref();
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading job journal {}", path.display()))?;
    let mut jobs: BTreeMap<u64, Replayed> = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            eprintln!(
                "serve: skipping malformed journal line {} in {}",
                lineno + 1,
                path.display()
            );
            continue;
        };
        let Some(id) = v.get("id").as_f64().map(|n| n as u64) else { continue };
        match v.get("event").as_str() {
            Some(ev @ ("submit" | "job")) => {
                let spec = match JobSpec::from_json(v.get("spec")) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("serve: journal job {id} has an unreadable spec: {e:#}");
                        continue;
                    }
                };
                let mut job = Replayed {
                    id,
                    spec,
                    state: JobState::Queued,
                    submitted_unix: v.get("ts").as_f64().unwrap_or(0.0),
                    run_seconds: 0.0,
                    best_test_acc: 0.0,
                    error: None,
                    epochs: Vec::new(),
                };
                if ev == "job" {
                    job.state = v
                        .get("state")
                        .as_str()
                        .and_then(|s| JobState::parse(s).ok())
                        .unwrap_or(JobState::Queued);
                    job.run_seconds = v.get("run_seconds").as_f64().unwrap_or(0.0);
                    job.best_test_acc = v.get("best_test_acc").as_f64().unwrap_or(0.0) as f32;
                    job.error = v.get("error").as_str().map(str::to_string);
                    if let Some(arr) = v.get("epochs").as_arr() {
                        job.epochs =
                            arr.iter().filter_map(|e| EpochStats::from_json(e).ok()).collect();
                    }
                }
                jobs.insert(id, job);
            }
            Some("start") => {
                if let Some(j) = jobs.get_mut(&id) {
                    j.state = JobState::Running;
                }
            }
            // a remote agent's lease expired (or it deregistered) and
            // the job went back on the queue mid-process
            Some("requeue") => {
                if let Some(j) = jobs.get_mut(&id) {
                    j.state = JobState::Queued;
                }
            }
            Some("epoch") => {
                if let Some(j) = jobs.get_mut(&id) {
                    if let Ok(s) = EpochStats::from_json(v.get("stats")) {
                        // a re-reported epoch supersedes any stale tail
                        // from a pre-requeue lineage: after a lost-agent
                        // requeue WITHOUT a usable checkpoint the job
                        // reran from scratch, and its fresh epoch 0..
                        // events must replace the dead lineage's — the
                        // live registry cleared them at requeue time
                        j.epochs.retain(|e| e.epoch < s.epoch);
                        j.best_test_acc = j.best_test_acc.max(s.test_acc);
                        j.epochs.push(s);
                    }
                }
            }
            Some("terminal") => {
                if let Some(j) = jobs.get_mut(&id) {
                    j.state = v
                        .get("state")
                        .as_str()
                        .and_then(|s| JobState::parse(s).ok())
                        .unwrap_or(JobState::Failed);
                    j.run_seconds = v.get("run_seconds").as_f64().unwrap_or(0.0);
                    if let Some(acc) = v.get("best_test_acc").as_f64() {
                        j.best_test_acc = acc as f32;
                    }
                    j.error = v.get("error").as_str().map(str::to_string);
                }
            }
            // a boundary pin negotiated at assignment rewrote the job's
            // effective method BEFORE its run started; fold it into the
            // replayed spec so a requeue/resume sees the same spec
            // identity the checkpoint trailer recorded. Mid-run
            // "elastic" moves are audit-only here — the k-schedule
            // rides in the checkpoint's training state, not the spec.
            Some("boundary") => {
                if v.get("reason").as_str() == Some("negotiated") {
                    if let (Some(j), Some(k)) = (jobs.get_mut(&id), v.get("k").as_f64()) {
                        j.spec.config.method = crate::coordinator::Method::Tail(k as usize);
                    }
                }
            }
            // a submission whose queue push was rejected (429): void it
            Some("forget") => {
                jobs.remove(&id);
            }
            _ => {}
        }
    }
    Ok(jobs.into_values().collect())
}

/// Turn a replayed non-terminal-or-interrupted job back into a
/// schedulable one. Returns `false` for Done/Failed/Cancelled jobs
/// (restored for listing only). For requeued jobs:
///
/// * if the job's checkpoint file holds a v2 training state, `resume`
///   is armed on its config and the replayed history is trimmed to the
///   snapshot's completed epochs (the resumed run re-reports the rest);
/// * otherwise the history is cleared and the job reruns under its
///   original config.
pub fn prepare_requeue(job: &mut Replayed) -> bool {
    match job.state {
        JobState::Done | JobState::Failed | JobState::Cancelled => false,
        JobState::Queued | JobState::Running | JobState::Interrupted => {
            job.state = JobState::Queued;
            arm_resume(&mut job.spec, &mut job.epochs);
            true
        }
    }
}

/// The shared requeue core (PR 3's interrupted-requeue rule), used by
/// boot-time journal replay AND the cluster's lease-expiry requeue of a
/// lost agent's jobs:
///
/// * only a snapshot that verifiably belongs to THIS job's spec arms
///   `resume` — a stale file from an earlier run at a reused path must
///   fall back to a from-scratch rerun, not doom the requeue to a
///   spec-mismatch failure;
/// * when resume is armed, the recorded history is trimmed to the
///   snapshot's completed epochs (the resumed run re-reports the rest);
/// * with no usable snapshot the history is cleared and the job reruns
///   under its original config.
pub fn arm_resume(spec: &mut JobSpec, epochs: &mut Vec<EpochStats>) {
    let current_spec = spec.config.train_spec().to_json();
    let snapshot = spec.config.save_checkpoint.as_ref().and_then(|p| {
        match checkpoint::load_full(p) {
            Ok((_, Some(state)))
                if state.epochs_done > 0
                    && checkpoint::ensure_spec_matches(&state.spec, &current_spec).is_ok() =>
            {
                Some((p.clone(), state.epochs_done))
            }
            _ => None,
        }
    });
    match snapshot {
        Some((path, epochs_done)) => {
            spec.config.resume = Some(path);
            spec.config.load_checkpoint = None;
            epochs.retain(|e| e.epoch < epochs_done);
        }
        // no snapshot: rerun from the job's original config
        None => epochs.clear(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ezo_journal_{name}_{}", std::process::id()))
    }

    fn submit_ev(id: u64) -> Value {
        Value::obj(vec![
            ("event", Value::str("submit")),
            ("id", Value::num(id as f64)),
            ("ts", Value::num(123.0)),
            ("spec", JobSpec::new(Config::default()).to_json()),
        ])
    }

    #[test]
    fn replay_folds_event_stream() {
        let path = tmp("fold");
        let j = Journal::open(&path).unwrap();
        j.append(&submit_ev(1));
        j.append(&Value::obj(vec![
            ("event", Value::str("start")),
            ("id", Value::num(1.0)),
            ("worker", Value::num(0.0)),
        ]));
        j.append(&Value::obj(vec![
            ("event", Value::str("epoch")),
            ("id", Value::num(1.0)),
            (
                "stats",
                EpochStats { epoch: 0, test_acc: 0.5, ..Default::default() }.to_json(),
            ),
        ]));
        j.append(&submit_ev(2));
        j.append(&Value::obj(vec![
            ("event", Value::str("terminal")),
            ("id", Value::num(2.0)),
            ("state", Value::str("cancelled")),
            ("best_test_acc", Value::num(0.0)),
            ("run_seconds", Value::num(0.0)),
        ]));
        // torn tail from a crash mid-append: skipped, not fatal
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"event\":\"epo").unwrap();
        }
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].state, JobState::Running);
        assert_eq!(jobs[0].epochs.len(), 1);
        assert!((jobs[0].best_test_acc - 0.5).abs() < 1e-6);
        assert_eq!(jobs[1].state, JobState::Cancelled);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn requeue_event_folds_back_to_queued() {
        let path = tmp("requeue_event");
        let j = Journal::open(&path).unwrap();
        j.append(&submit_ev(1));
        j.append(&Value::obj(vec![
            ("event", Value::str("start")),
            ("id", Value::num(1.0)),
            ("agent", Value::num(3.0)),
        ]));
        j.append(&Value::obj(vec![
            ("event", Value::str("epoch")),
            ("id", Value::num(1.0)),
            (
                "stats",
                EpochStats { epoch: 0, test_acc: 0.4, ..Default::default() }.to_json(),
            ),
        ]));
        // the agent's lease expired: the job went back to Queued…
        j.append(&Value::obj(vec![
            ("event", Value::str("requeue")),
            ("id", Value::num(1.0)),
        ]));
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs[0].state, JobState::Queued);
        assert_eq!(jobs[0].epochs.len(), 1);

        // …and a later assignment + terminal folds to the final state
        j.append(&Value::obj(vec![
            ("event", Value::str("start")),
            ("id", Value::num(1.0)),
            ("agent", Value::num(4.0)),
        ]));
        j.append(&Value::obj(vec![
            ("event", Value::str("terminal")),
            ("id", Value::num(1.0)),
            ("state", Value::str("done")),
            ("best_test_acc", Value::num(0.6)),
            ("run_seconds", Value::num(2.0)),
        ]));
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs[0].state, JobState::Done);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replayed_rerun_supersedes_the_dead_lineage() {
        // a lost-agent requeue with no usable checkpoint reruns from
        // scratch: its fresh epoch events must REPLACE the dead
        // lineage's, not append after them
        let path = tmp("rerun_dedup");
        let j = Journal::open(&path).unwrap();
        j.append(&submit_ev(1));
        let epoch_ev = |e: usize, acc: f64| {
            Value::obj(vec![
                ("event", Value::str("epoch")),
                ("id", Value::num(1.0)),
                (
                    "stats",
                    EpochStats { epoch: e, test_acc: acc as f32, ..Default::default() }
                        .to_json(),
                ),
            ])
        };
        for e in 0..3 {
            j.append(&epoch_ev(e, 0.3));
        }
        j.append(&Value::obj(vec![
            ("event", Value::str("requeue")),
            ("id", Value::num(1.0)),
        ]));
        for e in 0..5 {
            j.append(&epoch_ev(e, 0.5));
        }
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs[0].epochs.len(), 5, "history must be the rerun's 0..5, no dups");
        for (i, e) in jobs[0].epochs.iter().enumerate() {
            assert_eq!(e.epoch, i);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_journal_is_empty() {
        assert!(replay(tmp("nonexistent")).unwrap().is_empty());
    }

    #[test]
    fn forget_voids_a_rejected_submission() {
        let path = tmp("forget");
        let j = Journal::open(&path).unwrap();
        j.append(&submit_ev(1));
        j.append(&submit_ev(2));
        j.append(&Value::obj(vec![
            ("event", Value::str("forget")),
            ("id", Value::num(2.0)),
        ]));
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs.len(), 1, "the 429'd submission must not replay");
        assert_eq!(jobs[0].id, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compact_rewrites_and_keeps_appending() {
        let path = tmp("compact");
        let j = Journal::open(&path).unwrap();
        j.append(&submit_ev(1));
        j.append(&submit_ev(2));
        let consolidated = Value::obj(vec![
            ("event", Value::str("job")),
            ("id", Value::num(1.0)),
            ("ts", Value::num(9.0)),
            ("spec", JobSpec::new(Config::default()).to_json()),
            ("state", Value::str("done")),
            ("best_test_acc", Value::num(0.75)),
            ("run_seconds", Value::num(1.5)),
            ("epochs", Value::Arr(vec![])),
        ]);
        j.compact(std::slice::from_ref(&consolidated)).unwrap();
        // appends after compaction land in the new file
        j.append(&submit_ev(3));
        let jobs = replay(&path).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].state, JobState::Done);
        assert!((jobs[0].best_test_acc - 0.75).abs() < 1e-6);
        assert_eq!(jobs[1].id, 3);
        assert_eq!(jobs[1].state, JobState::Queued);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn requeue_rules() {
        let mk = |state: JobState| Replayed {
            id: 1,
            spec: JobSpec::new(Config::default()),
            state,
            submitted_unix: 0.0,
            run_seconds: 0.0,
            best_test_acc: 0.0,
            error: None,
            epochs: vec![EpochStats::default()],
        };
        for s in [JobState::Done, JobState::Failed, JobState::Cancelled] {
            let mut job = mk(s);
            assert!(!prepare_requeue(&mut job), "{s:?} must not requeue");
            assert_eq!(job.state, s);
        }
        for s in [JobState::Queued, JobState::Running, JobState::Interrupted] {
            let mut job = mk(s);
            assert!(prepare_requeue(&mut job), "{s:?} must requeue");
            assert_eq!(job.state, JobState::Queued);
            // no checkpoint file ⇒ fresh rerun: history cleared
            assert!(job.epochs.is_empty());
            assert_eq!(job.spec.config.resume, None);
        }
    }

    #[test]
    fn requeue_arms_resume_when_snapshot_matches() {
        let ckpt = tmp("requeue_ckpt").display().to_string();
        let mut cfg = Config::default();
        cfg.set("save", &ckpt).unwrap();
        let state = checkpoint::TrainState {
            epochs_done: 2,
            step: 8,
            best_test_acc: 0.5,
            last_test_loss: 1.0,
            last_test_acc: 0.5,
            spec: cfg.train_spec().to_json(),
            elastic: None,
        };
        checkpoint::save_with_state(&ckpt, &[], Some(&state)).unwrap();
        let mk = |cfg: Config| Replayed {
            id: 4,
            spec: JobSpec::new(cfg),
            state: JobState::Interrupted,
            submitted_unix: 0.0,
            run_seconds: 3.0,
            best_test_acc: 0.5,
            error: None,
            epochs: (0..4)
                .map(|i| EpochStats { epoch: i, ..Default::default() })
                .collect(),
        };
        let mut job = mk(cfg.clone());
        assert!(prepare_requeue(&mut job));
        assert_eq!(job.spec.config.resume.as_deref(), Some(ckpt.as_str()));
        // history trimmed to the snapshot's completed epochs
        assert_eq!(job.epochs.len(), 2);

        // a stale snapshot from a DIFFERENT run at the same path must
        // fall back to a from-scratch rerun, not arm a doomed resume
        let mut other = cfg;
        other.set("seed", "999").unwrap();
        let mut job = mk(other);
        assert!(prepare_requeue(&mut job));
        assert_eq!(job.spec.config.resume, None);
        assert!(job.epochs.is_empty());
        std::fs::remove_file(&ckpt).ok();
    }
}
