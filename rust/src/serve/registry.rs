//! In-memory job table: id → spec + state machine + per-epoch history,
//! plus aggregate server statistics (jobs served, epochs/sec, per-phase
//! time rolled up from each job's `telemetry::PhaseTimer`). Jobs run
//! either on a local pool worker ([`JobRegistry::claim`]) or on a
//! remote cluster agent ([`JobRegistry::claim_for_agent`]); a remote
//! job whose agent vanishes re-enters the queue through
//! [`JobRegistry::requeue_interrupted`].
//!
//! When the server runs with a job journal, the registry doubles as the
//! journal's event source: every accepted submission, claim, epoch and
//! terminal transition appends one JSONL line (see `serve::journal`),
//! and [`JobRegistry::restore`] re-inserts jobs replayed at startup
//! without re-journaling their history (compaction snapshots it).
//!
//! The registry also owns the live-telemetry [`EventBus`]
//! (`serve::events`): every epoch record and state transition — local
//! worker or remote agent, user cancel or lease-expiry requeue — is
//! broadcast from inside the registry lock, which gives the SSE layer
//! its exactly-once replay/live watermark (see
//! [`JobRegistry::stream_snapshot`]). Publishing never blocks: slow
//! subscribers shed events, the trainers never wait.

use super::events::EventBus;
use super::journal::{self, Journal, Replayed};
use super::protocol::{JobSpec, JobState};
use crate::coordinator::control::StopFlag;
use crate::coordinator::metrics::EpochStats;
use crate::telemetry::{PhaseTimer, ALL_PHASES};
use crate::util::json::Value;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Sliding window over which `GET /stats` computes `epochs_per_sec`.
/// (The old uptime-since-boot quotient decayed toward zero after any
/// idle period and made a busy server look slower the longer it
/// lived.)
const EPOCH_RATE_WINDOW: Duration = Duration::from_secs(60);

/// Everything the worker hands back when a job leaves the Running state.
pub struct JobOutcome {
    pub best_test_acc: f32,
    pub timer: PhaseTimer,
    /// True iff the run ended early via the job's stop flag.
    pub stopped: bool,
}

/// What `cancel` did — drives the HTTP response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The job was still queued and is now terminally Cancelled.
    CancelledQueued,
    /// The job is running; its stop flag fired and a worker will mark it
    /// Cancelled at the next batch boundary.
    StopRequested,
    /// Already Done/Failed/Cancelled/Interrupted — nothing to do.
    AlreadyTerminal(JobState),
}

pub struct JobRecord {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub stop: StopFlag,
    pub worker: Option<usize>,
    /// Set instead of `worker` when a cluster agent runs the job.
    pub agent: Option<u64>,
    pub submitted_unix: f64,
    pub started: Option<Instant>,
    pub run_seconds: f64,
    pub epochs: Vec<EpochStats>,
    pub best_test_acc: f32,
    pub error: Option<String>,
    /// Set when the server's own shutdown fired this job's stop flag:
    /// the stopped run completes as Interrupted (requeued on the next
    /// journal replay) rather than Cancelled (a user decision).
    interrupted: bool,
}

impl JobRecord {
    /// Wall-clock training time: live while Running, frozen once terminal.
    fn live_run_seconds(&self) -> f64 {
        if self.state == JobState::Running {
            self.started.map_or(0.0, |t| t.elapsed().as_secs_f64())
        } else {
            self.run_seconds
        }
    }

    fn summary_json(&self) -> Value {
        Value::obj(vec![
            ("id", Value::num(self.id as f64)),
            ("name", Value::str(self.spec.name.clone())),
            ("state", Value::str(self.state.as_str())),
            ("priority", Value::num(self.spec.priority as f64)),
            ("model", Value::str(self.spec.config.model.clone())),
            ("dataset", Value::str(self.spec.config.dataset.token())),
            ("method", Value::str(self.spec.config.method.token())),
            ("precision", Value::str(self.spec.config.precision.token())),
            ("epochs_total", Value::num(self.spec.config.epochs as f64)),
            ("epochs_done", Value::num(self.epochs.len() as f64)),
            ("best_test_acc", Value::num(self.best_test_acc as f64)),
            ("submitted_unix", Value::num(self.submitted_unix)),
            ("run_seconds", Value::num(self.live_run_seconds())),
        ])
    }

    /// `since` trims the reported history to epochs `>= since`
    /// (`?history_since=`); `history_total` always counts the full
    /// recorded history so a caller can tell trimmed from short.
    fn detail_json(&self, since: Option<usize>) -> Value {
        let Value::Obj(mut obj) = self.summary_json() else { unreachable!() };
        obj.insert("spec".into(), self.spec.to_json());
        let since = since.unwrap_or(0);
        obj.insert(
            "history".into(),
            Value::Arr(
                self.epochs
                    .iter()
                    .filter(|e| e.epoch >= since)
                    .map(EpochStats::to_json)
                    .collect(),
            ),
        );
        obj.insert("history_total".into(), Value::num(self.epochs.len() as f64));
        // Fig.-7 per-job breakdown, summed from the per-epoch deltas —
        // identical for local-worker and remote-agent runs, because
        // both arrive through the same EpochStats wire shape
        let mut per_job = PhaseTimer::new();
        for e in &self.epochs {
            for d in &e.phases {
                per_job.add_delta(d);
            }
        }
        if per_job.grand_total() > Duration::ZERO {
            obj.insert(
                "phase_seconds".into(),
                Value::Obj(
                    ALL_PHASES
                        .iter()
                        .filter(|&&p| per_job.total(p) > Duration::ZERO)
                        .map(|&p| {
                            (p.name().to_string(), Value::num(per_job.total(p).as_secs_f64()))
                        })
                        .collect(),
                ),
            );
        }
        if let Some(w) = self.worker {
            obj.insert("worker".into(), Value::num(w as f64));
        }
        if let Some(a) = self.agent {
            obj.insert("agent".into(), Value::num(a as f64));
        }
        if let Some(e) = &self.error {
            obj.insert("error".into(), Value::str(e.clone()));
        }
        Value::Obj(obj)
    }

    /// The consolidated journal record (`{"event":"job",...}`) used by
    /// startup/shutdown compaction.
    fn compacted_json(&self) -> Value {
        let mut pairs = vec![
            ("event", Value::str("job")),
            ("id", Value::num(self.id as f64)),
            ("ts", Value::num(self.submitted_unix)),
            ("spec", self.spec.to_json()),
            ("state", Value::str(self.state.as_str())),
            ("best_test_acc", Value::num(self.best_test_acc as f64)),
            ("run_seconds", Value::num(self.live_run_seconds())),
            (
                "epochs",
                Value::Arr(self.epochs.iter().map(EpochStats::to_json).collect()),
            ),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Value::str(e.clone())));
        }
        Value::obj(pairs)
    }
}

fn terminal_event(job: &JobRecord) -> Value {
    let mut pairs = vec![
        ("event", Value::str("terminal")),
        ("id", Value::num(job.id as f64)),
        ("state", Value::str(job.state.as_str())),
        ("best_test_acc", Value::num(job.best_test_acc as f64)),
        ("run_seconds", Value::num(job.run_seconds)),
    ];
    if let Some(e) = &job.error {
        pairs.push(("error", Value::str(e.clone())));
    }
    Value::obj(pairs)
}

struct Inner {
    jobs: BTreeMap<u64, JobRecord>,
    next_id: u64,
    total_epochs: u64,
    timer: PhaseTimer,
    /// Completion instants of recent epochs, pruned to
    /// [`EPOCH_RATE_WINDOW`] — the sliding-window `epochs_per_sec`.
    epoch_marks: VecDeque<Instant>,
}

/// Thread-shared job table; every method takes `&self`.
pub struct JobRegistry {
    started_at: Instant,
    journal: Option<Arc<Journal>>,
    events: Arc<EventBus>,
    inner: Mutex<Inner>,
}

/// Everything a per-job SSE stream needs to start: the recorded
/// history so far, the current state, and the bus watermark separating
/// "covered by this snapshot" from "will arrive live" (taken under the
/// registry lock, so no event can straddle the boundary).
pub struct StreamSnapshot {
    pub epochs: Vec<EpochStats>,
    pub state: JobState,
    pub error: Option<String>,
    pub watermark: u64,
}

impl Default for JobRegistry {
    fn default() -> Self {
        JobRegistry::new()
    }
}

impl JobRegistry {
    pub fn new() -> JobRegistry {
        JobRegistry::with_journal(None)
    }

    /// A registry that appends every job event to `journal`.
    pub fn with_journal(journal: Option<Arc<Journal>>) -> JobRegistry {
        JobRegistry {
            started_at: Instant::now(),
            journal,
            events: Arc::new(EventBus::new()),
            inner: Mutex::new(Inner {
                jobs: BTreeMap::new(),
                next_id: 1,
                total_epochs: 0,
                timer: PhaseTimer::new(),
                epoch_marks: VecDeque::new(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The live-telemetry bus every epoch/state-transition record
    /// point publishes into (`serve::events`).
    pub fn events(&self) -> &Arc<EventBus> {
        &self.events
    }

    /// Atomic history + state + bus-watermark snapshot for
    /// `GET /jobs/{id}/events`: a subscriber created *before* this
    /// call replays the snapshot, then skips live events with
    /// `seq <= watermark` — exactly-once across the replay/live seam,
    /// because publishes happen under the same registry lock this
    /// snapshot holds.
    pub fn stream_snapshot(&self, id: u64) -> Option<StreamSnapshot> {
        let st = self.lock();
        let job = st.jobs.get(&id)?;
        Some(StreamSnapshot {
            epochs: job.epochs.clone(),
            state: job.state,
            error: job.error.clone(),
            watermark: self.events.current_seq(),
        })
    }

    /// Broadcast that a freshly submitted job is queued (called by the
    /// HTTP layer after the queue push succeeded — a 429'd submission
    /// is rolled back and must never surface on the bus).
    pub fn announce_queued(&self, id: u64) {
        let st = self.lock();
        if st.jobs.get(&id).is_some_and(|j| j.state == JobState::Queued) {
            self.events.publish_state(id, JobState::Queued.as_str(), None);
        }
    }

    fn append_event(&self, ev: Option<Value>) {
        if let (Some(j), Some(ev)) = (&self.journal, ev) {
            j.append(&ev);
        }
    }

    /// Register a new job in the Queued state; returns its id. NOT yet
    /// journaled — the submission only becomes durable once it is also
    /// queued (see [`JobRegistry::journal_submit`]); a rejected push is
    /// rolled back with [`JobRegistry::forget`] and leaves no trace.
    pub fn add(&self, spec: JobSpec) -> u64 {
        let mut st = self.lock();
        let id = st.next_id;
        st.next_id += 1;
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        st.jobs.insert(
            id,
            JobRecord {
                id,
                spec,
                state: JobState::Queued,
                stop: StopFlag::new(),
                worker: None,
                agent: None,
                submitted_unix: now,
                started: None,
                run_seconds: 0.0,
                epochs: Vec::new(),
                best_test_acc: 0.0,
                error: None,
                interrupted: false,
            },
        );
        id
    }

    /// Journal a submission. Call BEFORE the queue push makes the job
    /// claimable (worker events must replay after the submit line); a
    /// rejected push is compensated by [`JobRegistry::forget`]'s
    /// 'forget' event.
    pub fn journal_submit(&self, id: u64) {
        if self.journal.is_none() {
            return;
        }
        let ev = {
            let st = self.lock();
            st.jobs.get(&id).map(|job| {
                Value::obj(vec![
                    ("event", Value::str("submit")),
                    ("id", Value::num(id as f64)),
                    ("ts", Value::num(job.submitted_unix)),
                    ("spec", job.spec.to_json()),
                ])
            })
        };
        self.append_event(ev);
    }

    /// Re-insert a job replayed from the journal at startup. Historical
    /// events are not re-journaled (compaction snapshots them); the id
    /// counter advances past every restored id.
    pub fn restore(&self, r: Replayed) {
        let mut st = self.lock();
        st.next_id = st.next_id.max(r.id + 1);
        st.jobs.insert(
            r.id,
            JobRecord {
                id: r.id,
                spec: r.spec,
                state: r.state,
                stop: StopFlag::new(),
                worker: None,
                agent: None,
                submitted_unix: r.submitted_unix,
                started: None,
                run_seconds: r.run_seconds,
                epochs: r.epochs,
                best_test_acc: r.best_test_acc,
                error: r.error,
                interrupted: false,
            },
        );
    }

    /// Roll back a submission whose queue push was rejected: the job
    /// leaves the table, and a 'forget' event voids its already-written
    /// submit line so a 429'd job never replays on restart.
    pub fn forget(&self, id: u64) {
        self.lock().jobs.remove(&id);
        self.append_event(self.journal.is_some().then(|| {
            Value::obj(vec![("event", Value::str("forget")), ("id", Value::num(id as f64))])
        }));
    }

    /// Worker-side claim: Queued → Running. `None` if the job was
    /// cancelled (or vanished) while waiting in the queue.
    pub fn claim(&self, id: u64, worker: usize) -> Option<(JobSpec, StopFlag)> {
        let (out, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            if job.state != JobState::Queued {
                return None;
            }
            job.state = JobState::Running;
            job.worker = Some(worker);
            job.started = Some(Instant::now());
            self.events.publish_state(id, JobState::Running.as_str(), None);
            (
                (job.spec.clone(), job.stop.clone()),
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("start")),
                        ("id", Value::num(id as f64)),
                        ("worker", Value::num(worker as f64)),
                    ])
                }),
            )
        };
        self.append_event(ev);
        Some(out)
    }

    /// Remote claim: Queued → Running on a cluster agent. The job's
    /// stop flag stays coordinator-side — a remote run cannot share an
    /// `AtomicBool`, so its firing is fanned out through the
    /// dispatcher's poll stop-list instead (see [`JobRegistry::stop_requested`]).
    pub fn claim_for_agent(&self, id: u64, agent: u64) -> Option<JobSpec> {
        let (spec, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            if job.state != JobState::Queued {
                return None;
            }
            job.state = JobState::Running;
            job.agent = Some(agent);
            job.worker = None;
            job.started = Some(Instant::now());
            self.events.publish_state(id, JobState::Running.as_str(), None);
            (
                job.spec.clone(),
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("start")),
                        ("id", Value::num(id as f64)),
                        ("agent", Value::num(agent as f64)),
                    ])
                }),
            )
        };
        self.append_event(ev);
        Some(spec)
    }

    /// Data-parallel claim: Queued → Running with NO single owner — a
    /// dp job belongs to the whole replica set, whose membership the
    /// `serve::dp` coordinator tracks shard-by-shard (and journals via
    /// [`JobRegistry::journal_dp`]). Worker/agent stay `None` so a lost
    /// single agent never requeues the job wholesale; only the shard
    /// moves.
    /// The job's data-parallel spec, if it is a dp job.
    pub fn dp_of(&self, id: u64) -> Option<crate::coordinator::DpSpec> {
        self.lock().jobs.get(&id).and_then(|j| j.spec.config.dp_spec())
    }

    pub fn claim_for_dp(&self, id: u64) -> Option<JobSpec> {
        let (spec, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            if job.state != JobState::Queued {
                return None;
            }
            job.state = JobState::Running;
            job.agent = None;
            job.worker = None;
            job.started = Some(Instant::now());
            self.events.publish_state(id, JobState::Running.as_str(), None);
            (
                job.spec.clone(),
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("start")),
                        ("id", Value::num(id as f64)),
                        ("dp", Value::Bool(true)),
                    ])
                }),
            )
        };
        self.append_event(ev);
        Some(spec)
    }

    /// Journal a dp membership change (`action` ∈ join/leave/lost,
    /// with the shard set the agent held). Replay treats these as
    /// unknown events — they are an audit trail of which device
    /// evaluated which shards, not state to restore: a dp job
    /// interrupted by coordinator restart reruns from scratch (dp
    /// forbids resume).
    pub fn journal_dp(&self, id: u64, action: &str, agent: u64, shards: &[usize]) {
        if self.journal.is_none() {
            return;
        }
        self.append_event(Some(Value::obj(vec![
            ("event", Value::str("dp_member")),
            ("id", Value::num(id as f64)),
            ("action", Value::str(action)),
            ("agent", Value::num(agent as f64)),
            (
                "shards",
                Value::Arr(shards.iter().map(|&s| Value::num(s as f64)).collect()),
            ),
        ])));
    }

    /// True iff the job is Running and its stop flag has fired — the
    /// dispatcher relays this to the owning agent on its next poll, so
    /// user cancels and server shutdown reach remote runs through the
    /// exact same flag the local workers share directly.
    pub fn stop_requested(&self, id: u64) -> bool {
        self.lock()
            .jobs
            .get(&id)
            .is_some_and(|j| j.state == JobState::Running && j.stop.should_stop())
    }

    /// Put a remotely-running job whose agent vanished (lease expiry /
    /// deregister) back into Queued — resume armed from its last
    /// matching checkpoint and history trimmed to the snapshot, the
    /// exact rule journal replay applies to interrupted jobs
    /// ([`super::journal::arm_resume`]). A user cancel that raced in
    /// wins instead: the job lands terminally Cancelled. Returns the
    /// priority to requeue with (`None` = nothing to requeue).
    pub fn requeue_interrupted(&self, id: u64) -> Option<i64> {
        let (out, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            if job.state != JobState::Running {
                return None;
            }
            if job.stop.should_stop() && !job.interrupted {
                job.state = JobState::Cancelled;
                job.run_seconds = job.started.map_or(0.0, |t| t.elapsed().as_secs_f64());
                self.events.publish_state(id, JobState::Cancelled.as_str(), None);
                (None, self.journal.is_some().then(|| terminal_event(job)))
            } else {
                job.state = JobState::Queued;
                job.worker = None;
                job.agent = None;
                job.started = None;
                job.stop = StopFlag::new();
                journal::arm_resume(&mut job.spec, &mut job.epochs);
                self.events.publish_state(id, JobState::Queued.as_str(), None);
                (
                    Some(job.spec.priority),
                    self.journal.is_some().then(|| {
                        Value::obj(vec![
                            ("event", Value::str("requeue")),
                            ("id", Value::num(id as f64)),
                        ])
                    }),
                )
            }
        };
        self.append_event(ev);
        out
    }

    /// Per-epoch progress from a local worker's running job.
    pub fn record_epoch(&self, id: u64, stats: EpochStats) {
        self.record_epoch_inner(id, None, stats);
    }

    /// Per-epoch progress from a remote run: dropped unless the job is
    /// still Running AND still owned by `agent`. Both checks happen
    /// under the same lock `requeue_interrupted` and `claim_for_agent`
    /// take, so a stale report from a reaped agent can never land in a
    /// requeued job's history — not even after a successor re-claimed
    /// it (the owner changed).
    pub fn record_epoch_from_agent(&self, id: u64, agent: u64, stats: EpochStats) {
        self.record_epoch_inner(id, Some(agent), stats);
    }

    fn record_epoch_inner(&self, id: u64, from_agent: Option<u64>, stats: EpochStats) {
        let (ev, boundary_ev, steps_per_epoch) = {
            let mut st = self.lock();
            let Some(job) = st.jobs.get_mut(&id) else { return };
            if job.state != JobState::Running {
                return;
            }
            if let Some(a) = from_agent {
                if job.agent != Some(a) {
                    return;
                }
            }
            job.best_test_acc = job.best_test_acc.max(stats.test_acc);
            self.events.publish_epoch(id, &stats);
            // the elastic controller moved the ZO/BP boundary this
            // epoch: journal the change as a first-class event (the
            // epoch stats carry the new k too, so replay is redundant
            // by design — the event is the audit trail)
            let moved = match (job.epochs.last().and_then(|e| e.bp_tail), stats.bp_tail) {
                (Some(prev), Some(now)) if prev != now => Some(now),
                _ => None,
            };
            let boundary_ev = moved.and_then(|k| {
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("boundary")),
                        ("id", Value::num(id as f64)),
                        ("epoch", Value::num(stats.epoch as f64)),
                        ("k", Value::num(k as f64)),
                        ("reason", Value::str("elastic")),
                    ])
                })
            });
            if moved.is_some() {
                crate::metrics::global()
                    .counter(
                        "repro_boundary_changes_total",
                        "Mid-run ZO/BP boundary moves applied by the elastic controller",
                        &[],
                    )
                    .inc();
            }
            job.epochs.push(stats.clone());
            let steps = job.spec.config.train_n.div_ceil(job.spec.config.batch.max(1));
            st.total_epochs += 1;
            // phase deltas roll into the aggregate timer at record time
            // — one path for local workers and remote agents alike
            // (`complete` skips its whole-run merge for such jobs)
            for d in &stats.phases {
                st.timer.add_delta(d);
            }
            let now = Instant::now();
            st.epoch_marks.push_back(now);
            while st
                .epoch_marks
                .front()
                .is_some_and(|&t| now.duration_since(t) > EPOCH_RATE_WINDOW)
            {
                st.epoch_marks.pop_front();
            }
            (
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("epoch")),
                        ("id", Value::num(id as f64)),
                        ("stats", stats.to_json()),
                    ])
                }),
                boundary_ev,
                steps,
            )
        };
        observe_epoch_metrics(id, steps_per_epoch, &stats);
        self.append_event(boundary_ev);
        self.append_event(ev);
    }

    /// Pin a negotiated ZO/BP boundary into a remotely-claimed job's
    /// stored spec: the dispatcher evaluated the paper's memory model
    /// against the agent's budget and chose `Method::Tail(k)`. The pin
    /// lands in the registry's copy (so failover / journal replay / the
    /// checkpoint trailer all see the chosen k) and is journaled as a
    /// `boundary` event with reason "negotiated". Returns the updated
    /// spec for the assignment wire; `None` if the job is no longer
    /// running on `agent` (the caller sends the unpinned spec).
    pub fn pin_boundary(&self, id: u64, agent: u64, k: usize) -> Option<JobSpec> {
        let (spec, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            if job.state != JobState::Running || job.agent != Some(agent) {
                return None;
            }
            job.spec.config.method = crate::coordinator::Method::Tail(k);
            (
                job.spec.clone(),
                self.journal.is_some().then(|| {
                    Value::obj(vec![
                        ("event", Value::str("boundary")),
                        ("id", Value::num(id as f64)),
                        ("k", Value::num(k as f64)),
                        ("reason", Value::str("negotiated")),
                        ("agent", Value::num(agent as f64)),
                    ])
                }),
            )
        };
        self.append_event(ev);
        let job = id.to_string();
        crate::metrics::global()
            .gauge(
                "repro_boundary",
                "BP-tail depth (k) currently in effect per job",
                &[("job", job.as_str())],
            )
            .set(k as f64);
        Some(spec)
    }

    /// Running → Done, or — when the outcome says it stopped —
    /// Cancelled (user cancel) / Interrupted (server shutdown).
    pub fn complete(&self, id: u64, outcome: JobOutcome) {
        let ev = {
            let mut st = self.lock();
            let Some(job) = st.jobs.get_mut(&id) else { return };
            // epochs that carried phase deltas already rolled them into
            // the aggregate timer at record time; merging the whole-run
            // timer on top would double-count every phase
            let phases_recorded = job.epochs.iter().any(|e| !e.phases.is_empty());
            job.state = if outcome.stopped {
                if job.interrupted {
                    JobState::Interrupted
                } else {
                    JobState::Cancelled
                }
            } else {
                JobState::Done
            };
            job.best_test_acc = job.best_test_acc.max(outcome.best_test_acc);
            job.run_seconds = job.started.map_or(0.0, |t| t.elapsed().as_secs_f64());
            self.events.publish_state(id, job.state.as_str(), None);
            let ev = self.journal.is_some().then(|| terminal_event(job));
            if !phases_recorded {
                st.timer.merge(&outcome.timer);
            }
            ev
        };
        self.append_event(ev);
    }

    /// Running → Failed with an error message.
    pub fn fail(&self, id: u64, msg: String) {
        let ev = {
            let mut st = self.lock();
            let Some(job) = st.jobs.get_mut(&id) else { return };
            job.state = JobState::Failed;
            job.error = Some(msg);
            job.run_seconds = job.started.map_or(0.0, |t| t.elapsed().as_secs_f64());
            self.events
                .publish_state(id, JobState::Failed.as_str(), job.error.as_deref());
            self.journal.is_some().then(|| terminal_event(job))
        };
        self.append_event(ev);
    }

    /// Cancel by id. Unknown ids return `None`.
    pub fn cancel(&self, id: u64) -> Option<CancelOutcome> {
        let (outcome, ev) = {
            let mut st = self.lock();
            let job = st.jobs.get_mut(&id)?;
            match job.state {
                JobState::Queued => {
                    job.state = JobState::Cancelled;
                    self.events.publish_state(id, JobState::Cancelled.as_str(), None);
                    (
                        CancelOutcome::CancelledQueued,
                        self.journal.is_some().then(|| terminal_event(job)),
                    )
                }
                JobState::Running => {
                    job.stop.request_stop();
                    (CancelOutcome::StopRequested, None)
                }
                terminal => (CancelOutcome::AlreadyTerminal(terminal), None),
            }
        };
        self.append_event(ev);
        Some(outcome)
    }

    pub fn state_of(&self, id: u64) -> Option<JobState> {
        self.lock().jobs.get(&id).map(|j| j.state)
    }

    /// Fire the stop flag of every Running job (server shutdown): the
    /// workers notice at their next batch boundary and exit promptly
    /// instead of holding the pool-join for the rest of the run. Jobs
    /// stopped this way complete as Interrupted — the journal replay on
    /// the next startup requeues them from their last checkpoint —
    /// while user cancels stay terminally Cancelled.
    pub fn stop_all_running(&self) {
        let mut st = self.lock();
        for job in st.jobs.values_mut() {
            if job.state == JobState::Running {
                job.interrupted = true;
                job.stop.request_stop();
            }
        }
    }

    /// Consolidated journal records for every job (compaction).
    pub fn compacted_jobs(&self) -> Vec<Value> {
        self.lock().jobs.values().map(JobRecord::compacted_json).collect()
    }

    /// Full detail JSON for one job (`GET /jobs/<id>`).
    pub fn job_json(&self, id: u64) -> Option<Value> {
        self.job_json_since(id, None)
    }

    /// [`JobRegistry::job_json`] with the epoch history trimmed to
    /// entries with `epoch >= since` (`GET /jobs/<id>?history_since=`),
    /// so pollers of long runs can fetch only what they have not seen.
    pub fn job_json_since(&self, id: u64, since: Option<usize>) -> Option<Value> {
        self.lock().jobs.get(&id).map(|j| j.detail_json(since))
    }

    /// Summary list (`GET /jobs`), newest first.
    pub fn jobs_json(&self) -> Value {
        let st = self.lock();
        Value::obj(vec![(
            "jobs",
            Value::Arr(st.jobs.values().rev().map(JobRecord::summary_json).collect()),
        )])
    }

    /// Aggregate stats (`GET /stats`). `queue_depth` comes from the
    /// queue, which the registry deliberately knows nothing about.
    /// `epochs_total` counts epochs trained by THIS process (journal
    /// restores do not inflate `epochs_per_sec`).
    pub fn stats_json(&self, queue_depth: usize, workers: usize) -> Value {
        let st = self.lock();
        let mut counts = [0usize; 6];
        for j in st.jobs.values() {
            let i = match j.state {
                JobState::Queued => 0,
                JobState::Running => 1,
                JobState::Done => 2,
                JobState::Failed => 3,
                JobState::Cancelled => 4,
                JobState::Interrupted => 5,
            };
            counts[i] += 1;
        }
        let uptime = self.started_at.elapsed().as_secs_f64();
        let phases = Value::Obj(
            ALL_PHASES
                .iter()
                .filter(|&&p| st.timer.total(p).as_nanos() > 0)
                .map(|&p| (p.name().to_string(), Value::num(st.timer.total(p).as_secs_f64())))
                .collect(),
        );
        // epochs/sec over the sliding window (young servers divide by
        // their uptime so the early rate isn't underestimated)
        let now = Instant::now();
        let in_window = st
            .epoch_marks
            .iter()
            .filter(|&&t| now.duration_since(t) <= EPOCH_RATE_WINDOW)
            .count();
        let window = EPOCH_RATE_WINDOW.as_secs_f64().min(uptime).max(1e-9);
        Value::obj(vec![
            ("uptime_seconds", Value::num(uptime)),
            ("workers", Value::num(workers as f64)),
            ("queue_depth", Value::num(queue_depth as f64)),
            ("jobs_total", Value::num(st.jobs.len() as f64)),
            ("jobs_queued", Value::num(counts[0] as f64)),
            ("jobs_running", Value::num(counts[1] as f64)),
            ("jobs_done", Value::num(counts[2] as f64)),
            ("jobs_failed", Value::num(counts[3] as f64)),
            ("jobs_cancelled", Value::num(counts[4] as f64)),
            ("jobs_interrupted", Value::num(counts[5] as f64)),
            ("epochs_total", Value::num(st.total_epochs as f64)),
            ("epochs_per_sec", Value::num(in_window as f64 / window)),
            (
                "epochs_per_sec_window_seconds",
                Value::num(EPOCH_RATE_WINDOW.as_secs_f64().min(uptime)),
            ),
            ("events_seq", Value::num(self.events.current_seq() as f64)),
            ("events_subscribers", Value::num(self.events.subscriber_count() as f64)),
            ("events_lagged_total", Value::num(self.events.lagged_total() as f64)),
            ("phase_seconds", phases),
        ])
    }

    /// `(state, count)` for every job state — the scrape-time sample
    /// behind the `repro_jobs{state=...}` gauge.
    pub fn jobs_by_state(&self) -> [(JobState, usize); 6] {
        let st = self.lock();
        let mut out = [
            (JobState::Queued, 0),
            (JobState::Running, 0),
            (JobState::Done, 0),
            (JobState::Failed, 0),
            (JobState::Cancelled, 0),
            (JobState::Interrupted, 0),
        ];
        for j in st.jobs.values() {
            if let Some(slot) = out.iter_mut().find(|(s, _)| *s == j.state) {
                slot.1 += 1;
            }
        }
        out
    }
}

/// Feed the process metrics registry from one recorded epoch. Called
/// outside the registry lock; histograms and gauges are cheap atomics.
fn observe_epoch_metrics(id: u64, steps_per_epoch: usize, stats: &EpochStats) {
    use crate::metrics::{global, LATENCY_BUCKETS_S};
    let m = global();
    for d in &stats.phases {
        m.histogram(
            "repro_phase_epoch_seconds",
            "Seconds spent per training phase per epoch (the paper's Fig. 7 slices)",
            &[("phase", d.phase.name())],
            &LATENCY_BUCKETS_S,
        )
        .observe(d.seconds);
    }
    m.histogram(
        "repro_epoch_seconds",
        "Wall-clock seconds per completed training epoch",
        &[],
        &LATENCY_BUCKETS_S,
    )
    .observe(stats.seconds);
    m.counter("repro_epochs_total", "Training epochs recorded by this process", &[]).inc();
    let job = id.to_string();
    let lbl = [("job", job.as_str())];
    m.gauge("repro_job_train_loss", "Last reported training loss per job", &lbl)
        .set(stats.train_loss as f64);
    m.gauge("repro_job_train_acc", "Last reported training accuracy per job", &lbl)
        .set(stats.train_acc as f64);
    m.gauge("repro_job_test_acc", "Last reported test accuracy per job", &lbl)
        .set(stats.test_acc as f64);
    if let Some(k) = stats.bp_tail {
        m.gauge("repro_boundary", "BP-tail depth (k) currently in effect per job", &lbl)
            .set(k as f64);
    }
    if stats.seconds > 0.0 {
        m.gauge(
            "repro_job_steps_per_sec",
            "Training steps per second per job (batches/epoch over epoch seconds)",
            &lbl,
        )
        .set(steps_per_epoch as f64 / stats.seconds);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::telemetry::Phase;
    use std::time::Duration;

    fn spec() -> JobSpec {
        JobSpec::new(Config::default())
    }

    #[test]
    fn lifecycle_queued_running_done() {
        let r = JobRegistry::new();
        let id = r.add(spec());
        assert_eq!(r.state_of(id), Some(JobState::Queued));

        let (s, _stop) = r.claim(id, 0).expect("claimable");
        assert_eq!(s.config.epochs, Config::default().epochs);
        assert_eq!(r.state_of(id), Some(JobState::Running));
        // double-claim must fail
        assert!(r.claim(id, 1).is_none());

        r.record_epoch(id, EpochStats { epoch: 0, test_acc: 0.4, ..Default::default() });
        let mut timer = PhaseTimer::new();
        timer.add(Phase::Forward, Duration::from_millis(3));
        r.complete(id, JobOutcome { best_test_acc: 0.4, timer, stopped: false });
        assert_eq!(r.state_of(id), Some(JobState::Done));

        let j = r.job_json(id).unwrap();
        assert_eq!(j.get("state").as_str(), Some("done"));
        assert_eq!(j.get("epochs_done").as_usize(), Some(1));
        assert!(j.get("best_test_acc").as_f64().unwrap() > 0.39);
    }

    #[test]
    fn cancel_queued_and_running() {
        let r = JobRegistry::new();
        let a = r.add(spec());
        assert_eq!(r.cancel(a), Some(CancelOutcome::CancelledQueued));
        assert_eq!(r.state_of(a), Some(JobState::Cancelled));
        // a cancelled-while-queued job is no longer claimable
        assert!(r.claim(a, 0).is_none());

        let b = r.add(spec());
        let (_, stop) = r.claim(b, 0).unwrap();
        assert!(!stop.should_stop());
        assert_eq!(r.cancel(b), Some(CancelOutcome::StopRequested));
        assert!(stop.should_stop());
        r.complete(b, JobOutcome { best_test_acc: 0.0, timer: PhaseTimer::new(), stopped: true });
        assert_eq!(r.state_of(b), Some(JobState::Cancelled));
        assert_eq!(
            r.cancel(b),
            Some(CancelOutcome::AlreadyTerminal(JobState::Cancelled))
        );
        assert_eq!(r.cancel(999), None);
    }

    #[test]
    fn shutdown_stop_completes_as_interrupted() {
        // the same stopped outcome lands differently depending on who
        // asked: stop_all_running (shutdown) ⇒ Interrupted, a user
        // cancel ⇒ Cancelled (exercised above)
        let r = JobRegistry::new();
        let id = r.add(spec());
        let (_, stop) = r.claim(id, 0).unwrap();
        r.stop_all_running();
        assert!(stop.should_stop());
        r.complete(id, JobOutcome { best_test_acc: 0.1, timer: PhaseTimer::new(), stopped: true });
        assert_eq!(r.state_of(id), Some(JobState::Interrupted));
        assert_eq!(
            r.cancel(id),
            Some(CancelOutcome::AlreadyTerminal(JobState::Interrupted))
        );
    }

    #[test]
    fn remote_claim_requeue_and_cancel_race() {
        let r = JobRegistry::new();
        let id = r.add(spec());
        // only Running jobs can requeue
        assert_eq!(r.requeue_interrupted(id), None);

        let s = r.claim_for_agent(id, 7).expect("claimable by an agent");
        assert_eq!(s.config.epochs, Config::default().epochs);
        assert_eq!(r.state_of(id), Some(JobState::Running));
        assert!(r.claim(id, 0).is_none(), "no double claim across local/remote");
        assert_eq!(r.job_json(id).unwrap().get("agent").as_usize(), Some(7));

        // the agent dies: the job goes back to Queued (no checkpoint on
        // disk ⇒ fresh rerun, history cleared) and is claimable again
        r.record_epoch(id, EpochStats::default());
        assert_eq!(r.requeue_interrupted(id), Some(0));
        assert_eq!(r.state_of(id), Some(JobState::Queued));
        assert_eq!(r.job_json(id).unwrap().get("epochs_done").as_usize(), Some(0));
        // a stale epoch report racing the requeue changes nothing
        r.record_epoch(id, EpochStats::default());
        assert_eq!(r.job_json(id).unwrap().get("epochs_done").as_usize(), Some(0));
        assert!(r.claim_for_agent(id, 8).is_some());
        // …and neither does a dead agent's report after a successor
        // re-claimed the job (the owner changed: 7 ≠ 8)
        r.record_epoch_from_agent(id, 7, EpochStats::default());
        assert_eq!(r.job_json(id).unwrap().get("epochs_done").as_usize(), Some(0));
        r.record_epoch_from_agent(id, 8, EpochStats::default());
        assert_eq!(r.job_json(id).unwrap().get("epochs_done").as_usize(), Some(1));

        // a user cancel that raced the agent's death wins over requeue
        assert_eq!(r.cancel(id), Some(CancelOutcome::StopRequested));
        assert!(r.stop_requested(id), "the dispatcher must relay the stop");
        assert_eq!(r.requeue_interrupted(id), None);
        assert_eq!(r.state_of(id), Some(JobState::Cancelled));
        assert!(!r.stop_requested(id), "terminal jobs have nothing to stop");
    }

    #[test]
    fn restore_rebuilds_table_and_advances_ids() {
        use super::super::journal::Replayed;
        let r = JobRegistry::new();
        r.restore(Replayed {
            id: 7,
            spec: spec(),
            state: JobState::Done,
            submitted_unix: 11.0,
            run_seconds: 2.0,
            best_test_acc: 0.8,
            error: None,
            epochs: vec![EpochStats { epoch: 0, test_acc: 0.8, ..Default::default() }],
        });
        assert_eq!(r.state_of(7), Some(JobState::Done));
        let j = r.job_json(7).unwrap();
        assert_eq!(j.get("epochs_done").as_usize(), Some(1));
        assert!(j.get("best_test_acc").as_f64().unwrap() > 0.79);
        // new submissions never collide with restored ids
        let fresh = r.add(spec());
        assert_eq!(fresh, 8);
    }

    #[test]
    fn failure_records_error() {
        let r = JobRegistry::new();
        let id = r.add(spec());
        r.claim(id, 2).unwrap();
        r.fail(id, "engine exploded".into());
        let j = r.job_json(id).unwrap();
        assert_eq!(j.get("state").as_str(), Some("failed"));
        assert_eq!(j.get("error").as_str(), Some("engine exploded"));
        assert_eq!(j.get("worker").as_usize(), Some(2));
    }

    #[test]
    fn stats_aggregate() {
        let r = JobRegistry::new();
        let a = r.add(spec());
        let _b = r.add(spec());
        r.claim(a, 0).unwrap();
        r.record_epoch(a, EpochStats::default());
        r.record_epoch(a, EpochStats::default());
        let s = r.stats_json(1, 4);
        assert_eq!(s.get("jobs_total").as_usize(), Some(2));
        assert_eq!(s.get("jobs_running").as_usize(), Some(1));
        assert_eq!(s.get("jobs_queued").as_usize(), Some(1));
        assert_eq!(s.get("jobs_interrupted").as_usize(), Some(0));
        assert_eq!(s.get("queue_depth").as_usize(), Some(1));
        assert_eq!(s.get("workers").as_usize(), Some(4));
        assert_eq!(s.get("epochs_total").as_usize(), Some(2));
        // sliding-window rate: 2 fresh epochs over a tiny uptime is a
        // positive rate (the old uptime quotient also was, but the
        // window fields must be present and sane)
        assert!(s.get("epochs_per_sec").as_f64().unwrap() > 0.0);
        assert!(s.get("epochs_per_sec_window_seconds").as_f64().unwrap() <= 60.0);
        // event-bus introspection: 2 epoch publishes + 1 state change
        assert_eq!(s.get("events_seq").as_usize(), Some(3));
        assert_eq!(s.get("events_subscribers").as_usize(), Some(0));
        assert_eq!(s.get("events_lagged_total").as_usize(), Some(0));
        // valid JSON end to end
        let text = crate::util::json::to_string(&s);
        crate::util::json::parse(&text).unwrap();
    }

    #[test]
    fn phase_deltas_merge_once_and_surface_per_job() {
        use crate::telemetry::PhaseDelta;
        let r = JobRegistry::new();
        let id = r.add(spec());
        r.claim(id, 0).unwrap();
        for epoch in 0..2 {
            r.record_epoch(
                id,
                EpochStats {
                    epoch,
                    phases: vec![
                        PhaseDelta { phase: Phase::Forward, seconds: 0.5, calls: 10 },
                        PhaseDelta { phase: Phase::ZoUpdate, seconds: 0.25, calls: 5 },
                    ],
                    ..Default::default()
                },
            );
        }
        // the worker's whole-run timer covers the same time; it must
        // NOT be merged on top of the per-epoch deltas
        let mut timer = PhaseTimer::new();
        timer.add(Phase::Forward, Duration::from_secs(1));
        timer.add(Phase::ZoUpdate, Duration::from_millis(500));
        r.complete(id, JobOutcome { best_test_acc: 0.5, timer, stopped: false });

        let s = r.stats_json(0, 1);
        let fwd = s.get("phase_seconds").get("Forward").as_f64().unwrap();
        assert!((fwd - 1.0).abs() < 1e-6, "Forward double-counted: {fwd}");

        // per-job Fig.-7 breakdown in the job detail
        let j = r.job_json(id).unwrap();
        let per_job = j.get("phase_seconds");
        assert!((per_job.get("Forward").as_f64().unwrap() - 1.0).abs() < 1e-6);
        assert!((per_job.get("ZO Update").as_f64().unwrap() - 0.5).abs() < 1e-6);

        // a job with NO phase-carrying epochs still lands its run timer
        // in the aggregate (the legacy path)
        let id2 = r.add(spec());
        r.claim(id2, 0).unwrap();
        let mut t2 = PhaseTimer::new();
        t2.add(Phase::Eval, Duration::from_millis(250));
        r.complete(id2, JobOutcome { best_test_acc: 0.0, timer: t2, stopped: false });
        let s = r.stats_json(0, 1);
        assert!((s.get("phase_seconds").get("Eval").as_f64().unwrap() - 0.25).abs() < 1e-6);
    }

    #[test]
    fn jobs_by_state_counts() {
        let r = JobRegistry::new();
        let a = r.add(spec());
        let _b = r.add(spec());
        r.claim(a, 0).unwrap();
        let counts: BTreeMap<_, _> =
            r.jobs_by_state().into_iter().map(|(s, n)| (s.as_str(), n)).collect();
        assert_eq!(counts["queued"], 1);
        assert_eq!(counts["running"], 1);
        assert_eq!(counts["done"], 0);
    }
}
