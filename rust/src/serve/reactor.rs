//! Nonblocking connection plane for the job server: a small pool of
//! reactor threads, each running a `poll(2)` readiness loop over
//! nonblocking sockets, replaces the old thread-per-connection model.
//!
//! The acceptor ([`super::http::Server::run`]) stays a plain blocking
//! accept loop; every accepted socket is handed to one reactor via
//! [`ReactorPool::assign`] (round-robin, woken through a pipe). From
//! then on the reactor owns the connection end to end:
//!
//! - **Reads** accumulate into a per-connection buffer; the
//!   `\r\n\r\n` scan resumes from the previous read's tail (same
//!   linear-scan guarantee as the old blocking `read_request`).
//! - **HTTP/1.1 keep-alive**: `Connection` and `Content-Length` are
//!   honored in both directions, pipelined requests are answered in
//!   order, and connections idle past `ServeOptions::http_idle` are
//!   reaped. `Connection: close` (and any HTTP/1.0 request without
//!   `keep-alive`) still gets the old one-shot behavior byte for
//!   byte.
//! - **Writes** stage into a reusable per-connection buffer and drain
//!   on `POLLOUT` — a stalled client holds only its own buffer, never
//!   a thread. `WouldBlock` is handled explicitly everywhere; there
//!   are no socket timeouts left in the server path.
//! - **SSE streams** are reactor-registered writers multiplexed off
//!   the event bus: each stream is a [`Subscriber`] polled with
//!   `try_recv` (publish wakes the reactor through the same pipe), so
//!   open streams cost a buffer instead of a thread and the old
//!   64-stream cap lifts to `ServeOptions::max_sse`. Live events ship
//!   the bus's pre-rendered frame bytes without re-serializing.
//! - **Drain**: when the shutdown flag rises, reactors stop parsing
//!   new requests, flush what they can, and force-close whatever is
//!   still stuck once `ServeOptions::drain_grace` elapses — a stalled
//!   SSE client can no longer hold `/shutdown` open.
//!
//! Everything protocol-visible (routes, status codes, error strings,
//! SSE frame bytes, metrics) is shared with — and identical to — the
//! old path in [`super::http`].

use super::events::{Poll as BusPoll, Subscriber, Waker};
use super::http::{
    find_subslice, http_route_label, is_stream_route, observe_http, qget, split_query,
    status_text, Gateway, HTTP_REQS_HELP, HTTP_REQS_NAME, SSE_KEEPALIVE,
};
use super::protocol::{error_json, JobState};
use crate::util::json::{self, Value};
use anyhow::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::os::fd::{AsRawFd, RawFd};
use std::os::raw::{c_int, c_short};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// poll(2) FFI — std-only readiness notification (no new dependencies).

#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: RawFd,
    events: c_short,
    revents: c_short,
}

const POLLIN: c_short = 0x001;
const POLLOUT: c_short = 0x004;
const POLLERR: c_short = 0x008;
const POLLHUP: c_short = 0x010;
const POLLNVAL: c_short = 0x020;

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
}

/// Block until a descriptor is ready or `timeout_ms` elapses. EINTR
/// and transient failures report as "nothing ready"; the caller's
/// loop re-polls.
fn poll_ready(fds: &mut [PollFd], timeout_ms: i32) {
    // SAFETY: `fds` is an exclusively borrowed slice of `#[repr(C)]`
    // records matching the kernel's `struct pollfd` layout, valid for
    // the whole call, and `nfds` is exactly its length.
    let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
    if rc < 0 && std::io::Error::last_os_error().kind() != ErrorKind::Interrupted {
        // EINVAL/ENOMEM have no per-connection remedy; back off so a
        // persistent failure cannot spin the reactor at 100% CPU.
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// Reactor pool

/// The reactor threads plus the acceptor-side handles for feeding
/// them connections. Owned by [`super::http::Server::run`].
pub(crate) struct ReactorPool {
    workers: Vec<ReactorHandle>,
    next: usize,
}

struct ReactorHandle {
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    wake_tx: UnixStream,
    handle: std::thread::JoinHandle<()>,
}

impl ReactorPool {
    /// Spawn the reactor threads (`ServeOptions::reactor_threads`, or
    /// about half the available cores clamped to [1, 4] when 0).
    pub(crate) fn spawn(gw: Arc<Gateway>) -> Result<ReactorPool> {
        let n = if gw.reactor_threads > 0 {
            gw.reactor_threads
        } else {
            let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
            cores.div_ceil(2).clamp(1, 4)
        };
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_tx.set_nonblocking(true)?;
            wake_rx.set_nonblocking(true)?;
            // the reactor hands clones of this end to bus subscribers
            // as their waker, so publishes interrupt the poll sleep
            let waker_tx = wake_tx.try_clone()?;
            let inbox: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
            let gw2 = gw.clone();
            let inbox2 = inbox.clone();
            let handle = std::thread::Builder::new()
                .name(format!("serve-reactor-{i}"))
                .spawn(move || reactor_loop(gw2, inbox2, wake_rx, waker_tx))?;
            workers.push(ReactorHandle { inbox, wake_tx, handle });
        }
        Ok(ReactorPool { workers, next: 0 })
    }

    /// Hand a freshly accepted connection to the next reactor
    /// (round-robin) and wake it.
    pub(crate) fn assign(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return; // socket already dead — nothing to serve
        }
        let _ = stream.set_nodelay(true);
        let w = &self.workers[self.next % self.workers.len()];
        self.next = self.next.wrapping_add(1);
        w.inbox.lock().unwrap_or_else(|e| e.into_inner()).push(stream);
        let _ = (&w.wake_tx).write(&[1u8]);
    }

    /// Wake every reactor so it notices the shutdown flag, then wait
    /// for them to drain (bounded by `ServeOptions::drain_grace`).
    pub(crate) fn join(self) {
        for w in &self.workers {
            let _ = (&w.wake_tx).write(&[1u8]);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Per-connection state

/// One read(2) worth of bytes.
const READ_CHUNK: usize = 4096;

/// Pending-request bytes past which a connection stops being polled
/// readable until its backlog drains — the bound on pipelining depth
/// (a client cannot buffer unbounded requests server-side).
const RBUF_HIGHWATER: usize = 256 * 1024;

/// Reactor tick: the longest a timer-driven action (SSE keep-alive,
/// idle reaping, drain deadline) can lag behind its due time.
const POLL_TICK_MS: i32 = 100;

struct SseState {
    sub: Subscriber,
    /// Events at or below this bus sequence were covered by the
    /// replay snapshot; the live loop skips them (exactly-once).
    watermark: u64,
    /// Per-job streams end when the watched job goes terminal.
    close_on_terminal: bool,
    last_write: Instant,
}

struct Conn {
    stream: TcpStream,
    /// Unparsed request bytes; `scan_from` resumes the header-
    /// terminator scan so parsing stays linear in the header size.
    rbuf: Vec<u8>,
    scan_from: usize,
    /// Staged response bytes not yet accepted by the socket; reused
    /// across requests so the steady-state request cycle does not
    /// allocate.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Reusable JSON serialization buffer (bodies render here first
    /// so `Content-Length` is known before the header is written).
    scratch: String,
    sse: Option<SseState>,
    /// Requests already served on this connection (> 0 ⇒ keep-alive
    /// reuse).
    served: u64,
    /// Peer half-closed its write side (read returned 0).
    eof: bool,
    /// Close once `wbuf` is flushed (Connection: close, fatal 400,
    /// terminal SSE, shutdown response).
    close_after_flush: bool,
    /// Close now, flushed or not (socket error, drain deadline).
    force_close: bool,
    last_progress: Instant,
    ready: c_short,
}

impl Conn {
    fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            scan_from: 0,
            wbuf: Vec::new(),
            wpos: 0,
            scratch: String::new(),
            sse: None,
            served: 0,
            eof: false,
            close_after_flush: false,
            force_close: false,
            last_progress: now,
            ready: 0,
        }
    }

    fn flushed(&self) -> bool {
        self.wpos >= self.wbuf.len()
    }

    fn poll_events(&self) -> c_short {
        let mut ev = 0;
        if !self.eof && self.rbuf.len() <= RBUF_HIGHWATER {
            ev |= POLLIN;
        }
        if !self.flushed() {
            ev |= POLLOUT;
        }
        ev
    }

    /// Should this connection be torn down after the current pass?
    fn should_close(
        &self,
        now: Instant,
        gw: &Gateway,
        draining: bool,
        drain_deadline: Option<Instant>,
    ) -> bool {
        if self.force_close {
            return true;
        }
        if self.close_after_flush && self.flushed() {
            return true;
        }
        // peer is gone (or half-closed with nothing left to say)
        if self.eof && self.flushed() {
            return true;
        }
        if draining {
            // flush what we can; past the grace deadline a stalled
            // client is cut loose rather than holding the drain open
            return self.flushed() || drain_deadline.is_some_and(|dl| now >= dl);
        }
        // Idle reaping: HTTP connections (including half-read
        // requests and stalled response readers) are reaped after
        // `http_idle` without progress — the old 10 s socket-timeout
        // behavior. A healthy SSE stream is exempt (its keep-alives
        // count as progress); one with stuck bytes is not.
        if now.duration_since(self.last_progress) < gw.http_idle {
            return false;
        }
        !(self.sse.is_some() && self.flushed())
    }
}

// ---------------------------------------------------------------------------
// The event loop

fn reactor_loop(
    gw: Arc<Gateway>,
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    wake_rx: UnixStream,
    waker_tx: UnixStream,
) {
    let m = crate::metrics::global();
    // cache the label-less handles once — the loop body must not take
    // the registry lock per pass
    let loop_hist = m.histogram(
        "repro_reactor_loop_seconds",
        "Reactor pass service time (excluding the poll sleep)",
        &[],
        &crate::metrics::LATENCY_BUCKETS_S,
    );
    let reuse_ctr = m.counter(
        "repro_http_keepalive_reuse_total",
        "Requests served on an already-used keep-alive connection",
        &[],
    );
    let waker: Waker = Arc::new(move || {
        let _ = (&waker_tx).write(&[1u8]);
    });
    let mut conns: Vec<Conn> = Vec::new();
    let mut pfds: Vec<PollFd> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        let draining = gw.shutdown.load(Ordering::SeqCst);
        // adopt freshly assigned connections (dropped during drain:
        // the acceptor has already stopped feeding us by then)
        for stream in inbox.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            if draining {
                continue;
            }
            gw.open_conns.fetch_add(1, Ordering::SeqCst);
            conns.push(Conn::new(stream, Instant::now()));
        }
        if draining {
            if drain_deadline.is_none() {
                drain_deadline = Some(Instant::now() + gw.drain_grace);
            }
            if conns.is_empty() {
                return;
            }
        }
        pfds.clear();
        pfds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        for c in &conns {
            pfds.push(PollFd { fd: c.stream.as_raw_fd(), events: c.poll_events(), revents: 0 });
        }
        poll_ready(&mut pfds, POLL_TICK_MS);
        let t0 = Instant::now();
        if pfds[0].revents != 0 {
            let mut sink = [0u8; 64];
            while matches!((&wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }
        for (c, p) in conns.iter_mut().zip(pfds[1..].iter()) {
            c.ready = p.revents;
        }
        let now = Instant::now();
        let draining = gw.shutdown.load(Ordering::SeqCst);
        let mut i = 0;
        while i < conns.len() {
            service_conn(&gw, &mut conns[i], &waker, &reuse_ctr, now, draining);
            if conns[i].should_close(now, &gw, draining, drain_deadline) {
                teardown(&gw, conns.swap_remove(i));
            } else {
                i += 1;
            }
        }
        loop_hist.observe(t0.elapsed().as_secs_f64());
    }
}

fn teardown(gw: &Gateway, c: Conn) {
    if c.sse.is_some() {
        // dropping the Subscriber (inside SseState) unregisters it
        // from the bus — no reactor-side registration can leak
        gw.sse_active.fetch_sub(1, Ordering::SeqCst);
    }
    gw.open_conns.fetch_sub(1, Ordering::SeqCst);
}

/// One pass over one connection: read what the socket has, serve any
/// complete requests, pump SSE events, flush what the socket takes.
fn service_conn(
    gw: &Arc<Gateway>,
    c: &mut Conn,
    waker: &Waker,
    reuse_ctr: &crate::metrics::Counter,
    now: Instant,
    draining: bool,
) {
    if c.ready & POLLNVAL != 0 {
        c.force_close = true;
        return;
    }
    if c.ready & (POLLIN | POLLHUP | POLLERR) != 0 {
        read_some(c, now);
    }
    if c.force_close {
        return;
    }
    if !draining {
        serve_buffered_requests(gw, c, waker, reuse_ctr, now);
    }
    pump_sse(gw, c, now);
    flush_some(c, now);
}

/// Drain the socket's receive buffer into `rbuf` (explicit
/// `WouldBlock` handling — the reactor never blocks in read).
fn read_some(c: &mut Conn, now: Instant) {
    let mut tmp = [0u8; READ_CHUNK];
    loop {
        match c.stream.read(&mut tmp) {
            Ok(0) => {
                c.eof = true;
                return;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&tmp[..n]);
                c.last_progress = now;
                if c.rbuf.len() > RBUF_HIGHWATER {
                    return; // pipelining bound: parse before reading more
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.force_close = true;
                return;
            }
        }
    }
}

/// Write as much of `wbuf` as the socket will take right now.
fn flush_some(c: &mut Conn, now: Instant) {
    while !c.flushed() {
        match c.stream.write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.force_close = true;
                return;
            }
            Ok(n) => {
                c.wpos += n;
                c.last_progress = now;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.force_close = true;
                return;
            }
        }
    }
    // fully drained: recycle the buffer allocation for the next
    // response instead of growing forever
    c.wbuf.clear();
    c.wpos = 0;
}

// ---------------------------------------------------------------------------
// HTTP request cycle

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

enum Parse {
    /// Not enough bytes yet — wait for more reads.
    Incomplete,
    Ok(Request),
    /// Protocol error; the message matches the old blocking scanner's
    /// wording byte for byte.
    Err(&'static str),
}

/// Try to cut one complete content-length-framed request off the
/// front of `rbuf`. Same limits and error strings as the old blocking
/// `read_request`.
fn parse_request(rbuf: &mut Vec<u8>, scan_from: &mut usize) -> Parse {
    let header_end = match find_subslice(&rbuf[*scan_from..], b"\r\n\r\n") {
        Some(pos) => *scan_from + pos,
        None => {
            // the terminator may straddle a read boundary: keep the
            // last 3 scanned bytes in play for the next attempt
            *scan_from = rbuf.len().saturating_sub(3);
            if rbuf.len() >= 64 * 1024 {
                return Parse::Err("headers too large");
            }
            return Parse::Incomplete;
        }
    };
    let Ok(head) = std::str::from_utf8(&rbuf[..header_end]) else {
        return Parse::Err("non-utf8 headers");
    };
    let mut lines = head.split("\r\n");
    let Some(reqline) = lines.next() else {
        return Parse::Err("empty request");
    };
    let mut parts = reqline.split_whitespace();
    let Some(method) = parts.next() else {
        return Parse::Err("missing method");
    };
    let method = method.to_ascii_uppercase();
    let Some(path) = parts.next() else {
        return Parse::Err("missing path");
    };
    let path = path.to_string();
    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close; an explicit
    // Connection token overrides either way
    let mut keep_alive = !reqline.trim_end().ends_with("HTTP/1.0");
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let k = k.trim();
            if k.eq_ignore_ascii_case("content-length") {
                match v.trim().parse() {
                    Ok(n) => content_len = n,
                    Err(_) => return Parse::Err("bad content-length"),
                }
            } else if k.eq_ignore_ascii_case("connection") {
                for tok in v.split(',') {
                    let tok = tok.trim();
                    if tok.eq_ignore_ascii_case("close") {
                        keep_alive = false;
                    } else if tok.eq_ignore_ascii_case("keep-alive") {
                        keep_alive = true;
                    }
                }
            }
        }
    }
    if content_len > 1 << 20 {
        return Parse::Err("body too large (max 1 MiB)");
    }
    let total = header_end + 4 + content_len;
    if rbuf.len() < total {
        return Parse::Incomplete; // scan_from ≤ header_end, refinds it
    }
    let body = rbuf[header_end + 4..total].to_vec();
    rbuf.drain(..total);
    *scan_from = 0;
    Parse::Ok(Request { method, path, body, keep_alive })
}

/// Parse and serve requests off `rbuf` until it runs dry, the
/// connection turns into an SSE stream, or a close is pending.
fn serve_buffered_requests(
    gw: &Arc<Gateway>,
    c: &mut Conn,
    waker: &Waker,
    reuse_ctr: &crate::metrics::Counter,
    now: Instant,
) {
    while c.sse.is_none() && !c.close_after_flush && !c.force_close {
        match parse_request(&mut c.rbuf, &mut c.scan_from) {
            Parse::Incomplete => {
                if c.eof && !c.rbuf.is_empty() {
                    // peer hung up mid-request: the old scanner's error
                    write_error_close(c, "bad request: connection closed mid-headers");
                }
                return;
            }
            Parse::Err(msg) => {
                c.scratch.clear();
                c.scratch.push_str("bad request: ");
                c.scratch.push_str(msg);
                let body = error_json(&c.scratch);
                write_json_response(c, 400, &body, false);
                c.close_after_flush = true;
                return;
            }
            Parse::Ok(req) => {
                if c.served > 0 {
                    reuse_ctr.inc();
                }
                c.served += 1;
                serve_request(gw, c, req, waker, now);
            }
        }
    }
}

fn write_error_close(c: &mut Conn, msg: &str) {
    let body = error_json(msg);
    write_json_response(c, 400, &body, false);
    c.close_after_flush = true;
}

/// Route one parsed request and stage its response.
fn serve_request(gw: &Arc<Gateway>, c: &mut Conn, req: Request, waker: &Waker, now: Instant) {
    let t0 = Instant::now();
    let (path, query) = split_query(&req.path);
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    // Prometheus exposition: the one non-JSON one-shot response
    if let ("GET", ["metrics"]) = (req.method.as_str(), segs.as_slice()) {
        let text = gw.render_metrics();
        observe_http("GET /metrics", 200, t0.elapsed());
        write_text_response(c, 200, &text, req.keep_alive);
        if !req.keep_alive {
            c.close_after_flush = true;
        }
        return;
    }
    if is_stream_route(&req.method, &segs) {
        start_sse(gw, c, &segs, &query, waker, now);
        return;
    }
    let (status, body, shutdown) = gw.route(&req.method, &segs, &query, &req.body);
    observe_http(&http_route_label(&req.method, &segs, status), status, t0.elapsed());
    if shutdown {
        // close the queue BEFORE acknowledging: any submission that
        // observes the shutdown gets a truthful 503 instead of racing
        // the teardown
        gw.begin_shutdown();
    }
    let keep = req.keep_alive && !shutdown;
    write_json_response(c, status, &body, keep);
    if !keep {
        c.close_after_flush = true;
    }
    if shutdown {
        gw.wake();
    }
}

// ---------------------------------------------------------------------------
// SSE streams

/// Upgrade the connection into a reactor-registered SSE writer (or
/// stage a one-shot error / replay-only response).
fn start_sse(
    gw: &Arc<Gateway>,
    c: &mut Conn,
    segs: &[&str],
    query: &[(&str, &str)],
    waker: &Waker,
    now: Instant,
) {
    // Streams are cheap now (a buffer, not a thread) but each still
    // pins a bus subscriber; a runaway client opening streams in a
    // loop is refused past the cap instead of exhausting the very
    // devices this stack runs on.
    if gw.sse_active.fetch_add(1, Ordering::SeqCst) >= gw.max_sse {
        gw.sse_active.fetch_sub(1, Ordering::SeqCst);
        let body = error_json(&format!(
            "too many open event streams (max {}); \
             close one or poll GET /jobs/<id>?history_since=",
            gw.max_sse
        ));
        write_json_response(c, 503, &body, false);
        c.close_after_flush = true;
        return;
    }
    // streams are counted but not latency-timed: their "duration" is
    // the watch lifetime, not a response time
    let label = if segs.len() == 1 { "GET /events" } else { "GET /jobs/{}/events" };
    crate::metrics::global()
        .counter(HTTP_REQS_NAME, HTTP_REQS_HELP, &[("route", label), ("code", "200")])
        .inc();
    let installed = match segs {
        ["events"] => sse_firehose(gw, c, query, now),
        ["jobs", id, "events"] => sse_job_events(gw, c, id, now),
        _ => unreachable!("is_stream_route and this match must agree"),
    };
    match installed {
        Some(sse) => {
            sse.sub.set_waker(waker.clone());
            c.sse = Some(sse);
        }
        None => {
            // refused (bad id / no such job) or replay-only: the
            // response is already staged, the stream never installs
            gw.sse_active.fetch_sub(1, Ordering::SeqCst);
            c.close_after_flush = true;
        }
    }
}

/// `GET /jobs/{id}/events` — replay the recorded history, then hand
/// back a live subscription (None when the job is already terminal).
fn sse_job_events(gw: &Arc<Gateway>, c: &mut Conn, id_seg: &str, now: Instant) -> Option<SseState> {
    let Ok(id) = id_seg.parse::<u64>() else {
        let body = error_json("job id must be an integer");
        write_json_response(c, 400, &body, false);
        return None;
    };
    // subscribe BEFORE the snapshot: anything published in between
    // lands in the buffer AND below the snapshot's watermark, and the
    // live loop skips it — exactly-once across the seam
    let sub = gw.registry.events().subscribe(Some(id), gw.events_buffer);
    let Some(snap) = gw.registry.stream_snapshot(id) else {
        let body = error_json(&format!("no job {id}"));
        write_json_response(c, 404, &body, false);
        return None;
    };
    write_sse_header(c);
    for e in &snap.epochs {
        let data = Value::obj(vec![
            ("type", Value::str("epoch")),
            ("job", Value::num(id as f64)),
            ("replay", Value::Bool(true)),
            ("stats", e.to_json()),
        ]);
        push_sse_frame(&mut c.wbuf, &mut c.scratch, "epoch", None, &data);
    }
    let mut pairs = vec![
        ("type", Value::str("state")),
        ("job", Value::num(id as f64)),
        ("replay", Value::Bool(true)),
        ("state", Value::str(snap.state.as_str())),
    ];
    if let Some(err) = &snap.error {
        pairs.push(("error", Value::str(err.clone())));
    }
    push_sse_frame(&mut c.wbuf, &mut c.scratch, "state", None, &Value::obj(pairs));
    if snap.state.is_terminal() {
        return None; // the job already finished: replay-only stream
    }
    Some(SseState { sub, watermark: snap.watermark, close_on_terminal: true, last_write: now })
}

/// `GET /events` — the all-jobs firehose, with `?since_seq=` resume
/// off the retained ring (a leading `lagged` frame marks an evicted
/// resume point).
fn sse_firehose(
    gw: &Arc<Gateway>,
    c: &mut Conn,
    query: &[(&str, &str)],
    now: Instant,
) -> Option<SseState> {
    let since = match qget(query, "since_seq") {
        None => None,
        Some(s) => match s.parse::<u64>() {
            Ok(n) => Some(n),
            Err(_) => {
                let body = error_json("since_seq must be an integer sequence number");
                write_json_response(c, 400, &body, false);
                return None;
            }
        },
    };
    let bus = gw.registry.events().clone();
    let (sub, backlog, gap, resume_seq) =
        bus.subscribe_since(gw.events_buffer, since.unwrap_or_else(|| bus.current_seq()));
    write_sse_header(c);
    if gap {
        let data = Value::obj(vec![
            ("type", Value::str("lagged")),
            ("next_seq", Value::num(resume_seq as f64)),
        ]);
        push_sse_frame(&mut c.wbuf, &mut c.scratch, "lagged", None, &data);
    }
    for e in &backlog {
        c.wbuf.extend_from_slice(e.frame.as_bytes());
    }
    Some(SseState { sub, watermark: 0, close_on_terminal: false, last_write: now })
}

/// Deliver pending bus events into the connection's write buffer —
/// the nonblocking counterpart of the old `pump` loop. Stops pulling
/// at the write high-water mark so a slow reader sheds at the bus
/// (yielding a `lagged` frame) instead of buffering without bound;
/// the trainers never wait either way.
fn pump_sse(gw: &Arc<Gateway>, c: &mut Conn, now: Instant) {
    let Some(sse) = c.sse.as_mut() else { return };
    let mut closed = false;
    while c.wbuf.len() - c.wpos < gw.sse_highwater {
        match sse.sub.try_recv() {
            BusPoll::Event(e) => {
                if e.seq <= sse.watermark {
                    continue; // the replay snapshot already covered it
                }
                // live frames were rendered once at publish; every
                // subscriber ships the same bytes, allocation-free
                c.wbuf.extend_from_slice(e.frame.as_bytes());
                sse.last_write = now;
                let terminal = e
                    .state()
                    .and_then(|s| JobState::parse(s).ok())
                    .is_some_and(|s| s.is_terminal());
                if sse.close_on_terminal && terminal {
                    closed = true;
                    break;
                }
            }
            BusPoll::Lagged { next_seq } => {
                let data = Value::obj(vec![
                    ("type", Value::str("lagged")),
                    ("next_seq", Value::num(next_seq as f64)),
                ]);
                push_sse_frame(&mut c.wbuf, &mut c.scratch, "lagged", None, &data);
                sse.last_write = now;
            }
            BusPoll::Timeout => break,
            BusPoll::Closed => {
                closed = true;
                break;
            }
        }
    }
    if !closed && now.duration_since(sse.last_write) >= SSE_KEEPALIVE {
        c.wbuf.extend_from_slice(b": keep-alive\n\n");
        sse.last_write = now;
    }
    if closed {
        c.close_after_flush = true;
    }
}

// ---------------------------------------------------------------------------
// Response staging (into the connection's reusable buffers)

fn write_json_response(c: &mut Conn, status: u16, v: &Value, keep_alive: bool) {
    c.scratch.clear();
    json::write_compact(v, &mut c.scratch);
    let blen = c.scratch.len();
    let conn_hdr = if keep_alive { "keep-alive" } else { "close" };
    let _ = write!(
        c.wbuf,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {blen}\r\nConnection: {conn_hdr}\r\n\r\n",
        status_text(status)
    );
    let Conn { wbuf, scratch, .. } = c;
    wbuf.extend_from_slice(scratch.as_bytes());
}

/// Plain-text staging for the Prometheus exposition. `version=0.0.4`
/// is the text-format marker scrapers key on.
fn write_text_response(c: &mut Conn, status: u16, body: &str, keep_alive: bool) {
    let conn_hdr = if keep_alive { "keep-alive" } else { "close" };
    let _ = write!(
        c.wbuf,
        "HTTP/1.1 {status} {}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: {conn_hdr}\r\n\r\n",
        status_text(status),
        body.len()
    );
    c.wbuf.extend_from_slice(body.as_bytes());
}

fn write_sse_header(c: &mut Conn) {
    c.wbuf.extend_from_slice(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    );
}

/// Stage one cold-path SSE frame (replay / lagged / error frames that
/// have no pre-rendered bytes): optional `id:` line, `event:` name,
/// one `data:` line of compact JSON.
fn push_sse_frame(
    wbuf: &mut Vec<u8>,
    scratch: &mut String,
    event: &str,
    id: Option<u64>,
    data: &Value,
) {
    if let Some(i) = id {
        let _ = writeln!(wbuf, "id: {i}");
    }
    let _ = write!(wbuf, "event: {event}\ndata: ");
    scratch.clear();
    json::write_compact(data, scratch);
    wbuf.extend_from_slice(scratch.as_bytes());
    wbuf.extend_from_slice(b"\n\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(input: &[u8]) -> (Vec<Request>, Option<&'static str>) {
        let mut rbuf = input.to_vec();
        let mut scan_from = 0;
        let mut out = Vec::new();
        loop {
            match parse_request(&mut rbuf, &mut scan_from) {
                Parse::Incomplete => return (out, None),
                Parse::Err(e) => return (out, Some(e)),
                Parse::Ok(r) => out.push(r),
            }
        }
    }

    #[test]
    fn pipelined_requests_split_in_order() {
        let wire = b"GET /healthz HTTP/1.1\r\n\r\nPOST /jobs HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n";
        let (reqs, err) = parse_all(wire);
        assert!(err.is_none());
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].path, "/healthz");
        assert!(reqs[0].keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(reqs[1].body, b"{}");
        assert!(reqs[1].keep_alive);
        assert_eq!(reqs[2].path, "/stats");
        assert!(!reqs[2].keep_alive, "explicit Connection: close honored");
    }

    #[test]
    fn http10_defaults_to_close() {
        let (reqs, _) = parse_all(b"GET / HTTP/1.0\r\n\r\n");
        assert!(!reqs[0].keep_alive);
        let (reqs, _) = parse_all(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(reqs[0].keep_alive);
    }

    #[test]
    fn torn_input_resumes_across_feeds() {
        // feed a request one byte at a time through the resumable scanner
        let wire = b"POST /jobs HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut rbuf: Vec<u8> = Vec::new();
        let mut scan_from = 0;
        let mut got = None;
        for (i, b) in wire.iter().enumerate() {
            rbuf.push(*b);
            match parse_request(&mut rbuf, &mut scan_from) {
                Parse::Incomplete => assert!(i + 1 < wire.len(), "must complete on last byte"),
                Parse::Ok(r) => got = Some(r),
                Parse::Err(e) => panic!("unexpected error: {e}"),
            }
        }
        let r = got.expect("request completes");
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"hello");
        assert!(rbuf.is_empty(), "consumed exactly one request");
    }

    #[test]
    fn malformed_content_length_is_an_error() {
        let (_, err) = parse_all(b"POST /jobs HTTP/1.1\r\nContent-Length: banana\r\n\r\n");
        assert_eq!(err, Some("bad content-length"));
        let (_, err) = parse_all(b"POST /jobs HTTP/1.1\r\nContent-Length: 9999999999\r\n\r\n");
        assert_eq!(err, Some("body too large (max 1 MiB)"));
    }

    #[test]
    fn oversized_headers_rejected() {
        let mut wire = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        wire.resize(wire.len() + 70 * 1024, b'x');
        let (_, err) = parse_all(&wire);
        assert_eq!(err, Some("headers too large"));
    }

    #[test]
    fn response_staging_headers() {
        let mut c = Conn::new_for_test();
        write_json_response(&mut c, 200, &Value::obj(vec![("ok", Value::Bool(true))]), true);
        let text = String::from_utf8(c.wbuf.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.ends_with("{\"ok\":true}"));
        c.wbuf.clear();
        write_json_response(&mut c, 503, &error_json("x"), false);
        let text = String::from_utf8(c.wbuf.clone()).unwrap();
        assert!(text.contains("Connection: close\r\n"));
    }

    impl Conn {
        fn new_for_test() -> Conn {
            // a connected-but-unused socket pair stands in for a client
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            Conn::new(stream, Instant::now())
        }
    }
}
