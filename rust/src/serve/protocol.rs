//! JSON wire types for the job server — in the spirit of the in-tree
//! `util::json` substrate: no serde, hand-rolled (de)serialization.
//!
//! A job spec is a flat JSON object. Two keys are server-level
//! (`name`, `priority`); the training keys are exactly one serialized
//! `coordinator::session::TrainSpec` (method, combined precision token,
//! `grad_mode`, epochs/batch/lr/eps/seed/eval_every, int8 knobs — no
//! fp32/int8 union, one spec shape for every cell of the paper's grid);
//! the rest are data/backend keys with the `repro train` semantics
//! (`model`, `dataset`, `engine`, `train_n`, `test_n`, `npoints`,
//! `ncls`, `artifacts`, `save`, `load`). Everything the CLI can run,
//! the server can schedule.

use crate::config::{scalar_to_string, Config, Precision};
use crate::coordinator::session::resolve_grad_mode;
use crate::coordinator::{DpSpec, ZoGradMode};
use crate::util::json::Value;
use anyhow::{Context, Result};

/// Default TCP port of `repro serve`.
pub const DEFAULT_PORT: u16 = 8377;

/// One schedulable training job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Optional human label, echoed back in listings.
    pub name: String,
    /// Higher runs first; FIFO within a priority level. Default 0.
    pub priority: i64,
    /// The full training configuration (validated at submit time).
    pub config: Config,
}

impl JobSpec {
    pub fn new(config: Config) -> JobSpec {
        JobSpec { name: String::new(), priority: 0, config }
    }

    /// Parse a submit body. Unknown keys and invalid combinations are
    /// rejected with context (surfaced to the client as a 400). The
    /// `precision` × `grad_mode` pair (the [`ZoGradMode::token`] form a
    /// serialized `TrainSpec` carries) is resolved through the same
    /// [`resolve_grad_mode`] rule as `TrainSpec::from_json`, so the two
    /// layers can never disagree: a `"int"` token refines a plain
    /// `int8` precision to INT8*, true conflicts fail loudly.
    pub fn from_json(v: &Value) -> Result<JobSpec> {
        let obj = v.as_obj().context("job spec must be a JSON object")?;
        let mut spec = JobSpec::new(Config::default());
        let mut grad_mode: Option<ZoGradMode> = None;
        for (k, val) in obj {
            match k.as_str() {
                "name" => spec.name = val.as_str().context("name must be a string")?.to_string(),
                "priority" => {
                    spec.priority = val.as_i64().context("priority must be a number")?
                }
                "grad_mode" | "grad-mode" => {
                    grad_mode = Some(ZoGradMode::parse(
                        val.as_str().context("grad_mode must be a string")?,
                    )?)
                }
                // dp is the one nested key: {replicas, aggregate,
                // min_replicas} (a bare number also works via the flat
                // `"dp": N` form the CLI produces)
                "dp" if val.as_obj().is_some() => {
                    let dp = DpSpec::from_json(val)?;
                    spec.config.dp_replicas = dp.replicas;
                    spec.config.dp_aggregate = dp.aggregate;
                    spec.config.dp_min_replicas = dp.min_replicas;
                }
                key => {
                    let s = scalar_to_string(val)
                        .with_context(|| format!("job spec key '{key}'"))?;
                    spec.config.set(key, &s)?;
                }
            }
        }
        if grad_mode.is_some() {
            let resolved = resolve_grad_mode(
                spec.config.precision != Precision::Fp32,
                spec.config.precision == Precision::Int8Star,
                grad_mode,
            )?;
            if resolved == ZoGradMode::IntCE {
                spec.config.precision = Precision::Int8Star;
            }
        }
        spec.config.validate()?;
        Ok(spec)
    }

    /// Serialize back to the same flat shape `from_json` accepts: the
    /// training keys come from the one unified
    /// [`crate::coordinator::TrainSpec`] serializer, with the server
    /// and data/backend keys merged alongside.
    pub fn to_json(&self) -> Value {
        let c = &self.config;
        let Value::Obj(mut obj) = c.train_spec().to_json() else {
            unreachable!("TrainSpec::to_json returns an object")
        };
        let mut put = |k: &str, v: Value| {
            obj.insert(k.to_string(), v);
        };
        put("name", Value::str(self.name.clone()));
        put("priority", Value::num(self.priority as f64));
        put("model", Value::str(c.model.clone()));
        put("dataset", Value::str(c.dataset.token()));
        put("engine", Value::str(c.engine.token()));
        put("train_n", Value::num(c.train_n as f64));
        put("test_n", Value::num(c.test_n as f64));
        put("npoints", Value::num(c.npoints as f64));
        put("ncls", Value::num(c.ncls as f64));
        if let Some(p) = &c.artifacts_dir {
            put("artifacts", Value::str(p.clone()));
        }
        if let Some(p) = &c.load_checkpoint {
            put("load", Value::str(p.clone()));
        }
        if let Some(p) = &c.save_checkpoint {
            put("save", Value::str(p.clone()));
        }
        if let Some(p) = &c.resume {
            put("resume", Value::str(p.clone()));
        }
        put("ckpt_every", Value::num(c.ckpt_every as f64));
        put("ckpt_keep", Value::num(c.ckpt_keep as f64));
        if let Some(dp) = c.dp_spec() {
            put("dp", dp.to_json());
        }
        Value::Obj(obj)
    }
}

/// Job lifecycle: Queued → Running → Done | Failed | Cancelled |
/// Interrupted.
///
/// `Interrupted` is the shutdown-stop state: the server's own shutdown
/// fired the job's stop flag, not a user cancel. It is terminal for
/// the current process, but a journal replay on the next startup
/// requeues interrupted jobs (from their last checkpoint when one
/// exists) — cancelled jobs stay cancelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
    Interrupted,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Interrupted => "interrupted",
        }
    }

    /// Inverse of [`JobState::as_str`] (journal replay).
    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            "interrupted" => JobState::Interrupted,
            other => anyhow::bail!("unknown job state '{other}'"),
        })
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Done | JobState::Failed | JobState::Cancelled | JobState::Interrupted
        )
    }
}

/// A registered cluster agent as the coordinator sees it: `Idle`
/// (no assigned jobs) or `Busy` (≥ 1 assigned, possibly below
/// capacity). There is deliberately no "lost" state — an agent whose
/// lease expires leaves the table entirely and its jobs requeue, so a
/// listed agent is always one the dispatcher would hand work to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentState {
    Idle,
    Busy,
}

impl AgentState {
    pub fn as_str(&self) -> &'static str {
        match self {
            AgentState::Idle => "idle",
            AgentState::Busy => "busy",
        }
    }

    /// Inverse of [`AgentState::as_str`].
    pub fn parse(s: &str) -> Result<AgentState> {
        Ok(match s {
            "idle" => AgentState::Idle,
            "busy" => AgentState::Busy,
            other => anyhow::bail!("unknown agent state '{other}'"),
        })
    }
}

/// The structured error body every non-2xx response carries.
pub fn error_json(msg: &str) -> Value {
    Value::obj(vec![("error", Value::str(msg))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::coordinator::Method;
    use crate::util::json;

    #[test]
    fn spec_roundtrips_through_json() {
        let v = json::parse(
            r#"{"name": "night-ft", "priority": 3, "model": "lenet",
                "dataset": "fashion", "method": "cls2", "precision": "int8*",
                "epochs": 4, "batch": 16, "seed": 9, "train_n": 128, "test_n": 64,
                "ncls": 10, "verbose": true}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.name, "night-ft");
        assert_eq!(spec.priority, 3);
        assert_eq!(spec.config.method, Method::CLS2);
        assert_eq!(spec.config.precision, Precision::Int8Star);
        assert_eq!(spec.config.epochs, 4);

        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.priority, spec.priority);
        assert_eq!(back.config.method, spec.config.method);
        assert_eq!(back.config.precision, spec.config.precision);
        assert_eq!(back.config.train_n, spec.config.train_n);
        assert_eq!(back.config.ncls, spec.config.ncls);
        assert_eq!(back.config.verbose, spec.config.verbose);
    }

    #[test]
    fn train_spec_roundtrips_through_protocol() {
        // the unified TrainSpec survives JobSpec -> JSON -> JobSpec for
        // every precision (including the int8 knobs and grad_mode token)
        for precision in ["fp32", "int8", "int8*"] {
            let mut cfg = Config::default();
            cfg.set("precision", precision).unwrap();
            cfg.set("method", "cls2").unwrap();
            cfg.set("epochs", "6").unwrap();
            cfg.set("r_max", "31").unwrap();
            cfg.set("eval_every", "2").unwrap();
            cfg.validate().unwrap();
            let spec = JobSpec::new(cfg);
            let wire = spec.to_json();
            if precision == "int8*" {
                assert_eq!(wire.get("precision").as_str(), Some("int8*"));
                assert_eq!(
                    wire.get("grad_mode").as_str(),
                    Some(crate::coordinator::ZoGradMode::IntCE.token())
                );
            }
            let back = JobSpec::from_json(&wire).unwrap();
            assert_eq!(
                back.config.train_spec().to_json(),
                spec.config.train_spec().to_json(),
                "{precision}: TrainSpec must round-trip through the protocol"
            );
        }
    }

    #[test]
    fn grad_mode_refines_or_conflicts_like_train_spec() {
        for bad in [
            // grad_mode on a fp32 spec
            r#"{"precision": "fp32", "grad_mode": "int"}"#,
            // float-CE token on the int-CE precision: a true conflict
            r#"{"precision": "int8*", "grad_mode": "float"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v).is_err(), "should reject {bad}");
        }
        // a consistent grad_mode is accepted, and an "int" token refines
        // a plain int8 precision — the same rule TrainSpec::from_json
        // applies, so the two parsers agree on identical bytes
        for refined in [
            r#"{"precision": "int8*", "grad_mode": "int"}"#,
            r#"{"precision": "int8", "grad_mode": "int"}"#,
        ] {
            let v = json::parse(refined).unwrap();
            assert_eq!(
                JobSpec::from_json(&v).unwrap().config.precision,
                Precision::Int8Star,
                "{refined}"
            );
            let spec = crate::coordinator::TrainSpec::from_json(&v).unwrap();
            assert_eq!(spec.precision.token(), "int8*", "{refined}");
        }
    }

    #[test]
    fn invalid_specs_rejected() {
        for bad in [
            r#"[1, 2]"#,
            r#"{"model": "resnet"}"#,
            r#"{"optimzer": "adam"}"#,
            r#"{"epochs": 0}"#,
            r#"{"model": "pointnet", "precision": "int8"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn agent_states_roundtrip() {
        for s in [AgentState::Idle, AgentState::Busy] {
            assert_eq!(AgentState::parse(s.as_str()).unwrap(), s);
        }
        assert!(AgentState::parse("lost").is_err());
    }

    #[test]
    fn job_states() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert!(JobState::Interrupted.is_terminal());
        assert_eq!(JobState::Failed.as_str(), "failed");
        for s in [
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Failed,
            JobState::Cancelled,
            JobState::Interrupted,
        ] {
            assert_eq!(JobState::parse(s.as_str()).unwrap(), s);
        }
        assert!(JobState::parse("paused").is_err());
    }

    #[test]
    fn dp_roundtrips_through_job_spec() {
        // nested object form (what to_json emits)
        let v = json::parse(
            r#"{"method": "full-zo", "engine": "native",
                "dp": {"replicas": 4, "aggregate": "sum", "min_replicas": 2}}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.config.dp_replicas, 4);
        assert_eq!(spec.config.dp_min_replicas, 2);
        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.config.dp_spec(), spec.config.dp_spec());

        // flat CLI form: "dp": N
        let v = json::parse(r#"{"method": "full-zo", "engine": "native", "dp": 2}"#).unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().config.dp_replicas, 2);

        // non-dp specs don't grow a dp key
        assert_eq!(JobSpec::new(Config::default()).to_json().get("dp"), &Value::Null);

        // dp validation still applies at submit time
        let v = json::parse(r#"{"method": "cls1", "engine": "native", "dp": 2}"#).unwrap();
        assert!(JobSpec::from_json(&v).is_err());
    }

    #[test]
    fn checkpoint_keys_roundtrip_through_job_spec() {
        let v = json::parse(
            r#"{"method": "cls1", "engine": "native", "epochs": 3,
                "save": "/tmp/j.ckpt", "ckpt_every": 2, "ckpt_keep": 4,
                "resume": "/tmp/j.ckpt"}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.config.save_checkpoint.as_deref(), Some("/tmp/j.ckpt"));
        assert_eq!(spec.config.resume.as_deref(), Some("/tmp/j.ckpt"));
        assert_eq!(spec.config.ckpt_every, 2);
        assert_eq!(spec.config.ckpt_keep, 4);
        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.config.resume, spec.config.resume);
        assert_eq!(back.config.ckpt_every, spec.config.ckpt_every);
        assert_eq!(back.config.ckpt_keep, spec.config.ckpt_keep);
        assert_eq!(
            back.config.train_spec().to_json(),
            spec.config.train_spec().to_json()
        );
    }
}
