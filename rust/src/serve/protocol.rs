//! JSON wire types for the job server — in the spirit of the in-tree
//! `util::json` substrate: no serde, hand-rolled (de)serialization.
//!
//! A job spec is a flat JSON object. Two keys are server-level
//! (`name`, `priority`); every other key is a training-config key with
//! exactly the `repro train` semantics (`model`, `dataset`, `method`,
//! `precision`, `engine`, `epochs`, `batch`, `lr`, `eps`, `seed`,
//! `r_max`, `b_zo`, `train_n`, `test_n`, `npoints`, `save`, `load`, …),
//! so everything the CLI can run, the server can schedule.

use crate::config::{scalar_to_string, Config};
use crate::util::json::Value;
use anyhow::{Context, Result};

/// Default TCP port of `repro serve`.
pub const DEFAULT_PORT: u16 = 8377;

/// One schedulable training job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Optional human label, echoed back in listings.
    pub name: String,
    /// Higher runs first; FIFO within a priority level. Default 0.
    pub priority: i64,
    /// The full training configuration (validated at submit time).
    pub config: Config,
}

impl JobSpec {
    pub fn new(config: Config) -> JobSpec {
        JobSpec { name: String::new(), priority: 0, config }
    }

    /// Parse a submit body. Unknown keys and invalid combinations are
    /// rejected with context (surfaced to the client as a 400).
    pub fn from_json(v: &Value) -> Result<JobSpec> {
        let obj = v.as_obj().context("job spec must be a JSON object")?;
        let mut spec = JobSpec::new(Config::default());
        for (k, val) in obj {
            match k.as_str() {
                "name" => spec.name = val.as_str().context("name must be a string")?.to_string(),
                "priority" => {
                    spec.priority = val.as_i64().context("priority must be a number")?
                }
                key => {
                    let s = scalar_to_string(val)
                        .with_context(|| format!("job spec key '{key}'"))?;
                    spec.config.set(key, &s)?;
                }
            }
        }
        spec.config.validate()?;
        Ok(spec)
    }

    /// Serialize back to the same flat shape `from_json` accepts.
    pub fn to_json(&self) -> Value {
        let c = &self.config;
        let mut pairs = vec![
            ("name", Value::str(self.name.clone())),
            ("priority", Value::num(self.priority as f64)),
            ("model", Value::str(c.model.clone())),
            ("dataset", Value::str(c.dataset.token())),
            ("method", Value::str(c.method.token())),
            ("precision", Value::str(c.precision.token())),
            ("engine", Value::str(c.engine.token())),
            ("epochs", Value::num(c.epochs as f64)),
            ("batch", Value::num(c.batch as f64)),
            ("lr", Value::num(c.lr as f64)),
            ("eps", Value::num(c.eps as f64)),
            ("g_clip", Value::num(c.g_clip as f64)),
            ("r_max", Value::num(c.r_max as f64)),
            ("b_zo", Value::num(c.b_zo as f64)),
            ("seed", Value::num(c.seed as f64)),
            ("train_n", Value::num(c.train_n as f64)),
            ("test_n", Value::num(c.test_n as f64)),
            ("npoints", Value::num(c.npoints as f64)),
            ("ncls", Value::num(c.ncls as f64)),
            ("verbose", Value::Bool(c.verbose)),
        ];
        if let Some(p) = &c.artifacts_dir {
            pairs.push(("artifacts", Value::str(p.clone())));
        }
        if let Some(p) = &c.load_checkpoint {
            pairs.push(("load", Value::str(p.clone())));
        }
        if let Some(p) = &c.save_checkpoint {
            pairs.push(("save", Value::str(p.clone())));
        }
        Value::obj(pairs)
    }
}

/// Job lifecycle: Queued → Running → Done | Failed | Cancelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// The structured error body every non-2xx response carries.
pub fn error_json(msg: &str) -> Value {
    Value::obj(vec![("error", Value::str(msg))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::coordinator::Method;
    use crate::util::json;

    #[test]
    fn spec_roundtrips_through_json() {
        let v = json::parse(
            r#"{"name": "night-ft", "priority": 3, "model": "lenet",
                "dataset": "fashion", "method": "cls2", "precision": "int8*",
                "epochs": 4, "batch": 16, "seed": 9, "train_n": 128, "test_n": 64,
                "ncls": 10, "verbose": true}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.name, "night-ft");
        assert_eq!(spec.priority, 3);
        assert_eq!(spec.config.method, Method::Cls2);
        assert_eq!(spec.config.precision, Precision::Int8Star);
        assert_eq!(spec.config.epochs, 4);

        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.priority, spec.priority);
        assert_eq!(back.config.method, spec.config.method);
        assert_eq!(back.config.precision, spec.config.precision);
        assert_eq!(back.config.train_n, spec.config.train_n);
        assert_eq!(back.config.ncls, spec.config.ncls);
        assert_eq!(back.config.verbose, spec.config.verbose);
    }

    #[test]
    fn invalid_specs_rejected() {
        for bad in [
            r#"[1, 2]"#,
            r#"{"model": "resnet"}"#,
            r#"{"optimzer": "adam"}"#,
            r#"{"epochs": 0}"#,
            r#"{"model": "pointnet", "precision": "int8"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn job_states() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert_eq!(JobState::Failed.as_str(), "failed");
    }
}
