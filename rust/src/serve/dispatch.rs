//! The cluster dispatcher: the coordinator side of multi-node
//! sharding. Remote worker agents (`repro agent`) register here, then
//! pull work — every poll renews the agent's lease, hands back queued
//! jobs up to the agent's free capacity (serialized `JobSpec` on the
//! wire) and relays stop requests for jobs the user cancelled or the
//! server is shutting down. Per-epoch progress and terminal outcomes
//! are POSTed back and land in the same registry/journal as local
//! worker runs, so `GET /jobs`, `GET /stats` and the restart replay
//! are agent-agnostic.
//!
//! Because remote reports land in the shared registry, they also land
//! on its live-telemetry event bus (`serve::events`): an epoch POSTed
//! by an agent, a reaper requeue, a remote job's terminal outcome all
//! stream to `GET /events` / `GET /jobs/{id}/events` subscribers
//! exactly like local-worker activity — `repro watch` cannot tell
//! where a job runs.
//!
//! # Leases
//!
//! Polling is the heartbeat (deliberately: epoch reports do NOT renew
//! the lease, so a wedged agent that still streams progress from an
//! old run cannot hold jobs hostage). A background reaper declares any
//! agent that has not polled within `lease_ms` lost, removes it, and
//! requeues its assigned jobs through the exact interrupted-requeue
//! rule journal replay uses ([`super::journal::arm_resume`]): resume
//! armed from the job's last spec-matching checkpoint, history trimmed
//! to the snapshot, from-scratch rerun otherwise. Requeues re-enter
//! the queue through the capacity-bypassing `push_admitted` — a lost
//! agent must never translate into destroyed jobs.
//!
//! A report that arrives for a job the reaper already requeued gets a
//! 409 (stale assignment) and changes nothing; an agent whose poll
//! answers 404 knows it was presumed dead and re-registers fresh.

use super::dp::DpCoordinator;
use super::protocol::{error_json, AgentState, JobSpec};
use super::queue::JobQueue;
use super::registry::{JobOutcome, JobRegistry};
use crate::coordinator::metrics::EpochStats;
use crate::telemetry::PhaseTimer;
use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cluster-side knobs of `repro serve --cluster`.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Lease duration in milliseconds: an agent that has not polled
    /// for this long is declared lost and its jobs requeue from their
    /// last checkpoint.
    pub lease_ms: u64,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions { lease_ms: 10_000 }
    }
}

struct AgentRec {
    id: u64,
    name: String,
    capacity: usize,
    /// Device memory budget in bytes, as reported at registration.
    /// Drives the elastic-boundary negotiation: an elastic job assigned
    /// to this agent gets the deepest BP tail whose modeled footprint
    /// (paper Eqs. 2–5 / 13–15) fits. `None` = unconstrained.
    mem_budget: Option<usize>,
    /// Job ids currently assigned to (running on) this agent.
    assigned: Vec<u64>,
    last_seen: Instant,
    jobs_done: u64,
}

struct DispatchInner {
    agents: BTreeMap<u64, AgentRec>,
    next_agent: u64,
}

/// Agent table + assignment logic + the lease reaper. One per
/// cluster-enabled server, shared with every connection handler.
pub struct Dispatcher {
    opts: ClusterOptions,
    queue: Arc<JobQueue>,
    registry: Arc<JobRegistry>,
    /// Shard leases + step barriers of data-parallel runs (the
    /// `/cluster/dp/*` wire). Lives here because dp membership rides
    /// on the same agent table, leases and reaper as whole-job
    /// assignments.
    pub dp: DpCoordinator,
    inner: Mutex<DispatchInner>,
    stop: AtomicBool,
    reaper: Mutex<Option<JoinHandle<()>>>,
}

impl Dispatcher {
    /// Build the dispatcher and start its lease reaper thread. The
    /// reaper holds only a `Weak` reference, so a dispatcher whose
    /// server is dropped without a clean [`Dispatcher::shutdown`]
    /// (e.g. bound but never run) is still freed — the thread notices
    /// the dead upgrade within one tick and exits on its own.
    pub fn spawn(
        opts: ClusterOptions,
        queue: Arc<JobQueue>,
        registry: Arc<JobRegistry>,
    ) -> Arc<Dispatcher> {
        let tick = Duration::from_millis((opts.lease_ms / 4).clamp(25, 250));
        // never-owned dp shards stay reserved for fresh agents for half
        // a lease (capped at 2s) before members may absorb them
        let grace = Duration::from_millis((opts.lease_ms / 2).min(2_000));
        let d = Arc::new(Dispatcher {
            dp: DpCoordinator::new(registry.clone(), grace),
            opts,
            queue,
            registry,
            inner: Mutex::new(DispatchInner { agents: BTreeMap::new(), next_agent: 1 }),
            stop: AtomicBool::new(false),
            reaper: Mutex::new(None),
        });
        let weak = Arc::downgrade(&d);
        let h = std::thread::Builder::new()
            .name("serve-lease-reaper".into())
            .spawn(move || loop {
                let Some(d) = weak.upgrade() else { return };
                if d.stop.load(Ordering::SeqCst) {
                    return;
                }
                d.reap_expired();
                d.dp.tick();
                drop(d);
                std::thread::sleep(tick);
            })
            .expect("spawning lease reaper");
        *d.reaper.lock().unwrap_or_else(PoisonError::into_inner) = Some(h);
        d
    }

    fn lock(&self) -> MutexGuard<'_, DispatchInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn agent_count(&self) -> usize {
        self.lock().agents.len()
    }

    /// `POST /cluster/register` — admit a new agent; body
    /// `{"name": S?, "capacity": N?, "mem_budget": BYTES?}` (capacity
    /// defaults to 1; a missing/zero budget means unconstrained).
    pub fn register(&self, body: &[u8]) -> (u16, Value) {
        let v = match parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let name = v.get("name").as_str().unwrap_or("").to_string();
        let capacity = v.get("capacity").as_usize().unwrap_or(1).max(1);
        let mem_budget = v.get("mem_budget").as_usize().filter(|&b| b > 0);
        let id = {
            let mut inner = self.lock();
            let id = inner.next_agent;
            inner.next_agent += 1;
            inner.agents.insert(
                id,
                AgentRec {
                    id,
                    name,
                    capacity,
                    mem_budget,
                    assigned: Vec::new(),
                    last_seen: Instant::now(),
                    jobs_done: 0,
                },
            );
            id
        };
        (
            200,
            Value::obj(vec![
                ("agent", Value::num(id as f64)),
                ("lease_ms", Value::num(self.opts.lease_ms as f64)),
            ]),
        )
    }

    /// `POST /cluster/agents/{id}/poll` — heartbeat + work pull.
    /// Renews the lease, then answers with jobs to start (up to the
    /// agent's free capacity) and running jobs to stop.
    ///
    /// The body's optional `"running": [ids]` is the assignment ack:
    /// the agent's poll loop is sequential, so every assignment it
    /// ever received is either in that set or already done-reported.
    /// An assigned job missing from it was handed out in a poll
    /// response that never arrived — the dispatcher takes it back and
    /// requeues it, closing the lost-response liveness hole (without
    /// the ack, such a job would stay Running forever on an agent
    /// that keeps renewing its lease but never learned of the job).
    /// Polls without the key (e.g. manual curl) skip reconciliation.
    pub fn poll(&self, agent: u64, body: &[u8]) -> (u16, Value) {
        let v = match parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let reported: Option<Vec<u64>> = v
            .get("running")
            .as_arr()
            .map(|arr| arr.iter().filter_map(|x| x.as_f64().map(|n| n as u64)).collect());
        let mut lost: Vec<u64> = Vec::new();
        let (capacity, assigned) = {
            let mut inner = self.lock();
            let Some(a) = inner.agents.get_mut(&agent) else {
                return unknown_agent();
            };
            a.last_seen = Instant::now();
            if let Some(run) = &reported {
                let (keep, gone): (Vec<u64>, Vec<u64>) =
                    a.assigned.iter().copied().partition(|j| run.contains(j));
                a.assigned = keep;
                lost = gone;
            }
            (a.capacity, a.assigned.clone())
        };
        // requeue lost assignments before handing out work, so the
        // freed slots (and even the lost jobs themselves) are
        // available to this very poll
        self.requeue_all(agent, &lost);
        // stop fan-out: cancelled (or shutdown-stopped) running jobs
        let stop: Vec<Value> = assigned
            .iter()
            .filter(|&&id| self.registry.stop_requested(id))
            .map(|&id| Value::num(id as f64))
            .collect();
        // hand out queued work up to the agent's free slots
        let mut assign = Vec::new();
        let mut nassigned = assigned.len();
        while nassigned < capacity {
            let Some(id) = self.queue.try_pop() else { break };
            // a dp job is adopted by the dp coordinator instead of
            // assigned wholesale: its shards go out through the offer
            // pass below (this poll included)
            if let Some(dp) = self.registry.dp_of(id) {
                if let Some(spec) = self.registry.claim_for_dp(id) {
                    self.dp.adopt(id, spec, dp);
                }
                continue;
            }
            // a pop that fails to claim was cancelled while queued
            let Some(mut spec) = self.registry.claim_for_agent(id, agent) else { continue };
            // boundary negotiation: an elastic job lands at the deepest
            // BP tail the agent's memory budget fits (unconstrained
            // agents get the range's deepest); the chosen k is pinned
            // into the registry's spec so failover and resume replay it
            if let Some(pinned) = self.negotiate_boundary(id, agent, &spec) {
                spec = pinned;
            }
            {
                let mut inner = self.lock();
                match inner.agents.get_mut(&agent) {
                    Some(a) => a.assigned.push(id),
                    None => {
                        // reaped between locks: hand the job straight back
                        drop(inner);
                        if let Some(p) = self.registry.requeue_interrupted(id) {
                            let _ = self.queue.push_admitted(id, p);
                        }
                        return unknown_agent();
                    }
                }
            }
            assign.push(Value::obj(vec![
                ("id", Value::num(id as f64)),
                ("spec", spec.to_json()),
            ]));
            nassigned += 1;
        }
        // dp shard offers: live runs this agent is not yet a member of
        // lease one shard each into the remaining free slots
        for (id, shard, spec) in self.dp.offer(agent, capacity.saturating_sub(nassigned)) {
            {
                let mut inner = self.lock();
                match inner.agents.get_mut(&agent) {
                    Some(a) => {
                        if !a.assigned.contains(&id) {
                            a.assigned.push(id);
                        }
                    }
                    None => {
                        // reaped between locks: give the shard back
                        drop(inner);
                        self.dp.agent_lost(id, agent);
                        return unknown_agent();
                    }
                }
            }
            assign.push(Value::obj(vec![
                ("id", Value::num(id as f64)),
                ("spec", spec.to_json()),
                ("dp", Value::obj(vec![("shard", Value::num(shard as f64))])),
            ]));
        }
        (
            200,
            Value::obj(vec![
                ("agent", Value::num(agent as f64)),
                ("assign", Value::Arr(assign)),
                ("stop", Value::Arr(stop)),
            ]),
        )
    }

    /// Evaluate the elastic-boundary negotiation for a just-claimed
    /// job: pick the deepest BP tail in the job's elastic range whose
    /// analytic memory total fits the agent's budget (the same
    /// [`elastic::candidate_rows`] table `repro train --mem-report`
    /// prints), pin it into the registry's stored spec, and return the
    /// pinned spec for the wire. `None` = nothing to pin (fixed
    /// boundary, dp job, k unchanged, or a racing requeue).
    fn negotiate_boundary(
        &self,
        id: u64,
        agent: u64,
        spec: &super::protocol::JobSpec,
    ) -> Option<super::protocol::JobSpec> {
        use crate::coordinator::elastic;
        let cfg = &spec.config;
        let es = cfg.effective_elastic().ok().flatten()?;
        if cfg.dp_replicas > 0 {
            return None;
        }
        let budget = {
            let inner = self.lock();
            inner.agents.get(&agent)?.mem_budget
        };
        let int8 = cfg.precision != crate::config::Precision::Fp32;
        let k = match budget {
            Some(b) => elastic::negotiate_k(cfg.model_enum(), cfg.batch, int8, b, es.min, es.max),
            None => es.max.min(cfg.model_enum().max_bp_tail()),
        };
        if cfg.method.bp_tail() == Some(k) {
            return None;
        }
        self.registry.pin_boundary(id, agent, k)
    }

    /// `POST /cluster/agents/{id}/jobs/{job}/epoch` — per-epoch
    /// progress from a remote run; lands in the registry (and journal)
    /// exactly like a local worker's `ProgressSink` callback. Does NOT
    /// renew the lease (see the module docs).
    pub fn report_epoch(&self, agent: u64, job: u64, body: &[u8]) -> (u16, Value) {
        let v = match parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        {
            let inner = self.lock();
            let Some(a) = inner.agents.get(&agent) else {
                return unknown_agent();
            };
            if !a.assigned.contains(&job) {
                return stale_assignment();
            }
        }
        match EpochStats::from_json(&v) {
            Ok(stats) => {
                // the registry re-checks ownership under its own lock,
                // closing the window between our assignment check and
                // this call (reap + re-claim by a successor)
                self.registry.record_epoch_from_agent(job, agent, stats);
                (200, Value::obj(vec![("ok", Value::Bool(true))]))
            }
            Err(e) => (400, error_json(&format!("invalid epoch stats: {e:#}"))),
        }
    }

    /// `POST /cluster/agents/{id}/jobs/{job}/done` — terminal outcome
    /// of a remote run: `{"stopped": bool, "best_test_acc": F}` or
    /// `{"error": S}`. Frees the agent's slot and completes the job in
    /// the registry.
    pub fn report_done(&self, agent: u64, job: u64, body: &[u8]) -> (u16, Value) {
        let v = match parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        {
            let mut inner = self.lock();
            let Some(a) = inner.agents.get_mut(&agent) else {
                return unknown_agent();
            };
            let Some(pos) = a.assigned.iter().position(|&j| j == job) else {
                return stale_assignment();
            };
            a.assigned.remove(pos);
            a.jobs_done += 1;
        }
        match v.get("error").as_str() {
            Some(msg) => self.registry.fail(job, msg.to_string()),
            None => {
                let stopped = v.get("stopped").as_bool().unwrap_or(false);
                let best = v.get("best_test_acc").as_f64().unwrap_or(0.0) as f32;
                // the run's phase breakdown already arrived with each
                // epoch report (EpochStats.phases) and was merged at
                // record time, so no timer rides on the done message
                self.registry.complete(
                    job,
                    JobOutcome { best_test_acc: best, timer: PhaseTimer::new(), stopped },
                );
            }
        }
        let state = self
            .registry
            .state_of(job)
            .map(|s| s.as_str())
            .unwrap_or("unknown");
        (
            200,
            Value::obj(vec![("ok", Value::Bool(true)), ("state", Value::str(state))]),
        )
    }

    /// `POST /cluster/agents/{id}/deregister` — graceful leave: the
    /// agent's assigned jobs requeue immediately (same path as lease
    /// expiry, without waiting out the lease).
    pub fn deregister(&self, agent: u64) -> (u16, Value) {
        let assigned = {
            let mut inner = self.lock();
            match inner.agents.remove(&agent) {
                Some(a) => a.assigned,
                None => return unknown_agent(),
            }
        };
        let requeued = self.requeue_all(agent, &assigned);
        (
            200,
            Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("requeued", Value::num(requeued as f64)),
            ]),
        )
    }

    /// `GET /cluster/agents` — observability listing.
    pub fn agents_json(&self) -> Value {
        let inner = self.lock();
        Value::obj(vec![(
            "agents",
            Value::Arr(
                inner
                    .agents
                    .values()
                    .map(|a| {
                        let state = if a.assigned.is_empty() {
                            AgentState::Idle
                        } else {
                            AgentState::Busy
                        };
                        let mut pairs = vec![
                            ("agent", Value::num(a.id as f64)),
                            ("name", Value::str(a.name.clone())),
                            ("state", Value::str(state.as_str())),
                            ("capacity", Value::num(a.capacity as f64)),
                        ];
                        if let Some(b) = a.mem_budget {
                            pairs.push(("mem_budget", Value::num(b as f64)));
                        }
                        pairs.extend([
                            (
                                "running",
                                Value::Arr(
                                    a.assigned.iter().map(|&j| Value::num(j as f64)).collect(),
                                ),
                            ),
                            ("jobs_done", Value::num(a.jobs_done as f64)),
                            (
                                "seen_ms_ago",
                                Value::num(a.last_seen.elapsed().as_millis() as f64),
                            ),
                        ]);
                        Value::obj(pairs)
                    })
                    .collect(),
            ),
        )])
    }

    /// One reaper tick: agents past their lease are removed and their
    /// jobs requeued from their last checkpoint.
    fn reap_expired(&self) {
        let lease = Duration::from_millis(self.opts.lease_ms);
        let expired: Vec<(u64, Vec<u64>)> = {
            let mut inner = self.lock();
            let dead: Vec<u64> = inner
                .agents
                .values()
                .filter(|a| a.last_seen.elapsed() > lease)
                .map(|a| a.id)
                .collect();
            dead.into_iter()
                .filter_map(|id| inner.agents.remove(&id).map(|a| (id, a.assigned)))
                .collect()
        };
        for (id, jobs) in expired {
            let n = self.requeue_all(id, &jobs);
            eprintln!(
                "serve: agent {id} lease expired ({} ms); requeued {n} job(s)",
                self.opts.lease_ms
            );
        }
    }

    /// Hand a vanished agent's jobs back: dp shards return to their
    /// run's free pool (the surviving quorum absorbs them), whole-job
    /// assignments requeue from their last checkpoint.
    fn requeue_all(&self, agent: u64, jobs: &[u64]) -> usize {
        let mut n = 0;
        for &id in jobs {
            if self.dp.agent_lost(id, agent) {
                continue;
            }
            if let Some(priority) = self.registry.requeue_interrupted(id) {
                if self.queue.push_admitted(id, priority) {
                    n += 1;
                }
            }
        }
        if n > 0 {
            crate::metrics::global()
                .counter(
                    "repro_agent_requeues_total",
                    "Jobs requeued off vanished agents (lease expiry, deregister, lost-ack reconcile)",
                    &[],
                )
                .add(n as u64);
        }
        n
    }

    /// Stop the reaper and complete every remotely-running job as
    /// interrupted: the server is shutting down and agents can no
    /// longer report in, but `stop_all_running` has already marked the
    /// jobs, so completing them here makes the journal's compaction
    /// record the terminal state the next boot requeues from.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.reaper.lock().unwrap_or_else(PoisonError::into_inner).take() {
            let _ = h.join();
        }
        let assigned: Vec<u64> = {
            let inner = self.lock();
            inner.agents.values().flat_map(|a| a.assigned.iter().copied()).collect()
        };
        // dp runs complete themselves (once each); finished dp ids may
        // still linger in assignment lists until the next poll, so skip
        // anything already terminal rather than clobbering its state
        let dp_live = self.dp.shutdown();
        for id in assigned {
            if dp_live.contains(&id)
                || self.registry.state_of(id).is_some_and(|s| s.is_terminal())
            {
                continue;
            }
            self.registry.complete(
                id,
                JobOutcome { best_test_acc: 0.0, timer: PhaseTimer::new(), stopped: true },
            );
        }
    }
}

fn unknown_agent() -> (u16, Value) {
    (404, error_json("unknown agent (lease expired? re-register)"))
}

fn stale_assignment() -> (u16, Value) {
    (409, error_json("stale assignment (the job was requeued)"))
}

pub(crate) fn parse_body(body: &[u8]) -> Result<Value, (u16, Value)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, error_json("body must be utf-8 JSON")))?;
    if text.trim().is_empty() {
        return Ok(Value::obj(vec![]));
    }
    json::parse(text).map_err(|e| (400, error_json(&format!("invalid JSON: {e}"))))
}

/// Wire helper for the agent side: the spec a poll assignment carries.
pub(crate) fn assignment_spec(assignment: &Value) -> anyhow::Result<(u64, JobSpec)> {
    let id = assignment
        .get("id")
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("assignment missing job id"))? as u64;
    let spec = JobSpec::from_json(assignment.get("spec"))?;
    Ok((id, spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::serve::protocol::JobState;

    fn parts() -> (Arc<JobQueue>, Arc<JobRegistry>) {
        (Arc::new(JobQueue::new(8)), Arc::new(JobRegistry::new()))
    }

    fn queued_job(queue: &JobQueue, registry: &JobRegistry) -> u64 {
        let id = registry.add(JobSpec::new(Config::default()));
        queue.push(id, 0).unwrap();
        id
    }

    #[test]
    fn register_poll_assign_report() {
        let (queue, registry) = parts();
        let d = Dispatcher::spawn(ClusterOptions::default(), queue.clone(), registry.clone());
        let (status, v) = d.register(br#"{"name": "edge-1", "capacity": 2}"#);
        assert_eq!(status, 200);
        let agent = v.get("agent").as_f64().unwrap() as u64;
        assert!(v.get("lease_ms").as_f64().unwrap() > 0.0);
        assert_eq!(d.agent_count(), 1);

        let j1 = queued_job(&queue, &registry);
        let j2 = queued_job(&queue, &registry);
        let j3 = queued_job(&queue, &registry);
        let (status, v) = d.poll(agent, b"{}");
        assert_eq!(status, 200);
        let assign = v.get("assign").as_arr().unwrap();
        assert_eq!(assign.len(), 2, "capacity 2 caps the hand-out");
        let (aid, spec) = assignment_spec(&assign[0]).unwrap();
        assert_eq!(aid, j1);
        assert_eq!(spec.config.epochs, Config::default().epochs);
        assert_eq!(registry.state_of(j1), Some(JobState::Running));
        assert_eq!(registry.state_of(j3), Some(JobState::Queued));

        // epoch + done reports flow into the registry; the freed slot
        // picks up the remaining job on the next poll
        let stats = EpochStats { epoch: 0, test_acc: 0.5, ..Default::default() };
        let (status, _) = d.report_epoch(agent, j1, json::to_string(&stats.to_json()).as_bytes());
        assert_eq!(status, 200);
        let body = br#"{"stopped": false, "best_test_acc": 0.5}"#;
        let (status, v) = d.report_done(agent, j1, body);
        assert_eq!(status, 200);
        assert_eq!(v.get("state").as_str(), Some("done"));
        assert_eq!(registry.state_of(j1), Some(JobState::Done));
        let (_, v) = d.poll(agent, b"{}");
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 1);

        // reports against a job the agent does not hold are stale
        let (status, _) = d.report_done(agent, j1, body);
        assert_eq!(status, 409);
        // unknown agents 404 everywhere
        assert_eq!(d.poll(999, b"{}").0, 404);
        assert_eq!(d.report_epoch(999, j2, b"{}").0, 404);
        d.shutdown();
    }

    #[test]
    fn cancel_fans_out_through_poll_and_failed_jobs_record_errors() {
        let (queue, registry) = parts();
        let d = Dispatcher::spawn(ClusterOptions::default(), queue.clone(), registry.clone());
        let (_, v) = d.register(b"{}");
        let agent = v.get("agent").as_f64().unwrap() as u64;
        let job = queued_job(&queue, &registry);
        let (_, v) = d.poll(agent, b"{}");
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 1);

        registry.cancel(job).unwrap();
        let (_, v) = d.poll(agent, b"{}");
        let stop = v.get("stop").as_arr().unwrap();
        assert_eq!(stop.len(), 1, "the cancel must reach the agent");
        assert_eq!(stop[0].as_f64().unwrap() as u64, job);
        d.report_done(agent, job, br#"{"stopped": true}"#);
        assert_eq!(registry.state_of(job), Some(JobState::Cancelled));

        // an error outcome lands as Failed with the message recorded
        let job2 = queued_job(&queue, &registry);
        d.poll(agent, b"{}");
        d.report_done(agent, job2, br#"{"error": "engine exploded"}"#);
        assert_eq!(registry.state_of(job2), Some(JobState::Failed));
        let detail = registry.job_json(job2).unwrap();
        assert_eq!(detail.get("error").as_str(), Some("engine exploded"));
        d.shutdown();
    }

    #[test]
    fn lost_assignment_is_reconciled_on_the_next_poll() {
        let (queue, registry) = parts();
        let d = Dispatcher::spawn(ClusterOptions::default(), queue.clone(), registry.clone());
        let (_, v) = d.register(b"{}");
        let agent = v.get("agent").as_f64().unwrap() as u64;
        let job = queued_job(&queue, &registry);

        // the assignment goes out…
        let (_, v) = d.poll(agent, br#"{"running": []}"#);
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 1);
        assert_eq!(registry.state_of(job), Some(JobState::Running));

        // …but the response never reached the agent: its next poll
        // still reports nothing running, so the dispatcher takes the
        // job back — and can hand it out again in the same answer
        let (_, v) = d.poll(agent, br#"{"running": []}"#);
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 1);
        assert_eq!(registry.state_of(job), Some(JobState::Running));

        // once the agent acks the job, polls leave it alone
        let ack = format!(r#"{{"running": [{job}]}}"#);
        let (_, v) = d.poll(agent, ack.as_bytes());
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 0);
        assert_eq!(registry.state_of(job), Some(JobState::Running));
        // a poll WITHOUT the running key must not reconcile (curl)
        let (_, v) = d.poll(agent, b"{}");
        assert_eq!(v.get("assign").as_arr().unwrap().len(), 0);
        assert_eq!(registry.state_of(job), Some(JobState::Running));
        d.shutdown();
    }

    #[test]
    fn lease_expiry_reaps_the_agent_and_requeues_its_jobs() {
        let (queue, registry) = parts();
        let d = Dispatcher::spawn(
            ClusterOptions { lease_ms: 120 },
            queue.clone(),
            registry.clone(),
        );
        let (_, v) = d.register(br#"{"capacity": 2}"#);
        let agent = v.get("agent").as_f64().unwrap() as u64;
        let j1 = queued_job(&queue, &registry);
        let j2 = queued_job(&queue, &registry);
        d.poll(agent, b"{}");
        assert_eq!(registry.state_of(j1), Some(JobState::Running));
        assert_eq!(queue.len(), 0);

        // the agent goes silent: within a few lease periods both jobs
        // are back on the queue and the agent is gone
        let t0 = Instant::now();
        while (registry.state_of(j1) != Some(JobState::Queued)
            || registry.state_of(j2) != Some(JobState::Queued))
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(registry.state_of(j1), Some(JobState::Queued));
        assert_eq!(registry.state_of(j2), Some(JobState::Queued));
        assert_eq!(queue.len(), 2);
        assert_eq!(d.poll(agent, b"{}").0, 404, "a reaped agent must re-register");
        assert_eq!(d.agent_count(), 0);

        // deregister is the graceful version of the same path
        let (_, v) = d.register(b"{}");
        let agent2 = v.get("agent").as_f64().unwrap() as u64;
        d.poll(agent2, b"{}");
        let (status, v) = d.deregister(agent2);
        assert_eq!(status, 200);
        assert_eq!(v.get("requeued").as_usize(), Some(1));
        assert_eq!(d.agent_count(), 0);
        d.shutdown();
    }
}
