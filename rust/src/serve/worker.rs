//! The worker pool: N OS threads popping jobs off the queue and driving
//! the exact same training path as `repro train` — `launch::run`, which
//! dispatches the job's unified `TrainSpec` into the one
//! `coordinator::session` loop (FP32 over either engine, INT8/INT8*
//! over the NITI path) — with the job's stop flag and a registry-backed
//! progress sink armed on the spec.
//!
//! Durability rides the same path with zero worker-side code: a job
//! whose config sets `save` gets cadence snapshots from inside the
//! session loop, and a requeued-after-restart job arrives with
//! `resume` armed on its config, so `launch::run` restores params +
//! loop state before the first batch.
//!
//! Live telemetry rides it too: the progress sink lands in
//! [`JobRegistry::record_epoch`](super::registry::JobRegistry::record_epoch),
//! which both appends to the job history and broadcasts the epoch on
//! the registry's event bus (`serve::events`) — the publish never
//! blocks, so a slow SSE watcher can never stall a training thread.

use super::queue::JobQueue;
use super::registry::{JobOutcome, JobRegistry};
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::launch;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn exactly `n` workers over a shared queue + registry (0 is
    /// a legal pool for a cluster-only coordinator that runs nothing
    /// locally — `Server::bind` enforces that a non-cluster server has
    /// at least one). Workers exit when the queue is closed.
    pub fn spawn(n: usize, queue: Arc<JobQueue>, registry: Arc<JobRegistry>) -> WorkerPool {
        let handles = (0..n)
            .map(|i| {
                let q = queue.clone();
                let r = registry.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(i, &q, &r))
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Wait for every worker to exit (call after closing the queue).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(idx: usize, queue: &JobQueue, registry: &Arc<JobRegistry>) {
    while let Some(id) = queue.pop() {
        // Claim may fail: the job was cancelled while queued.
        let Some((spec, stop)) = registry.claim(id, idx) else { continue };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            run_job(id, &spec.config, stop, registry)
        }));
        match outcome {
            Ok(Ok(done)) => registry.complete(id, done),
            Ok(Err(e)) => registry.fail(id, format!("{e:#}")),
            Err(_) => registry.fail(id, "worker panicked during training".to_string()),
        }
    }
}

/// Run one job to completion (or cancellation): exactly `launch::run`
/// (the `repro train` path) with the stop flag + progress sink armed.
fn run_job(
    id: u64,
    cfg: &crate::config::Config,
    stop: StopFlag,
    registry: &Arc<JobRegistry>,
) -> Result<JobOutcome> {
    let reg = registry.clone();
    let progress = ProgressSink::new(move |e| reg.record_epoch(id, e.clone()));
    let l = launch::run(cfg, stop, progress)?;
    Ok(JobOutcome {
        best_test_acc: l.result.history.best_test_acc(),
        timer: l.result.timer,
        stopped: l.result.stopped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::serve::protocol::{JobSpec, JobState};
    use std::time::{Duration, Instant};

    fn tiny_spec(precision: &str) -> JobSpec {
        let mut cfg = Config::default();
        cfg.set("engine", "native").unwrap();
        cfg.set("precision", precision).unwrap();
        cfg.set("epochs", "1").unwrap();
        cfg.set("batch", "16").unwrap();
        cfg.set("train_n", "48").unwrap();
        cfg.set("test_n", "32").unwrap();
        cfg.validate().unwrap();
        JobSpec::new(cfg)
    }

    fn wait_terminal(reg: &JobRegistry, id: u64) -> JobState {
        let t0 = Instant::now();
        loop {
            let s = reg.state_of(id).unwrap();
            if s.is_terminal() {
                return s;
            }
            assert!(t0.elapsed() < Duration::from_secs(120), "job {id} stuck in {s:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn pool_runs_fp32_and_int8_jobs_to_done() {
        let queue = Arc::new(JobQueue::new(8));
        let registry = Arc::new(JobRegistry::new());
        let pool = WorkerPool::spawn(2, queue.clone(), registry.clone());
        assert_eq!(pool.len(), 2);

        let a = registry.add(tiny_spec("fp32"));
        let b = registry.add(tiny_spec("int8"));
        queue.push(a, 0).unwrap();
        queue.push(b, 0).unwrap();

        assert_eq!(wait_terminal(&registry, a), JobState::Done);
        assert_eq!(wait_terminal(&registry, b), JobState::Done);
        let ja = registry.job_json(a).unwrap();
        assert_eq!(ja.get("epochs_done").as_usize(), Some(1));

        queue.close();
        pool.join();
    }

    #[test]
    fn cancelled_while_queued_is_skipped() {
        let queue = Arc::new(JobQueue::new(8));
        let registry = Arc::new(JobRegistry::new());
        let id = registry.add(tiny_spec("fp32"));
        registry.cancel(id).unwrap();
        queue.push(id, 0).unwrap(); // worker pops it, claim fails, skips

        let pool = WorkerPool::spawn(1, queue.clone(), registry.clone());
        // the job must stay Cancelled, never flip to Running/Done
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(registry.state_of(id), Some(JobState::Cancelled));
        queue.close();
        pool.join();
    }
}
