//! The worker pool: N OS threads popping jobs off the queue and driving
//! the exact same training paths as `repro train` — FP32 via
//! `trainer::train` over either engine, INT8/INT8* via
//! `int8_trainer::train_int8` — with the job's stop flag and a
//! registry-backed progress sink threaded into the config.

use super::queue::JobQueue;
use super::registry::{JobOutcome, JobRegistry};
use crate::config::Precision;
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::coordinator::int8_trainer::{self, Int8TrainConfig};
use crate::coordinator::{checkpoint, trainer, ParamSet, TrainConfig};
use crate::data;
use crate::exp;
use crate::int8::lenet8;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers over a shared queue + registry. Workers exit
    /// when the queue is closed.
    pub fn spawn(n: usize, queue: Arc<JobQueue>, registry: Arc<JobRegistry>) -> WorkerPool {
        let handles = (0..n.max(1))
            .map(|i| {
                let q = queue.clone();
                let r = registry.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(i, &q, &r))
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Wait for every worker to exit (call after closing the queue).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(idx: usize, queue: &JobQueue, registry: &Arc<JobRegistry>) {
    while let Some(id) = queue.pop() {
        // Claim may fail: the job was cancelled while queued.
        let Some((spec, stop)) = registry.claim(id, idx) else { continue };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            run_job(id, &spec.config, stop, registry)
        }));
        match outcome {
            Ok(Ok(done)) => registry.complete(id, done),
            Ok(Err(e)) => registry.fail(id, format!("{e:#}")),
            Err(_) => registry.fail(id, "worker panicked during training".to_string()),
        }
    }
}

/// Run one job to completion (or cancellation). Mirrors `cmd_train` in
/// `main.rs`, with the stop flag + progress sink armed.
fn run_job(
    id: u64,
    cfg: &crate::config::Config,
    stop: StopFlag,
    registry: &Arc<JobRegistry>,
) -> Result<JobOutcome> {
    let (train_d, test_d) =
        data::generate(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed, cfg.npoints);
    let reg = registry.clone();
    let progress = ProgressSink::new(move |e| reg.record_epoch(id, e.clone()));

    match cfg.precision {
        Precision::Fp32 => {
            let model = cfg.model_enum();
            let mut engine =
                exp::build_engine_at(model, cfg.batch, cfg.engine, cfg.artifacts_dir.as_deref());
            let mut params = ParamSet::init(model, cfg.seed ^ 0xC0FFEE);
            if let Some(path) = &cfg.load_checkpoint {
                checkpoint::load_params(path, &mut params)?;
            }
            let tcfg = TrainConfig {
                method: cfg.method,
                epochs: cfg.epochs,
                batch: cfg.batch,
                lr0: cfg.lr,
                eps: cfg.eps,
                g_clip: cfg.g_clip,
                seed: cfg.seed,
                eval_every: 1,
                verbose: cfg.verbose,
                stop,
                progress,
            };
            let r = trainer::train(engine.as_mut(), &mut params, &train_d, &test_d, &tcfg)?;
            if let (Some(path), false) = (&cfg.save_checkpoint, r.stopped) {
                checkpoint::save_params(path, &params)?;
            }
            Ok(JobOutcome {
                best_test_acc: r.history.best_test_acc(),
                timer: r.timer,
                stopped: r.stopped,
            })
        }
        Precision::Int8 | Precision::Int8Star => {
            let mut ws = lenet8::init_params(cfg.seed ^ 0xC0FFEE, cfg.r_max.max(16));
            if let Some(path) = &cfg.load_checkpoint {
                ws = checkpoint::load_int8(path)?;
            }
            let icfg = Int8TrainConfig {
                method: cfg.method,
                grad_mode: cfg.precision.grad_mode(),
                epochs: cfg.epochs,
                batch: cfg.batch,
                r_max: cfg.r_max,
                b_zo: cfg.b_zo,
                seed: cfg.seed,
                eval_every: 1,
                verbose: cfg.verbose,
                stop,
                progress,
            };
            let r = int8_trainer::train_int8(&mut ws, &train_d, &test_d, &icfg)?;
            if let (Some(path), false) = (&cfg.save_checkpoint, r.stopped) {
                let names: Vec<&str> = lenet8::PARAM_SPECS.iter().map(|(n, _)| *n).collect();
                checkpoint::save_int8(path, &names, &ws)?;
            }
            Ok(JobOutcome {
                best_test_acc: r.history.best_test_acc(),
                timer: r.timer,
                stopped: r.stopped,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::serve::protocol::{JobSpec, JobState};
    use std::time::{Duration, Instant};

    fn tiny_spec(precision: &str) -> JobSpec {
        let mut cfg = Config::default();
        cfg.set("engine", "native").unwrap();
        cfg.set("precision", precision).unwrap();
        cfg.set("epochs", "1").unwrap();
        cfg.set("batch", "16").unwrap();
        cfg.set("train_n", "48").unwrap();
        cfg.set("test_n", "32").unwrap();
        cfg.validate().unwrap();
        JobSpec::new(cfg)
    }

    fn wait_terminal(reg: &JobRegistry, id: u64) -> JobState {
        let t0 = Instant::now();
        loop {
            let s = reg.state_of(id).unwrap();
            if s.is_terminal() {
                return s;
            }
            assert!(t0.elapsed() < Duration::from_secs(120), "job {id} stuck in {s:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn pool_runs_fp32_and_int8_jobs_to_done() {
        let queue = Arc::new(JobQueue::new(8));
        let registry = Arc::new(JobRegistry::new());
        let pool = WorkerPool::spawn(2, queue.clone(), registry.clone());
        assert_eq!(pool.len(), 2);

        let a = registry.add(tiny_spec("fp32"));
        let b = registry.add(tiny_spec("int8"));
        queue.push(a, 0).unwrap();
        queue.push(b, 0).unwrap();

        assert_eq!(wait_terminal(&registry, a), JobState::Done);
        assert_eq!(wait_terminal(&registry, b), JobState::Done);
        let ja = registry.job_json(a).unwrap();
        assert_eq!(ja.get("epochs_done").as_usize(), Some(1));

        queue.close();
        pool.join();
    }

    #[test]
    fn cancelled_while_queued_is_skipped() {
        let queue = Arc::new(JobQueue::new(8));
        let registry = Arc::new(JobRegistry::new());
        let id = registry.add(tiny_spec("fp32"));
        registry.cancel(id).unwrap();
        queue.push(id, 0).unwrap(); // worker pops it, claim fails, skips

        let pool = WorkerPool::spawn(1, queue.clone(), registry.clone());
        // the job must stay Cancelled, never flip to Running/Done
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(registry.state_of(id), Some(JobState::Cancelled));
        queue.close();
        pool.join();
    }
}
