//! Minimal HTTP/1.1 front end on `std::net::TcpListener` — content-length
//! framing only, one request per connection (`Connection: close`), JSON
//! bodies everywhere. One acceptor thread handles the (cheap) control
//! plane; training runs on the worker pool.
//!
//! Routes:
//!
//! | method+path            | action                                   |
//! |------------------------|------------------------------------------|
//! | GET  /healthz          | liveness probe                           |
//! | GET  /stats            | aggregate `ServerStats`                  |
//! | GET  /jobs             | job summaries, newest first              |
//! | POST /jobs             | submit a `JobSpec` (429 when queue full) |
//! | GET  /jobs/{id}        | full status + per-epoch history          |
//! | POST /jobs/{id}/cancel | cancel queued / stop running             |
//! | POST /shutdown         | drain acceptor, close queue, join pool   |

use super::journal::{self, Journal};
use super::protocol::{error_json, JobSpec, DEFAULT_PORT};
use super::queue::JobQueue;
use super::registry::{CancelOutcome, JobRegistry};
use super::worker::WorkerPool;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1; 0 picks an ephemeral port.
    pub port: u16,
    /// Worker-pool size (concurrent training jobs).
    pub workers: usize,
    /// Queue capacity; submissions beyond it get a 429.
    pub queue_cap: usize,
    /// Path of the persistent JSONL job journal (`None` = in-memory
    /// only, the pre-journal behavior). With a journal, the job table
    /// is replayed on startup, interrupted jobs requeue from their
    /// last checkpoint, and clean shutdown compacts the file.
    pub journal: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { port: DEFAULT_PORT, workers: 2, queue_cap: 64, journal: None }
    }
}

/// A bound job server: acceptor + queue + registry + worker pool,
/// optionally backed by a persistent job journal.
pub struct Server {
    listener: TcpListener,
    queue: Arc<JobQueue>,
    registry: Arc<JobRegistry>,
    pool: WorkerPool,
    journal: Option<Arc<Journal>>,
}

impl Server {
    /// Bind the listener and spawn the worker pool (jobs start flowing
    /// only once [`Server::run`] accepts submissions). With a journal
    /// configured, the previous process's job table is replayed first:
    /// terminal jobs reappear in listings, and jobs that were queued,
    /// running or interrupted go back on the queue — resuming from
    /// their last checkpoint when one exists.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let queue = Arc::new(JobQueue::new(opts.queue_cap));
        let (registry, jrnl, requeue) = match &opts.journal {
            None => (Arc::new(JobRegistry::new()), None, Vec::new()),
            Some(path) => {
                let mut replayed = journal::replay(path)?;
                let mut requeue = Vec::new();
                for job in &mut replayed {
                    if journal::prepare_requeue(job) {
                        requeue.push((job.id, job.spec.priority));
                    }
                }
                let j = Arc::new(Journal::open(path)?);
                let registry = Arc::new(JobRegistry::with_journal(Some(j.clone())));
                for job in replayed {
                    registry.restore(job);
                }
                // collapse the replayed event stream right away so the
                // file stays bounded across repeated restarts
                j.compact(&registry.compacted_jobs())?;
                (registry, Some(j), requeue)
            }
        };
        let pool = WorkerPool::spawn(opts.workers, queue.clone(), registry.clone());
        for (id, priority) in requeue {
            if queue.push(id, priority).is_err() {
                registry.fail(id, "restart requeue rejected: queue full".into());
            }
        }
        Ok(Server { listener, queue, registry, pool, journal: jrnl })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop; returns after a `POST /shutdown`, once the queue is
    /// closed, in-flight jobs are stop-flagged (completing as
    /// Interrupted, so the next journal replay requeues them), every
    /// worker has exited, and the journal — when configured — has been
    /// compacted with the final job states.
    pub fn run(self) -> Result<()> {
        for conn in self.listener.incoming() {
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            if self.handle(&mut stream) {
                break;
            }
        }
        self.queue.close();
        // without this, pool.join() would block for the remainder of
        // any in-flight training run
        self.registry.stop_all_running();
        self.pool.join();
        if let Some(j) = &self.journal {
            j.compact(&self.registry.compacted_jobs())?;
        }
        Ok(())
    }

    /// Serve one connection; returns true iff shutdown was requested.
    fn handle(&self, stream: &mut TcpStream) -> bool {
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
        let req = match read_request(stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_json(stream, 400, &error_json(&format!("bad request: {e:#}")));
                return false;
            }
        };
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        let (status, body, shutdown) = self.route(&req.method, &segs, &req.body);
        let _ = write_json(stream, status, &body);
        shutdown
    }

    fn route(&self, method: &str, segs: &[&str], body: &[u8]) -> (u16, Value, bool) {
        match (method, segs) {
            ("GET", ["healthz"]) => (200, Value::obj(vec![("ok", Value::Bool(true))]), false),
            ("GET", ["stats"]) => (
                200,
                self.registry.stats_json(self.queue.len(), self.pool.len()),
                false,
            ),
            ("GET", ["jobs"]) => (200, self.registry.jobs_json(), false),
            ("POST", ["jobs"]) => {
                let (status, v) = self.submit(body);
                (status, v, false)
            }
            ("GET", ["jobs", id]) => match parse_id(id) {
                Some(id) => match self.registry.job_json(id) {
                    Some(v) => (200, v, false),
                    None => (404, error_json(&format!("no job {id}")), false),
                },
                None => (400, error_json("job id must be an integer"), false),
            },
            ("POST", ["jobs", id, "cancel"]) => match parse_id(id) {
                Some(id) => self.cancel(id),
                None => (400, error_json("job id must be an integer"), false),
            },
            ("POST", ["shutdown"]) => {
                (200, Value::obj(vec![("ok", Value::Bool(true))]), true)
            }
            _ => (404, error_json(&format!("no route {method} /{}", segs.join("/"))), false),
        }
    }

    fn submit(&self, body: &[u8]) -> (u16, Value) {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return (400, error_json("body must be utf-8 JSON")),
        };
        let v = match json::parse(text) {
            Ok(v) => v,
            Err(e) => return (400, error_json(&format!("invalid JSON: {e}"))),
        };
        let spec = match JobSpec::from_json(&v) {
            Ok(s) => s,
            Err(e) => return (400, error_json(&format!("invalid job spec: {e:#}"))),
        };
        let priority = spec.priority;
        let id = self.registry.add(spec);
        // journal the submission BEFORE the job becomes poppable: once
        // push succeeds a worker may claim it immediately, and its
        // start/epoch/terminal events must replay after the submit
        // line. A rejected push compensates with a 'forget' event.
        self.registry.journal_submit(id);
        match self.queue.push(id, priority) {
            Ok(()) => (
                200,
                Value::obj(vec![
                    ("id", Value::num(id as f64)),
                    ("state", Value::str("queued")),
                ]),
            ),
            Err(full) => {
                // roll the record back so the rejected job never shows up
                self.registry.forget(id);
                (
                    429,
                    Value::obj(vec![
                        ("error", Value::str("queue full")),
                        ("capacity", Value::num(full.capacity as f64)),
                    ]),
                )
            }
        }
    }

    fn cancel(&self, id: u64) -> (u16, Value, bool) {
        match self.registry.cancel(id) {
            None => (404, error_json(&format!("no job {id}")), false),
            Some(outcome) => {
                let action = match outcome {
                    CancelOutcome::CancelledQueued => {
                        self.queue.remove(id);
                        "cancelled-while-queued"
                    }
                    CancelOutcome::StopRequested => "stop-requested",
                    CancelOutcome::AlreadyTerminal(_) => "already-terminal",
                };
                let state = self
                    .registry
                    .state_of(id)
                    .map(|s| s.as_str())
                    .unwrap_or("unknown");
                (
                    200,
                    Value::obj(vec![
                        ("id", Value::num(id as f64)),
                        ("action", Value::str(action)),
                        ("state", Value::str(state)),
                    ]),
                    false,
                )
            }
        }
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one content-length-framed request (no chunked encoding).
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        anyhow::ensure!(buf.len() < 64 * 1024, "headers too large");
        let n = stream.read(&mut tmp)?;
        anyhow::ensure!(n > 0, "connection closed mid-headers");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 headers")?;
    let mut lines = head.split("\r\n");
    let reqline = lines.next().context("empty request")?;
    let mut parts = reqline.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    anyhow::ensure!(content_len <= 1 << 20, "body too large (max 1 MiB)");
    let mut body: Vec<u8> = buf[header_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut tmp)?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_len);
    Ok(Request { method, path, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

fn write_json(stream: &mut TcpStream, status: u16, v: &Value) -> std::io::Result<()> {
    let body = json::to_string(v);
    let resp = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        status_text(status),
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Tiny blocking HTTP/1.1 client for `repro submit|jobs|job` and the
/// integration tests. Returns `(status, parsed JSON body)`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Value>) -> Result<(u16, Value)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let body_text = body.map(json::to_string).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> Result<(u16, Value)> {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .context("malformed HTTP response (no header terminator)")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .context("missing status code")?
        .parse()
        .context("non-numeric status code")?;
    let trimmed = body.trim();
    let v = if trimmed.is_empty() {
        Value::Null
    } else {
        json::parse(trimmed).context("parsing response JSON")?
    };
    Ok((status, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn response_parsing() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Length: 16\r\n\r\n{\"error\":\"full\"}";
        let (status, v) = parse_response(raw).unwrap();
        assert_eq!(status, 429);
        assert_eq!(v.get("error").as_str(), Some("full"));
        assert!(parse_response(b"garbage").is_err());
    }

    #[test]
    fn healthz_and_404_over_real_sockets() {
        let server =
            Server::bind(&ServeOptions { port: 0, workers: 1, queue_cap: 2, journal: None })
                .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || server.run().unwrap());

        let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(v.get("ok").as_bool(), Some(true));

        let (status, v) = request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(status, 404);
        assert!(v.get("error").as_str().is_some());

        let (status, _) = request(&addr, "GET", "/jobs/xyz", None).unwrap();
        assert_eq!(status, 400);

        let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
        assert_eq!(status, 200);
        h.join().unwrap();
    }
}
