//! Minimal HTTP/1.1 front end on `std::net::TcpListener` — content-length
//! framing only, one request per connection (`Connection: close`), JSON
//! bodies everywhere. The acceptor hands each connection to a
//! short-lived handler thread, so a slow or hung client can never
//! block `/healthz`, `/stats` or submissions behind its socket
//! timeout; training runs on the worker pool (and, with `--cluster`,
//! on remote agents).
//!
//! Routes:
//!
//! | method+path            | action                                   |
//! |------------------------|------------------------------------------|
//! | GET  /healthz          | liveness probe                           |
//! | GET  /stats            | aggregate `ServerStats`                  |
//! | GET  /metrics          | Prometheus text exposition (non-JSON)    |
//! | GET  /jobs             | job summaries, newest first              |
//! | POST /jobs             | submit a `JobSpec` (429 full, 503 closed)|
//! | GET  /jobs/{id}        | full status + history (`?history_since=`)|
//! | POST /jobs/{id}/cancel | cancel queued / stop running             |
//! | GET  /jobs/{id}/events | SSE: one job's epochs/states, replay+live|
//! | GET  /events           | SSE firehose (`?since_seq=` resume)      |
//! | POST /shutdown         | close queue, stop jobs, drain, compact   |
//!
//! The two `/events` routes are the server's only long-lived
//! streaming responses: `Content-Type: text/event-stream`, one SSE
//! frame per bus event, a `: keep-alive` comment each second of
//! idleness, subscriber teardown on client disconnect (write failure)
//! and on `/shutdown` (bus close). Everything else stays one-shot
//! JSON. Wire format details live in `rust/docs/SERVE_API.md`.
//!
//! With `ServeOptions::cluster` set, the `/cluster/*` control plane is
//! live as well (see [`super::dispatch`]):
//!
//! | method+path                              | action                      |
//! |------------------------------------------|-----------------------------|
//! | POST /cluster/register                   | admit a remote worker agent |
//! | GET  /cluster/agents                     | agent listing               |
//! | POST /cluster/agents/{a}/poll            | heartbeat + work pull       |
//! | POST /cluster/agents/{a}/deregister      | graceful leave (requeues)   |
//! | POST /cluster/agents/{a}/jobs/{j}/epoch  | per-epoch progress          |
//! | POST /cluster/agents/{a}/jobs/{j}/done   | terminal outcome            |
//! | POST /cluster/dp/{j}/join                | dp replica sync / catch-up  |
//! | POST /cluster/dp/{j}/step                | dp shard step-report        |
//! | POST /cluster/dp/{j}/commits             | dp commit watermark poll    |
//! | POST /cluster/dp/{j}/epoch               | dp epoch test metrics       |
//! | POST /cluster/dp/{j}/leave               | dp replica leaves the run   |

use super::dispatch::{ClusterOptions, Dispatcher};
use super::events::{Poll, Subscriber, DEFAULT_SUBSCRIBER_CAP};
use super::journal::{self, Journal};
use super::protocol::{error_json, JobSpec, JobState, DEFAULT_PORT};
use super::queue::{JobQueue, PushError};
use super::registry::{CancelOutcome, JobRegistry};
use super::worker::WorkerPool;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1; 0 picks an ephemeral port.
    pub port: u16,
    /// Worker-pool size (concurrent local training jobs). 0 is allowed
    /// only with `cluster` set: a pure coordinator that runs nothing
    /// itself.
    pub workers: usize,
    /// Queue capacity; fresh submissions beyond it get a 429. Journal
    /// replay and lease-expiry requeues bypass it (jobs admitted once
    /// are never destroyed by capacity).
    pub queue_cap: usize,
    /// Path of the persistent JSONL job journal (`None` = in-memory
    /// only, the pre-journal behavior). With a journal, the job table
    /// is replayed on startup, interrupted jobs requeue from their
    /// last checkpoint, and clean shutdown compacts the file.
    pub journal: Option<String>,
    /// Enable the cluster control plane (`/cluster/*`): remote worker
    /// agents register here and the dispatcher fans queued jobs out to
    /// them. `None` = single-node; with no registered agents a cluster
    /// server behaves exactly like a single-node one.
    pub cluster: Option<ClusterOptions>,
    /// Per-subscriber event buffer for the SSE streams: a consumer
    /// this many events behind starts shedding the oldest and gets a
    /// `lagged` resync marker — the trainers never wait on a slow
    /// watcher.
    pub events_buffer: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            port: DEFAULT_PORT,
            workers: 2,
            queue_cap: 64,
            journal: None,
            cluster: None,
            events_buffer: DEFAULT_SUBSCRIBER_CAP,
        }
    }
}

/// Everything a connection handler needs, shared across the acceptor
/// and the per-connection threads.
struct Gateway {
    addr: SocketAddr,
    queue: Arc<JobQueue>,
    registry: Arc<JobRegistry>,
    journal: Option<Arc<Journal>>,
    dispatcher: Option<Arc<Dispatcher>>,
    workers: usize,
    events_buffer: usize,
    shutdown: AtomicBool,
    active: AtomicUsize,
    /// Open SSE streams; each pins a connection thread for its whole
    /// lifetime, so they are bounded (see [`MAX_SSE_STREAMS`]).
    sse_active: AtomicUsize,
}

/// A bound job server: acceptor + queue + registry + worker pool,
/// optionally backed by a persistent job journal and/or fronting a
/// cluster of remote agents.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Gateway>,
    pool: WorkerPool,
}

impl Server {
    /// Bind the listener and spawn the worker pool (jobs start flowing
    /// only once [`Server::run`] accepts submissions). With a journal
    /// configured, the previous process's job table is replayed first:
    /// terminal jobs reappear in listings, and jobs that were queued,
    /// running or interrupted go back on the queue — resuming from
    /// their last checkpoint when one exists. Replay requeue bypasses
    /// `queue_cap`: a durable backlog larger than the queue must never
    /// fail jobs at boot.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        anyhow::ensure!(
            opts.workers > 0 || opts.cluster.is_some(),
            "a server without --cluster needs at least one local worker"
        );
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr()?;
        let queue = Arc::new(JobQueue::new(opts.queue_cap));
        let (registry, jrnl, requeue) = match &opts.journal {
            None => (Arc::new(JobRegistry::new()), None, Vec::new()),
            Some(path) => {
                let mut replayed = journal::replay(path)?;
                let mut requeue = Vec::new();
                for job in &mut replayed {
                    if journal::prepare_requeue(job) {
                        requeue.push((job.id, job.spec.priority));
                    }
                }
                let j = Arc::new(Journal::open(path)?);
                let registry = Arc::new(JobRegistry::with_journal(Some(j.clone())));
                for job in replayed {
                    registry.restore(job);
                }
                // collapse the replayed event stream right away so the
                // file stays bounded across repeated restarts
                j.compact(&registry.compacted_jobs())?;
                (registry, Some(j), requeue)
            }
        };
        let dispatcher = opts
            .cluster
            .as_ref()
            .map(|c| Dispatcher::spawn(c.clone(), queue.clone(), registry.clone()));
        let pool = WorkerPool::spawn(opts.workers, queue.clone(), registry.clone());
        for (id, priority) in requeue {
            // push_admitted only refuses on a closed queue, which
            // cannot happen at boot — but never fail silently
            if !queue.push_admitted(id, priority) {
                registry.fail(id, "restart requeue rejected: queue closed".into());
            }
        }
        let shared = Arc::new(Gateway {
            addr,
            queue,
            registry,
            journal: jrnl,
            dispatcher,
            workers: opts.workers,
            events_buffer: opts.events_buffer.max(1),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            sse_active: AtomicUsize::new(0),
        });
        Ok(Server { listener, shared, pool })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop; each connection is served on its own short-lived
    /// thread. Returns after a `POST /shutdown`: the handler closes the
    /// queue first (so racing submissions get a truthful 503), signals
    /// the acceptor through a flag + self-connect wake-up, in-flight
    /// handlers are drained, running jobs are stop-flagged (completing
    /// as Interrupted, so the next journal replay requeues them),
    /// remote agents' jobs are interrupted coordinator-side, every
    /// worker joins, and the journal — when configured — is compacted
    /// with the final job states.
    pub fn run(self) -> Result<()> {
        let Server { listener, shared, pool } = self;
        for conn in listener.incoming() {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            shared.active.fetch_add(1, Ordering::SeqCst);
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name("serve-conn".into())
                .spawn(move || {
                    sh.handle(&mut stream);
                    sh.active.fetch_sub(1, Ordering::SeqCst);
                });
            if spawned.is_err() {
                shared.active.fetch_sub(1, Ordering::SeqCst);
            }
        }
        // drain in-flight handlers briefly so their final journal
        // events land before compaction
        let t0 = Instant::now();
        while shared.active.load(Ordering::SeqCst) > 0
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        shared.queue.close();
        // without this, pool.join() would block for the remainder of
        // any in-flight training run
        shared.registry.stop_all_running();
        // idempotent: the shutdown handler already closed the bus, but
        // an acceptor that exits any other way must still end the SSE
        // streams instead of leaving watchers on a dead server
        shared.registry.events().close();
        if let Some(d) = &shared.dispatcher {
            d.shutdown();
        }
        pool.join();
        if let Some(j) = &shared.journal {
            j.compact(&shared.registry.compacted_jobs())?;
        }
        Ok(())
    }

    /// Drive one request through the router without a socket — the
    /// deterministic seam for tests and embedders (e.g. asserting the
    /// shutdown 503 without racing the acceptor teardown). Behaves
    /// exactly like a request over the wire, including shutdown
    /// side effects.
    pub fn inject(&self, method: &str, path: &str, body: Option<&Value>) -> (u16, Value) {
        let text = body.map(json::to_string).unwrap_or_default();
        let (path, query) = split_query(path);
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        if method == "GET" && segs == ["metrics"] {
            // text/plain on the wire; over this seam the exposition
            // rides as a JSON string
            return (200, Value::str(self.shared.render_metrics()));
        }
        if is_stream_route(method, &segs) {
            // the SSE endpoints write incrementally and never fit the
            // one-shot (status, body) seam
            return (501, error_json("streaming endpoint: connect over a real socket"));
        }
        let (status, v, shutdown) = self.shared.route(method, &segs, &query, text.as_bytes());
        if shutdown {
            self.shared.begin_shutdown();
            self.shared.wake();
        }
        (status, v)
    }
}

impl Gateway {
    /// Serve one connection (already on its own thread).
    fn handle(&self, stream: &mut TcpStream) {
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
        let req = match read_request(stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_json(stream, 400, &error_json(&format!("bad request: {e:#}")));
                return;
            }
        };
        let (path, query) = split_query(&req.path);
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        // Prometheus exposition is the one non-JSON one-shot response;
        // it gets its own seam so the JSON router stays JSON-only
        if let ("GET", ["metrics"]) = (req.method.as_str(), segs.as_slice()) {
            let t0 = Instant::now();
            let text = self.render_metrics();
            observe_http("GET /metrics", 200, t0.elapsed());
            let _ = write_text(stream, 200, &text);
            return;
        }
        if is_stream_route(&req.method, &segs) {
            // long-lived SSE response: hand the socket to the stream
            // writer; it owns the connection until the client leaves,
            // the job finishes, or the server drains. Each open stream
            // pins a thread + a bus subscriber, so a runaway client
            // opening streams in a loop is refused past the cap
            // instead of exhausting the very devices this stack runs on
            if self.sse_active.fetch_add(1, Ordering::SeqCst) >= MAX_SSE_STREAMS {
                self.sse_active.fetch_sub(1, Ordering::SeqCst);
                let _ = write_json(
                    stream,
                    503,
                    &error_json(&format!(
                        "too many open event streams (max {MAX_SSE_STREAMS}); \
                         close one or poll GET /jobs/<id>?history_since="
                    )),
                );
                return;
            }
            // streams are counted but not latency-timed: their
            // "duration" is the watch lifetime, not a response time
            let label = if segs.len() == 1 { "GET /events" } else { "GET /jobs/{}/events" };
            crate::metrics::global()
                .counter(HTTP_REQS_NAME, HTTP_REQS_HELP, &[("route", label), ("code", "200")])
                .inc();
            match segs.as_slice() {
                ["events"] => self.stream_firehose(stream, &query),
                ["jobs", id, "events"] => self.stream_job_events(stream, id),
                _ => unreachable!("is_stream_route and this match must agree"),
            }
            self.sse_active.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let t0 = Instant::now();
        let (status, body, shutdown) = self.route(&req.method, &segs, &query, &req.body);
        observe_http(&http_route_label(&req.method, &segs, status), status, t0.elapsed());
        if shutdown {
            // close the queue BEFORE acknowledging: any submission
            // that observes the shutdown gets a truthful 503 instead
            // of racing the acceptor teardown
            self.begin_shutdown();
        }
        let _ = write_json(stream, status, &body);
        if shutdown {
            self.wake();
        }
    }

    /// Sample the scrape-time gauges (queue depth, jobs by state, SSE
    /// streams, event bus, agents, heap) into the process registry and
    /// render the Prometheus text exposition (`GET /metrics`). The
    /// counters and histograms fed at record time (requests, epochs,
    /// phases, journal appends, requeues) come along with the render.
    fn render_metrics(&self) -> String {
        use crate::metrics::{alloc, global};
        let m = global();
        m.gauge("repro_queue_depth", "Jobs waiting in the queue", &[])
            .set(self.queue.len() as f64);
        for (state, n) in self.registry.jobs_by_state() {
            m.gauge("repro_jobs", "Jobs in the registry by state", &[("state", state.as_str())])
                .set(n as f64);
        }
        m.gauge("repro_sse_streams_active", "Open SSE event streams", &[])
            .set(self.sse_active.load(Ordering::SeqCst) as f64);
        let events = self.registry.events();
        m.gauge("repro_events_seq", "Current event-bus sequence number", &[])
            .set(events.current_seq() as f64);
        m.gauge("repro_event_subscribers", "Live event-bus subscribers", &[])
            .set(events.subscriber_count() as f64);
        m.counter(
            "repro_sse_lagged_total",
            "Events shed from slow event-stream subscribers",
            &[],
        )
        .mirror(events.lagged_total());
        if let Some(d) = &self.dispatcher {
            m.gauge("repro_agents", "Registered cluster agents", &[]).set(d.agent_count() as f64);
        }
        m.gauge(
            "repro_mem_live_bytes",
            "Live heap bytes (tracked allocator; 0 outside the repro binary)",
            &[],
        )
        .set(alloc::live_bytes() as f64);
        m.gauge("repro_mem_peak_bytes", "Peak live heap bytes since process start", &[])
            .set(alloc::peak_bytes() as f64);
        m.counter("repro_allocs_total", "Heap allocations served by the tracked allocator", &[])
            .mirror(alloc::alloc_count());
        m.render()
    }

    /// Make the shutdown observable (queue closed, running jobs
    /// stop-flagged as interrupted, event bus closed so SSE streams
    /// end instead of holding the drain open) and raise the acceptor's
    /// flag.
    fn begin_shutdown(&self) {
        self.queue.close();
        self.registry.stop_all_running();
        self.registry.events().close();
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Unblock the acceptor so it notices the shutdown flag.
    fn wake(&self) {
        let _ = TcpStream::connect(self.addr);
    }

    fn route(
        &self,
        method: &str,
        segs: &[&str],
        query: &[(String, String)],
        body: &[u8],
    ) -> (u16, Value, bool) {
        match (method, segs) {
            ("GET", ["healthz"]) => (200, Value::obj(vec![("ok", Value::Bool(true))]), false),
            ("GET", ["stats"]) => {
                let mut v = self.registry.stats_json(self.queue.len(), self.workers);
                if let (Some(d), Value::Obj(obj)) = (&self.dispatcher, &mut v) {
                    obj.insert("agents".into(), Value::num(d.agent_count() as f64));
                }
                (200, v, false)
            }
            ("GET", ["jobs"]) => (200, self.registry.jobs_json(), false),
            ("POST", ["jobs"]) => {
                let (status, v) = self.submit(body);
                (status, v, false)
            }
            ("GET", ["jobs", id]) => match parse_id(id) {
                Some(id) => {
                    // ?history_since=E trims the epoch history to
                    // entries with epoch >= E, so pollers of long runs
                    // stop shipping ever-growing bodies (default: full)
                    let since = match qget(query, "history_since") {
                        None => None,
                        Some(s) => match s.parse::<usize>() {
                            Ok(n) => Some(n),
                            Err(_) => {
                                return (
                                    400,
                                    error_json("history_since must be an integer epoch"),
                                    false,
                                )
                            }
                        },
                    };
                    match self.registry.job_json_since(id, since) {
                        Some(v) => (200, v, false),
                        None => (404, error_json(&format!("no job {id}")), false),
                    }
                }
                None => (400, error_json("job id must be an integer"), false),
            },
            ("POST", ["jobs", id, "cancel"]) => match parse_id(id) {
                Some(id) => self.cancel(id),
                None => (400, error_json("job id must be an integer"), false),
            },
            (m, ["cluster", rest @ ..]) => {
                let (status, v) = self.route_cluster(m, rest, body);
                (status, v, false)
            }
            ("POST", ["shutdown"]) => {
                (200, Value::obj(vec![("ok", Value::Bool(true))]), true)
            }
            _ => (404, error_json(&format!("no route {method} /{}", segs.join("/"))), false),
        }
    }

    /// The `/cluster/*` control plane (404 unless the server was
    /// started with cluster mode enabled).
    fn route_cluster(&self, method: &str, segs: &[&str], body: &[u8]) -> (u16, Value) {
        let Some(d) = &self.dispatcher else {
            return (404, error_json("cluster mode disabled (start with --cluster)"));
        };
        match (method, segs) {
            ("POST", ["register"]) => d.register(body),
            ("GET", ["agents"]) => (200, d.agents_json()),
            ("POST", ["agents", aid, "poll"]) => match parse_id(aid) {
                Some(a) => d.poll(a, body),
                None => (400, error_json("agent id must be an integer")),
            },
            ("POST", ["agents", aid, "deregister"]) => match parse_id(aid) {
                Some(a) => d.deregister(a),
                None => (400, error_json("agent id must be an integer")),
            },
            ("POST", ["agents", aid, "jobs", jid, "epoch"]) => {
                match (parse_id(aid), parse_id(jid)) {
                    (Some(a), Some(j)) => d.report_epoch(a, j, body),
                    _ => (400, error_json("agent and job ids must be integers")),
                }
            }
            ("POST", ["agents", aid, "jobs", jid, "done"]) => {
                match (parse_id(aid), parse_id(jid)) {
                    (Some(a), Some(j)) => d.report_done(a, j, body),
                    _ => (400, error_json("agent and job ids must be integers")),
                }
            }
            ("POST", ["dp", jid, "join"]) => match parse_id(jid) {
                Some(j) => d.dp.join(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "step"]) => match parse_id(jid) {
                Some(j) => d.dp.step(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "commits"]) => match parse_id(jid) {
                Some(j) => d.dp.commits(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "epoch"]) => match parse_id(jid) {
                Some(j) => d.dp.epoch(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "leave"]) => match parse_id(jid) {
                Some(j) => d.dp.leave(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            _ => (
                404,
                error_json(&format!("no route {method} /cluster/{}", segs.join("/"))),
            ),
        }
    }

    fn submit(&self, body: &[u8]) -> (u16, Value) {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return (400, error_json("body must be utf-8 JSON")),
        };
        let v = match json::parse(text) {
            Ok(v) => v,
            Err(e) => return (400, error_json(&format!("invalid JSON: {e}"))),
        };
        let spec = match JobSpec::from_json(&v) {
            Ok(s) => s,
            Err(e) => return (400, error_json(&format!("invalid job spec: {e:#}"))),
        };
        let priority = spec.priority;
        let id = self.registry.add(spec);
        // journal the submission BEFORE the job becomes poppable: once
        // push succeeds a worker may claim it immediately, and its
        // start/epoch/terminal events must replay after the submit
        // line. A rejected push compensates with a 'forget' event.
        self.registry.journal_submit(id);
        match self.queue.push(id, priority) {
            Ok(()) => {
                // only now is the submission real: broadcast it (a
                // rejected push below is rolled back and must never
                // surface on the event bus)
                self.registry.announce_queued(id);
                (
                    200,
                    Value::obj(vec![
                        ("id", Value::num(id as f64)),
                        ("state", Value::str("queued")),
                    ]),
                )
            }
            Err(e) => {
                // roll the record back so the rejected job never shows up
                self.registry.forget(id);
                match e {
                    PushError::Full { capacity } => (
                        429,
                        Value::obj(vec![
                            ("error", Value::str("queue full")),
                            ("capacity", Value::num(capacity as f64)),
                        ]),
                    ),
                    // shutdown in progress: not backpressure — this
                    // instance will never accept the job
                    PushError::Closed => (
                        503,
                        error_json("server shutting down; resubmit after restart"),
                    ),
                }
            }
        }
    }

    /// `GET /jobs/{id}/events` — one job's SSE stream: replay the
    /// history recorded so far, then go live; closes once the job is
    /// terminal (or immediately after the replay when it already is).
    fn stream_job_events(&self, stream: &mut TcpStream, id_seg: &str) {
        let Some(id) = parse_id(id_seg) else {
            let _ = write_json(stream, 400, &error_json("job id must be an integer"));
            return;
        };
        // subscribe BEFORE the snapshot: anything published in between
        // lands in the buffer AND below the snapshot's watermark, and
        // the live loop skips it — exactly-once across the seam
        let sub = self.registry.events().subscribe(Some(id), self.events_buffer);
        let Some(snap) = self.registry.stream_snapshot(id) else {
            let _ = write_json(stream, 404, &error_json(&format!("no job {id}")));
            return;
        };
        if write_sse_header(stream).is_err() {
            return;
        }
        for e in &snap.epochs {
            let data = Value::obj(vec![
                ("type", Value::str("epoch")),
                ("job", Value::num(id as f64)),
                ("replay", Value::Bool(true)),
                ("stats", e.to_json()),
            ]);
            if write_sse_frame(stream, "epoch", None, &data).is_err() {
                return;
            }
        }
        let mut pairs = vec![
            ("type", Value::str("state")),
            ("job", Value::num(id as f64)),
            ("replay", Value::Bool(true)),
            ("state", Value::str(snap.state.as_str())),
        ];
        if let Some(err) = &snap.error {
            pairs.push(("error", Value::str(err.clone())));
        }
        if write_sse_frame(stream, "state", None, &Value::obj(pairs)).is_err() {
            return;
        }
        if snap.state.is_terminal() {
            return; // the job already finished: replay-only stream
        }
        self.pump(stream, &sub, snap.watermark, true);
    }

    /// `GET /events` — the all-jobs SSE firehose. Without `since_seq`
    /// it streams from now; `?since_seq=N` atomically replays the
    /// retained ring tail past N (a leading `lagged` frame marks an
    /// evicted resume point) before going live.
    fn stream_firehose(&self, stream: &mut TcpStream, query: &[(String, String)]) {
        let since = match qget(query, "since_seq") {
            None => None,
            Some(s) => match s.parse::<u64>() {
                Ok(n) => Some(n),
                Err(_) => {
                    let _ = write_json(
                        stream,
                        400,
                        &error_json("since_seq must be an integer sequence number"),
                    );
                    return;
                }
            },
        };
        let bus = self.registry.events();
        let (sub, backlog, gap, resume_seq) =
            bus.subscribe_since(self.events_buffer, since.unwrap_or_else(|| bus.current_seq()));
        if write_sse_header(stream).is_err() {
            return;
        }
        if gap {
            // resume_seq was captured under the same lock that created
            // the subscription, so it can never trail a delivered event
            let data = Value::obj(vec![
                ("type", Value::str("lagged")),
                ("next_seq", Value::num(resume_seq as f64)),
            ]);
            if write_sse_frame(stream, "lagged", None, &data).is_err() {
                return;
            }
        }
        for e in &backlog {
            if write_sse_frame(stream, e.kind, Some(e.seq), &e.data).is_err() {
                return;
            }
        }
        self.pump(stream, &sub, 0, false);
    }

    /// Shared live loop of both SSE streams: deliver bus events with
    /// `seq > watermark`, translate buffer overflow into explicit
    /// `lagged` frames, emit `: keep-alive` comments through idle
    /// stretches, and tear down on client disconnect (write failure),
    /// bus close (server drain), or — for per-job streams — the
    /// watched job's terminal state.
    fn pump(
        &self,
        stream: &mut TcpStream,
        sub: &Subscriber,
        watermark: u64,
        close_on_terminal: bool,
    ) {
        loop {
            match sub.recv(SSE_KEEPALIVE) {
                Poll::Event(e) => {
                    if e.seq <= watermark {
                        continue; // the replay snapshot already covered it
                    }
                    if write_sse_frame(stream, e.kind, Some(e.seq), &e.data).is_err() {
                        return;
                    }
                    let terminal = e
                        .state()
                        .and_then(|s| JobState::parse(s).ok())
                        .is_some_and(|s| s.is_terminal());
                    if close_on_terminal && terminal {
                        return;
                    }
                }
                Poll::Lagged { next_seq } => {
                    let data = Value::obj(vec![
                        ("type", Value::str("lagged")),
                        ("next_seq", Value::num(next_seq as f64)),
                    ]);
                    if write_sse_frame(stream, "lagged", None, &data).is_err() {
                        return;
                    }
                }
                Poll::Timeout => {
                    if stream.write_all(b": keep-alive\n\n").is_err() {
                        return;
                    }
                }
                Poll::Closed => return,
            }
        }
    }

    fn cancel(&self, id: u64) -> (u16, Value, bool) {
        match self.registry.cancel(id) {
            None => (404, error_json(&format!("no job {id}")), false),
            Some(outcome) => {
                let action = match outcome {
                    CancelOutcome::CancelledQueued => {
                        self.queue.remove(id);
                        "cancelled-while-queued"
                    }
                    CancelOutcome::StopRequested => "stop-requested",
                    CancelOutcome::AlreadyTerminal(_) => "already-terminal",
                };
                let state = self
                    .registry
                    .state_of(id)
                    .map(|s| s.as_str())
                    .unwrap_or("unknown");
                (
                    200,
                    Value::obj(vec![
                        ("id", Value::num(id as f64)),
                        ("action", Value::str(action)),
                        ("state", Value::str(state)),
                    ]),
                    false,
                )
            }
        }
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

/// Idle interval after which the SSE streams emit a `: keep-alive`
/// comment, so clients (and anything buffering between) can tell a
/// quiet stream from a dead connection.
const SSE_KEEPALIVE: Duration = Duration::from_millis(1000);

/// Concurrent SSE streams the server will hold open; each pins a
/// connection thread and a bus subscriber for its whole lifetime, so
/// the count must be bounded on memory-constrained hosts. Requests
/// past the cap get a 503.
const MAX_SSE_STREAMS: usize = 64;

/// The long-lived SSE routes, dispatched before the one-shot router
/// (they own the socket instead of returning a `(status, body)`).
fn is_stream_route(method: &str, segs: &[&str]) -> bool {
    matches!((method, segs), ("GET", ["events"]) | ("GET", ["jobs", _, "events"]))
}

/// Split `path?query` and parse the `k=v&k2=v2` pairs. No %-decoding:
/// every query value this server accepts is a plain integer.
fn split_query(path: &str) -> (&str, Vec<(String, String)>) {
    match path.split_once('?') {
        None => (path, Vec::new()),
        Some((p, q)) => (
            p,
            q.split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), String::new()),
                })
                .collect(),
        ),
    }
}

fn qget<'a>(query: &'a [(String, String)], key: &str) -> Option<&'a str> {
    query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn write_sse_header(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )
}

/// One SSE frame: optional `id:` line (the bus sequence number), the
/// `event:` name, one `data:` line of compact JSON.
fn write_sse_frame(
    stream: &mut TcpStream,
    event: &str,
    id: Option<u64>,
    data: &Value,
) -> std::io::Result<()> {
    let mut frame = String::new();
    if let Some(i) = id {
        frame.push_str(&format!("id: {i}\n"));
    }
    frame.push_str(&format!("event: {event}\ndata: {}\n\n", json::to_string(data)));
    stream.write_all(frame.as_bytes())
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one content-length-framed request (no chunked encoding). The
/// `\r\n\r\n` scan resumes from the previous read's tail instead of
/// re-scanning the whole buffer after every 4 KiB chunk — linear in
/// the header size, where the naive rescan is quadratic.
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut scan_from = 0usize;
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf[scan_from..], b"\r\n\r\n") {
            break scan_from + pos;
        }
        // the terminator may straddle the chunk boundary: keep the
        // last 3 bytes of the scanned prefix in play
        scan_from = buf.len().saturating_sub(3);
        anyhow::ensure!(buf.len() < 64 * 1024, "headers too large");
        let n = stream.read(&mut tmp)?;
        anyhow::ensure!(n > 0, "connection closed mid-headers");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 headers")?;
    let mut lines = head.split("\r\n");
    let reqline = lines.next().context("empty request")?;
    let mut parts = reqline.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_len = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    anyhow::ensure!(content_len <= 1 << 20, "body too large (max 1 MiB)");
    let mut body: Vec<u8> = buf[header_end + 4..].to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut tmp)?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_len);
    Ok(Request { method, path, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn write_json(stream: &mut TcpStream, status: u16, v: &Value) -> std::io::Result<()> {
    let body = json::to_string(v);
    let resp = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        status_text(status),
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

/// Plain-text response writer for the Prometheus exposition — the one
/// route that is not JSON. `version=0.0.4` is the text-format marker
/// scrapers key on.
fn write_text(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    let resp = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        status_text(status),
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

const HTTP_REQS_NAME: &str = "repro_http_requests_total";
const HTTP_REQS_HELP: &str = "HTTP requests served, by route template and status code";

/// Record one served request into the process metrics: a latency
/// histogram per route template and a request counter per
/// (route, code).
fn observe_http(route: &str, status: u16, elapsed: Duration) {
    let m = crate::metrics::global();
    m.histogram(
        "repro_http_request_duration_seconds",
        "HTTP request service time by route template",
        &[("route", route)],
        &crate::metrics::LATENCY_BUCKETS_S,
    )
    .observe(elapsed.as_secs_f64());
    let code = status.to_string();
    m.counter(HTTP_REQS_NAME, HTTP_REQS_HELP, &[("route", route), ("code", &code)]).inc();
}

/// Collapse a request path to a bounded route template so metric
/// cardinality can't grow with job/agent ids: dynamic segments (the
/// ones routes match with a binding) become `{}`, and anything that
/// 404'd is folded into a single "other" label.
fn http_route_label(method: &str, segs: &[&str], status: u16) -> String {
    if status == 404 {
        return "other".to_string();
    }
    let mut out = String::from(method);
    for s in segs {
        out.push('/');
        // Ids are the only free-form segments in the route table;
        // fixed words stay literal so routes remain tell-apart-able.
        let fixed = matches!(
            *s,
            "jobs"
                | "stats"
                | "healthz"
                | "shutdown"
                | "cancel"
                | "events"
                | "metrics"
                | "cluster"
                | "register"
                | "agents"
                | "poll"
                | "deregister"
                | "epoch"
                | "done"
                | "dp"
                | "join"
                | "step"
                | "commits"
                | "leave"
        );
        out.push_str(if fixed { s } else { "{}" });
    }
    // "GET /jobs" style: method, space, then the path.
    if let Some(rest) = out.strip_prefix(method) {
        format!("{method} {rest}")
    } else {
        out
    }
}

/// Tiny blocking HTTP/1.1 client for `repro submit|jobs|job`, the
/// cluster agent and the integration tests. Returns `(status, parsed
/// JSON body)`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Value>) -> Result<(u16, Value)> {
    request_with_timeout(addr, method, path, body, Duration::from_secs(60))
}

/// [`request`] with an explicit read timeout (the agent uses a short
/// one so a dying coordinator shows up as a failed poll, not a hang).
pub fn request_with_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Value>,
    read_timeout: Duration,
) -> Result<(u16, Value)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let body_text = body.map(json::to_string).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> Result<(u16, Value)> {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .context("malformed HTTP response (no header terminator)")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .context("missing status code")?
        .parse()
        .context("non-numeric status code")?;
    let trimmed = body.trim();
    let v = if trimmed.is_empty() {
        Value::Null
    } else {
        json::parse(trimmed).context("parsing response JSON")?
    };
    Ok((status, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn response_parsing() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Length: 16\r\n\r\n{\"error\":\"full\"}";
        let (status, v) = parse_response(raw).unwrap();
        assert_eq!(status, 429);
        assert_eq!(v.get("error").as_str(), Some("full"));
        assert!(parse_response(b"garbage").is_err());
    }

    #[test]
    fn healthz_and_404_over_real_sockets() {
        let server = Server::bind(&ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 2,
            ..Default::default()
        })
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || server.run().unwrap());

        let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(v.get("ok").as_bool(), Some(true));

        let (status, v) = request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(status, 404);
        assert!(v.get("error").as_str().is_some());

        let (status, _) = request(&addr, "GET", "/jobs/xyz", None).unwrap();
        assert_eq!(status, 400);

        // without cluster mode the /cluster routes stay dark
        let (status, v) = request(&addr, "POST", "/cluster/register", None).unwrap();
        assert_eq!(status, 404);
        assert!(v.get("error").as_str().unwrap().contains("cluster mode disabled"));

        let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
        assert_eq!(status, 200);
        h.join().unwrap();
    }

    #[test]
    fn query_splitting_and_stream_route_detection() {
        let (p, q) = split_query("/jobs/3?history_since=2&x=1");
        assert_eq!(p, "/jobs/3");
        assert_eq!(qget(&q, "history_since"), Some("2"));
        assert_eq!(qget(&q, "x"), Some("1"));
        assert_eq!(qget(&q, "missing"), None);
        let (p, q) = split_query("/events");
        assert_eq!(p, "/events");
        assert!(q.is_empty());

        assert!(is_stream_route("GET", &["events"]));
        assert!(is_stream_route("GET", &["jobs", "7", "events"]));
        assert!(!is_stream_route("POST", &["events"]));
        assert!(!is_stream_route("GET", &["jobs", "7"]));
        assert!(!is_stream_route("GET", &["jobs"]));
    }

    #[test]
    fn inject_refuses_streaming_routes() {
        let server = Server::bind(&ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 2,
            ..Default::default()
        })
        .unwrap();
        for path in ["/events", "/events?since_seq=3", "/jobs/1/events"] {
            let (status, v) = server.inject("GET", path, None);
            assert_eq!(status, 501, "{path}");
            assert!(v.get("error").as_str().unwrap().contains("streaming"));
        }
        // the one-shot router still answers through inject
        let (status, _) = server.inject("GET", "/jobs/1?history_since=0", None);
        assert_eq!(status, 404, "no such job, but the query parses");
        let (status, _) = server.inject("GET", "/jobs/1?history_since=x", None);
        assert_eq!(status, 400);
        let (status, _) = server.inject("POST", "/shutdown", None);
        assert_eq!(status, 200);
    }

    #[test]
    fn workers_zero_requires_cluster() {
        let opts = ServeOptions { port: 0, workers: 0, queue_cap: 2, ..Default::default() };
        assert!(Server::bind(&opts).is_err());
        let opts = ServeOptions { cluster: Some(ClusterOptions::default()), ..opts };
        let server = Server::bind(&opts).unwrap();
        let (status, _) = server.inject("GET", "/healthz", None);
        assert_eq!(status, 200);
        let (status, _) = server.inject("POST", "/shutdown", None);
        assert_eq!(status, 200);
    }
}
