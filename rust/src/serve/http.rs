//! Minimal HTTP/1.1 front end on `std::net::TcpListener` —
//! content-length framing only (no chunked encoding), JSON bodies
//! everywhere, keep-alive by default. The acceptor hands each
//! connection to the nonblocking reactor pool ([`super::reactor`]):
//! a few `poll(2)` event loops own all sockets, so a slow or hung
//! client holds a buffer — never a thread — and can't block
//! `/healthz`, `/stats` or submissions; training runs on the worker
//! pool (and, with `--cluster`, on remote agents).
//!
//! Routes:
//!
//! | method+path            | action                                   |
//! |------------------------|------------------------------------------|
//! | GET  /healthz          | liveness probe                           |
//! | GET  /stats            | aggregate `ServerStats`                  |
//! | GET  /metrics          | Prometheus text exposition (non-JSON)    |
//! | GET  /jobs             | job summaries, newest first              |
//! | POST /jobs             | submit a `JobSpec` (429 full, 503 closed)|
//! | GET  /jobs/{id}        | full status + history (`?history_since=`)|
//! | POST /jobs/{id}/cancel | cancel queued / stop running             |
//! | GET  /jobs/{id}/events | SSE: one job's epochs/states, replay+live|
//! | GET  /events           | SSE firehose (`?since_seq=` resume)      |
//! | POST /shutdown         | close queue, stop jobs, drain, compact   |
//!
//! The two `/events` routes are the server's only long-lived
//! streaming responses: `Content-Type: text/event-stream`, one SSE
//! frame per bus event, a `: keep-alive` comment each second of
//! idleness, subscriber teardown on client disconnect (write failure)
//! and on `/shutdown` (bus close). Each stream is a reactor-
//! registered writer multiplexed off the event bus, so open streams
//! are bounded by [`ServeOptions::max_sse`] (default 4096), not by
//! threads. Everything else stays one-shot JSON. Wire format details
//! live in `rust/docs/SERVE_API.md`.
//!
//! With `ServeOptions::cluster` set, the `/cluster/*` control plane is
//! live as well (see [`super::dispatch`]):
//!
//! | method+path                              | action                      |
//! |------------------------------------------|-----------------------------|
//! | POST /cluster/register                   | admit a remote worker agent |
//! | GET  /cluster/agents                     | agent listing               |
//! | POST /cluster/agents/{a}/poll            | heartbeat + work pull       |
//! | POST /cluster/agents/{a}/deregister      | graceful leave (requeues)   |
//! | POST /cluster/agents/{a}/jobs/{j}/epoch  | per-epoch progress          |
//! | POST /cluster/agents/{a}/jobs/{j}/done   | terminal outcome            |
//! | POST /cluster/dp/{j}/join                | dp replica sync / catch-up  |
//! | POST /cluster/dp/{j}/step                | dp shard step-report        |
//! | POST /cluster/dp/{j}/commits             | dp commit watermark poll    |
//! | POST /cluster/dp/{j}/epoch               | dp epoch test metrics       |
//! | POST /cluster/dp/{j}/leave               | dp replica leaves the run   |

use super::dispatch::{ClusterOptions, Dispatcher};
use super::events::DEFAULT_SUBSCRIBER_CAP;
use super::journal::{self, Journal};
use super::protocol::{error_json, JobSpec, DEFAULT_PORT};
use super::queue::{JobQueue, PushError};
use super::registry::{CancelOutcome, JobRegistry};
use super::worker::WorkerPool;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// TCP port on 127.0.0.1; 0 picks an ephemeral port.
    pub port: u16,
    /// Worker-pool size (concurrent local training jobs). 0 is allowed
    /// only with `cluster` set: a pure coordinator that runs nothing
    /// itself.
    pub workers: usize,
    /// Queue capacity; fresh submissions beyond it get a 429. Journal
    /// replay and lease-expiry requeues bypass it (jobs admitted once
    /// are never destroyed by capacity).
    pub queue_cap: usize,
    /// Path of the persistent JSONL job journal (`None` = in-memory
    /// only, the pre-journal behavior). With a journal, the job table
    /// is replayed on startup, interrupted jobs requeue from their
    /// last checkpoint, and clean shutdown compacts the file.
    pub journal: Option<String>,
    /// Enable the cluster control plane (`/cluster/*`): remote worker
    /// agents register here and the dispatcher fans queued jobs out to
    /// them. `None` = single-node; with no registered agents a cluster
    /// server behaves exactly like a single-node one.
    pub cluster: Option<ClusterOptions>,
    /// Per-subscriber event buffer for the SSE streams: a consumer
    /// this many events behind starts shedding the oldest and gets a
    /// `lagged` resync marker — the trainers never wait on a slow
    /// watcher.
    pub events_buffer: usize,
    /// Concurrent SSE streams the server will hold open; each pins a
    /// bus subscriber and a write buffer (not a thread), so the cap
    /// is generous but still bounds a runaway stream-opening client.
    /// Requests past it get a 503 (`--max-sse`).
    pub max_sse: usize,
    /// Reactor event-loop threads; 0 (the default) sizes
    /// automatically to about half the available cores, clamped to
    /// [1, 4] (`--reactor-threads`).
    pub reactor_threads: usize,
    /// Reap a connection with no read/write progress for this long —
    /// the keep-alive idle timeout, and the old per-socket timeout's
    /// successor. Healthy SSE streams are exempt (their keep-alive
    /// comments count as progress).
    pub http_idle: Duration,
    /// On shutdown the reactors flush what each client will take for
    /// at most this long before cutting stalled connections loose —
    /// a stalled SSE reader cannot delay the drain past it.
    pub drain_grace: Duration,
    /// Staged-but-unsent bytes past which an SSE connection stops
    /// pulling bus events: the slow reader then sheds at the bus
    /// (getting a `lagged` marker) instead of buffering without
    /// bound.
    pub sse_highwater: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            port: DEFAULT_PORT,
            workers: 2,
            queue_cap: 64,
            journal: None,
            cluster: None,
            events_buffer: DEFAULT_SUBSCRIBER_CAP,
            max_sse: DEFAULT_MAX_SSE,
            reactor_threads: 0,
            http_idle: Duration::from_secs(10),
            drain_grace: Duration::from_secs(5),
            sse_highwater: 256 * 1024,
        }
    }
}

/// Default [`ServeOptions::max_sse`]: thousands, not 64 — streams no
/// longer pin a thread each.
pub const DEFAULT_MAX_SSE: usize = 4096;

/// Everything a connection handler needs, shared across the acceptor
/// and the reactor threads (see [`super::reactor`]).
pub(crate) struct Gateway {
    addr: SocketAddr,
    queue: Arc<JobQueue>,
    pub(crate) registry: Arc<JobRegistry>,
    journal: Option<Arc<Journal>>,
    dispatcher: Option<Arc<Dispatcher>>,
    workers: usize,
    pub(crate) events_buffer: usize,
    pub(crate) max_sse: usize,
    pub(crate) reactor_threads: usize,
    pub(crate) http_idle: Duration,
    pub(crate) drain_grace: Duration,
    pub(crate) sse_highwater: usize,
    pub(crate) shutdown: AtomicBool,
    /// Connections currently owned by the reactors (scrape-time
    /// gauge `repro_http_open_connections`).
    pub(crate) open_conns: AtomicUsize,
    /// Open SSE streams; bounded by `max_sse`.
    pub(crate) sse_active: AtomicUsize,
}

/// A bound job server: acceptor + queue + registry + worker pool,
/// optionally backed by a persistent job journal and/or fronting a
/// cluster of remote agents.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Gateway>,
    pool: WorkerPool,
}

impl Server {
    /// Bind the listener and spawn the worker pool (jobs start flowing
    /// only once [`Server::run`] accepts submissions). With a journal
    /// configured, the previous process's job table is replayed first:
    /// terminal jobs reappear in listings, and jobs that were queued,
    /// running or interrupted go back on the queue — resuming from
    /// their last checkpoint when one exists. Replay requeue bypasses
    /// `queue_cap`: a durable backlog larger than the queue must never
    /// fail jobs at boot.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        anyhow::ensure!(
            opts.workers > 0 || opts.cluster.is_some(),
            "a server without --cluster needs at least one local worker"
        );
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr()?;
        let queue = Arc::new(JobQueue::new(opts.queue_cap));
        let (registry, jrnl, requeue) = match &opts.journal {
            None => (Arc::new(JobRegistry::new()), None, Vec::new()),
            Some(path) => {
                let mut replayed = journal::replay(path)?;
                let mut requeue = Vec::new();
                for job in &mut replayed {
                    if journal::prepare_requeue(job) {
                        requeue.push((job.id, job.spec.priority));
                    }
                }
                let j = Arc::new(Journal::open(path)?);
                let registry = Arc::new(JobRegistry::with_journal(Some(j.clone())));
                for job in replayed {
                    registry.restore(job);
                }
                // collapse the replayed event stream right away so the
                // file stays bounded across repeated restarts
                j.compact(&registry.compacted_jobs())?;
                (registry, Some(j), requeue)
            }
        };
        let dispatcher = opts
            .cluster
            .as_ref()
            .map(|c| Dispatcher::spawn(c.clone(), queue.clone(), registry.clone()));
        let pool = WorkerPool::spawn(opts.workers, queue.clone(), registry.clone());
        for (id, priority) in requeue {
            // push_admitted only refuses on a closed queue, which
            // cannot happen at boot — but never fail silently
            if !queue.push_admitted(id, priority) {
                registry.fail(id, "restart requeue rejected: queue closed".into());
            }
        }
        let shared = Arc::new(Gateway {
            addr,
            queue,
            registry,
            journal: jrnl,
            dispatcher,
            workers: opts.workers,
            events_buffer: opts.events_buffer.max(1),
            max_sse: opts.max_sse.max(1),
            reactor_threads: opts.reactor_threads,
            http_idle: opts.http_idle,
            drain_grace: opts.drain_grace,
            sse_highwater: opts.sse_highwater.max(1),
            shutdown: AtomicBool::new(false),
            open_conns: AtomicUsize::new(0),
            sse_active: AtomicUsize::new(0),
        });
        Ok(Server { listener, shared, pool })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop; every connection is handed to the nonblocking
    /// reactor pool, which owns it from then on. Returns after a
    /// `POST /shutdown`: the handler closes the queue first (so
    /// racing submissions get a truthful 503), signals the acceptor
    /// through a flag + self-connect wake-up, the reactors drain —
    /// flushing what each client will take, bounded by
    /// `ServeOptions::drain_grace` — running jobs are stop-flagged
    /// (completing as Interrupted, so the next journal replay
    /// requeues them), remote agents' jobs are interrupted
    /// coordinator-side, every worker joins, and the journal — when
    /// configured — is compacted with the final job states.
    pub fn run(self) -> Result<()> {
        let Server { listener, shared, pool } = self;
        let mut reactors = super::reactor::ReactorPool::spawn(shared.clone())?;
        for conn in listener.incoming() {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(s) => reactors.assign(s),
                Err(_) => continue,
            }
        }
        // the reactors flush + close their connections (bounded by
        // drain_grace) so in-flight journal events land before the
        // compaction below
        reactors.join();
        shared.queue.close();
        // without this, pool.join() would block for the remainder of
        // any in-flight training run
        shared.registry.stop_all_running();
        // idempotent: the shutdown handler already closed the bus, but
        // an acceptor that exits any other way must still end the SSE
        // streams instead of leaving watchers on a dead server
        shared.registry.events().close();
        if let Some(d) = &shared.dispatcher {
            d.shutdown();
        }
        pool.join();
        if let Some(j) = &shared.journal {
            j.compact(&shared.registry.compacted_jobs())?;
        }
        Ok(())
    }

    /// Drive one request through the router without a socket — the
    /// deterministic seam for tests and embedders (e.g. asserting the
    /// shutdown 503 without racing the acceptor teardown). Behaves
    /// exactly like a request over the wire, including shutdown
    /// side effects.
    pub fn inject(&self, method: &str, path: &str, body: Option<&Value>) -> (u16, Value) {
        let text = body.map(json::to_string).unwrap_or_default();
        let (path, query) = split_query(path);
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        if let ("GET", ["metrics"]) = (method, segs.as_slice()) {
            // text/plain on the wire; over this seam the exposition
            // rides as a JSON string
            return (200, Value::str(self.shared.render_metrics()));
        }
        if is_stream_route(method, &segs) {
            // the SSE endpoints write incrementally and never fit the
            // one-shot (status, body) seam
            return (501, error_json("streaming endpoint: connect over a real socket"));
        }
        let (status, v, shutdown) = self.shared.route(method, &segs, &query, text.as_bytes());
        if shutdown {
            self.shared.begin_shutdown();
            self.shared.wake();
        }
        (status, v)
    }
}

impl Gateway {
    /// Sample the scrape-time gauges (queue depth, jobs by state, SSE
    /// streams, event bus, agents, heap) into the process registry and
    /// render the Prometheus text exposition (`GET /metrics`). The
    /// counters and histograms fed at record time (requests, epochs,
    /// phases, journal appends, requeues) come along with the render.
    pub(crate) fn render_metrics(&self) -> String {
        use crate::metrics::{alloc, global};
        let m = global();
        m.gauge("repro_queue_depth", "Jobs waiting in the queue", &[])
            .set(self.queue.len() as f64);
        m.gauge("repro_http_open_connections", "Connections owned by the reactor pool", &[])
            .set(self.open_conns.load(Ordering::SeqCst) as f64);
        for (state, n) in self.registry.jobs_by_state() {
            m.gauge("repro_jobs", "Jobs in the registry by state", &[("state", state.as_str())])
                .set(n as f64);
        }
        m.gauge("repro_sse_streams_active", "Open SSE event streams", &[])
            .set(self.sse_active.load(Ordering::SeqCst) as f64);
        let events = self.registry.events();
        m.gauge("repro_events_seq", "Current event-bus sequence number", &[])
            .set(events.current_seq() as f64);
        m.gauge("repro_event_subscribers", "Live event-bus subscribers", &[])
            .set(events.subscriber_count() as f64);
        m.counter(
            "repro_sse_lagged_total",
            "Events shed from slow event-stream subscribers",
            &[],
        )
        .mirror(events.lagged_total());
        if let Some(d) = &self.dispatcher {
            m.gauge("repro_agents", "Registered cluster agents", &[]).set(d.agent_count() as f64);
        }
        m.gauge(
            "repro_mem_live_bytes",
            "Live heap bytes (tracked allocator; 0 outside the repro binary)",
            &[],
        )
        .set(alloc::live_bytes() as f64);
        m.gauge("repro_mem_peak_bytes", "Peak live heap bytes since process start", &[])
            .set(alloc::peak_bytes() as f64);
        m.counter("repro_allocs_total", "Heap allocations served by the tracked allocator", &[])
            .mirror(alloc::alloc_count());
        m.render()
    }

    /// Make the shutdown observable (queue closed, running jobs
    /// stop-flagged as interrupted, event bus closed so SSE streams
    /// end instead of holding the drain open) and raise the acceptor's
    /// flag.
    pub(crate) fn begin_shutdown(&self) {
        self.queue.close();
        self.registry.stop_all_running();
        self.registry.events().close();
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Unblock the acceptor so it notices the shutdown flag.
    pub(crate) fn wake(&self) {
        let _ = TcpStream::connect(self.addr);
    }

    pub(crate) fn route(
        &self,
        method: &str,
        segs: &[&str],
        query: &[(&str, &str)],
        body: &[u8],
    ) -> (u16, Value, bool) {
        match (method, segs) {
            ("GET", ["healthz"]) => (200, Value::obj(vec![("ok", Value::Bool(true))]), false),
            ("GET", ["stats"]) => {
                let mut v = self.registry.stats_json(self.queue.len(), self.workers);
                if let (Some(d), Value::Obj(obj)) = (&self.dispatcher, &mut v) {
                    obj.insert("agents".into(), Value::num(d.agent_count() as f64));
                }
                (200, v, false)
            }
            ("GET", ["jobs"]) => (200, self.registry.jobs_json(), false),
            ("POST", ["jobs"]) => {
                let (status, v) = self.submit(body);
                (status, v, false)
            }
            ("GET", ["jobs", id]) => match parse_id(id) {
                Some(id) => {
                    // ?history_since=E trims the epoch history to
                    // entries with epoch >= E, so pollers of long runs
                    // stop shipping ever-growing bodies (default: full)
                    let since = match qget(query, "history_since") {
                        None => None,
                        Some(s) => match s.parse::<usize>() {
                            Ok(n) => Some(n),
                            Err(_) => {
                                return (
                                    400,
                                    error_json("history_since must be an integer epoch"),
                                    false,
                                )
                            }
                        },
                    };
                    match self.registry.job_json_since(id, since) {
                        Some(v) => (200, v, false),
                        None => (404, error_json(&format!("no job {id}")), false),
                    }
                }
                None => (400, error_json("job id must be an integer"), false),
            },
            ("POST", ["jobs", id, "cancel"]) => match parse_id(id) {
                Some(id) => self.cancel(id),
                None => (400, error_json("job id must be an integer"), false),
            },
            (m, ["cluster", rest @ ..]) => {
                let (status, v) = self.route_cluster(m, rest, body);
                (status, v, false)
            }
            ("POST", ["shutdown"]) => {
                (200, Value::obj(vec![("ok", Value::Bool(true))]), true)
            }
            _ => (404, error_json(&format!("no route {method} /{}", segs.join("/"))), false),
        }
    }

    /// The `/cluster/*` control plane (404 unless the server was
    /// started with cluster mode enabled).
    fn route_cluster(&self, method: &str, segs: &[&str], body: &[u8]) -> (u16, Value) {
        let Some(d) = &self.dispatcher else {
            return (404, error_json("cluster mode disabled (start with --cluster)"));
        };
        match (method, segs) {
            ("POST", ["register"]) => d.register(body),
            ("GET", ["agents"]) => (200, d.agents_json()),
            ("POST", ["agents", aid, "poll"]) => match parse_id(aid) {
                Some(a) => d.poll(a, body),
                None => (400, error_json("agent id must be an integer")),
            },
            ("POST", ["agents", aid, "deregister"]) => match parse_id(aid) {
                Some(a) => d.deregister(a),
                None => (400, error_json("agent id must be an integer")),
            },
            ("POST", ["agents", aid, "jobs", jid, "epoch"]) => {
                match (parse_id(aid), parse_id(jid)) {
                    (Some(a), Some(j)) => d.report_epoch(a, j, body),
                    _ => (400, error_json("agent and job ids must be integers")),
                }
            }
            ("POST", ["agents", aid, "jobs", jid, "done"]) => {
                match (parse_id(aid), parse_id(jid)) {
                    (Some(a), Some(j)) => d.report_done(a, j, body),
                    _ => (400, error_json("agent and job ids must be integers")),
                }
            }
            ("POST", ["dp", jid, "join"]) => match parse_id(jid) {
                Some(j) => d.dp.join(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "step"]) => match parse_id(jid) {
                Some(j) => d.dp.step(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "commits"]) => match parse_id(jid) {
                Some(j) => d.dp.commits(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "epoch"]) => match parse_id(jid) {
                Some(j) => d.dp.epoch(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            ("POST", ["dp", jid, "leave"]) => match parse_id(jid) {
                Some(j) => d.dp.leave(j, body),
                None => (400, error_json("job id must be an integer")),
            },
            _ => (
                404,
                error_json(&format!("no route {method} /cluster/{}", segs.join("/"))),
            ),
        }
    }

    fn submit(&self, body: &[u8]) -> (u16, Value) {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return (400, error_json("body must be utf-8 JSON")),
        };
        // the pull parser is the submission hot path: differentially
        // tested against the recursive parser, allocation-bounded
        let v = match json::parse_pull(text) {
            Ok(v) => v,
            Err(e) => return (400, error_json(&format!("invalid JSON: {e}"))),
        };
        let spec = match JobSpec::from_json(&v) {
            Ok(s) => s,
            Err(e) => return (400, error_json(&format!("invalid job spec: {e:#}"))),
        };
        let priority = spec.priority;
        let id = self.registry.add(spec);
        // journal the submission BEFORE the job becomes poppable: once
        // push succeeds a worker may claim it immediately, and its
        // start/epoch/terminal events must replay after the submit
        // line. A rejected push compensates with a 'forget' event.
        self.registry.journal_submit(id);
        match self.queue.push(id, priority) {
            Ok(()) => {
                // only now is the submission real: broadcast it (a
                // rejected push below is rolled back and must never
                // surface on the event bus)
                self.registry.announce_queued(id);
                (
                    200,
                    Value::obj(vec![
                        ("id", Value::num(id as f64)),
                        ("state", Value::str("queued")),
                    ]),
                )
            }
            Err(e) => {
                // roll the record back so the rejected job never shows up
                self.registry.forget(id);
                match e {
                    PushError::Full { capacity } => (
                        429,
                        Value::obj(vec![
                            ("error", Value::str("queue full")),
                            ("capacity", Value::num(capacity as f64)),
                        ]),
                    ),
                    // shutdown in progress: not backpressure — this
                    // instance will never accept the job
                    PushError::Closed => (
                        503,
                        error_json("server shutting down; resubmit after restart"),
                    ),
                }
            }
        }
    }

    fn cancel(&self, id: u64) -> (u16, Value, bool) {
        match self.registry.cancel(id) {
            None => (404, error_json(&format!("no job {id}")), false),
            Some(outcome) => {
                let action = match outcome {
                    CancelOutcome::CancelledQueued => {
                        self.queue.remove(id);
                        "cancelled-while-queued"
                    }
                    CancelOutcome::StopRequested => "stop-requested",
                    CancelOutcome::AlreadyTerminal(_) => "already-terminal",
                };
                let state = self
                    .registry
                    .state_of(id)
                    .map(|s| s.as_str())
                    .unwrap_or("unknown");
                (
                    200,
                    Value::obj(vec![
                        ("id", Value::num(id as f64)),
                        ("action", Value::str(action)),
                        ("state", Value::str(state)),
                    ]),
                    false,
                )
            }
        }
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

/// Idle interval after which the SSE streams emit a `: keep-alive`
/// comment, so clients (and anything buffering between) can tell a
/// quiet stream from a dead connection.
pub(crate) const SSE_KEEPALIVE: Duration = Duration::from_millis(1000);

/// The long-lived SSE routes, dispatched before the one-shot router
/// (they own the connection instead of returning a `(status, body)`).
pub(crate) fn is_stream_route(method: &str, segs: &[&str]) -> bool {
    matches!((method, segs), ("GET", ["events"]) | ("GET", ["jobs", _, "events"]))
}

/// Split `path?query` and parse the `k=v&k2=v2` pairs, borrowing the
/// path (the request hot path allocates nothing here). No %-decoding:
/// every query value this server accepts is a plain integer.
pub(crate) fn split_query(path: &str) -> (&str, Vec<(&str, &str)>) {
    match path.split_once('?') {
        None => (path, Vec::new()),
        Some((p, q)) => (
            p,
            q.split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| kv.split_once('=').unwrap_or((kv, "")))
                .collect(),
        ),
    }
}

pub(crate) fn qget<'a>(query: &[(&'a str, &'a str)], key: &str) -> Option<&'a str> {
    query.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
}

/// Locate `needle` in `haystack` (the `\r\n\r\n` header-terminator
/// scan shares this with the reactor's resumable parser).
pub(crate) fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

pub(crate) fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

pub(crate) const HTTP_REQS_NAME: &str = "repro_http_requests_total";
pub(crate) const HTTP_REQS_HELP: &str =
    "HTTP requests served, by route template and status code";

/// Record one served request into the process metrics: a latency
/// histogram per route template and a request counter per
/// (route, code).
pub(crate) fn observe_http(route: &str, status: u16, elapsed: Duration) {
    let m = crate::metrics::global();
    m.histogram(
        "repro_http_request_duration_seconds",
        "HTTP request service time by route template",
        &[("route", route)],
        &crate::metrics::LATENCY_BUCKETS_S,
    )
    .observe(elapsed.as_secs_f64());
    let code = status.to_string();
    m.counter(HTTP_REQS_NAME, HTTP_REQS_HELP, &[("route", route), ("code", &code)]).inc();
}

/// Collapse a request path to a bounded route template so metric
/// cardinality can't grow with job/agent ids: dynamic segments (the
/// ones routes match with a binding) become `{}`, and anything that
/// 404'd is folded into a single "other" label.
pub(crate) fn http_route_label(method: &str, segs: &[&str], status: u16) -> String {
    if status == 404 {
        return "other".to_string();
    }
    let mut out = String::from(method);
    for s in segs {
        out.push('/');
        // Ids are the only free-form segments in the route table;
        // fixed words stay literal so routes remain tell-apart-able.
        let fixed = matches!(
            *s,
            "jobs"
                | "stats"
                | "healthz"
                | "shutdown"
                | "cancel"
                | "events"
                | "metrics"
                | "cluster"
                | "register"
                | "agents"
                | "poll"
                | "deregister"
                | "epoch"
                | "done"
                | "dp"
                | "join"
                | "step"
                | "commits"
                | "leave"
        );
        out.push_str(if fixed { s } else { "{}" });
    }
    // "GET /jobs" style: method, space, then the path.
    if let Some(rest) = out.strip_prefix(method) {
        format!("{method} {rest}")
    } else {
        out
    }
}

/// Tiny blocking HTTP/1.1 client for `repro submit|jobs|job`, the
/// cluster agent and the integration tests. Returns `(status, parsed
/// JSON body)`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Value>) -> Result<(u16, Value)> {
    request_with_timeout(addr, method, path, body, Duration::from_secs(60))
}

/// [`request`] with an explicit read timeout (the agent uses a short
/// one so a dying coordinator shows up as a failed poll, not a hang).
pub fn request_with_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Value>,
    read_timeout: Duration,
) -> Result<(u16, Value)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let body_text = body.map(json::to_string).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> Result<(u16, Value)> {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .context("malformed HTTP response (no header terminator)")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .context("missing status code")?
        .parse()
        .context("non-numeric status code")?;
    let trimmed = body.trim();
    let v = if trimmed.is_empty() {
        Value::Null
    } else {
        json::parse(trimmed).context("parsing response JSON")?
    };
    Ok((status, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn response_parsing() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\nContent-Length: 16\r\n\r\n{\"error\":\"full\"}";
        let (status, v) = parse_response(raw).unwrap();
        assert_eq!(status, 429);
        assert_eq!(v.get("error").as_str(), Some("full"));
        assert!(parse_response(b"garbage").is_err());
    }

    #[test]
    fn healthz_and_404_over_real_sockets() {
        let server = Server::bind(&ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 2,
            ..Default::default()
        })
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || server.run().unwrap());

        let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(v.get("ok").as_bool(), Some(true));

        let (status, v) = request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(status, 404);
        assert!(v.get("error").as_str().is_some());

        let (status, _) = request(&addr, "GET", "/jobs/xyz", None).unwrap();
        assert_eq!(status, 400);

        // without cluster mode the /cluster routes stay dark
        let (status, v) = request(&addr, "POST", "/cluster/register", None).unwrap();
        assert_eq!(status, 404);
        assert!(v.get("error").as_str().unwrap().contains("cluster mode disabled"));

        let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
        assert_eq!(status, 200);
        h.join().unwrap();
    }

    #[test]
    fn query_splitting_and_stream_route_detection() {
        let (p, q) = split_query("/jobs/3?history_since=2&x=1");
        assert_eq!(p, "/jobs/3");
        assert_eq!(qget(&q, "history_since"), Some("2"));
        assert_eq!(qget(&q, "x"), Some("1"));
        assert_eq!(qget(&q, "missing"), None);
        let (p, q) = split_query("/events");
        assert_eq!(p, "/events");
        assert!(q.is_empty());

        assert!(is_stream_route("GET", &["events"]));
        assert!(is_stream_route("GET", &["jobs", "7", "events"]));
        assert!(!is_stream_route("POST", &["events"]));
        assert!(!is_stream_route("GET", &["jobs", "7"]));
        assert!(!is_stream_route("GET", &["jobs"]));
    }

    #[test]
    fn inject_refuses_streaming_routes() {
        let server = Server::bind(&ServeOptions {
            port: 0,
            workers: 1,
            queue_cap: 2,
            ..Default::default()
        })
        .unwrap();
        for path in ["/events", "/events?since_seq=3", "/jobs/1/events"] {
            let (status, v) = server.inject("GET", path, None);
            assert_eq!(status, 501, "{path}");
            assert!(v.get("error").as_str().unwrap().contains("streaming"));
        }
        // the one-shot router still answers through inject
        let (status, _) = server.inject("GET", "/jobs/1?history_since=0", None);
        assert_eq!(status, 404, "no such job, but the query parses");
        let (status, _) = server.inject("GET", "/jobs/1?history_since=x", None);
        assert_eq!(status, 400);
        let (status, _) = server.inject("POST", "/shutdown", None);
        assert_eq!(status, 200);
    }

    #[test]
    fn workers_zero_requires_cluster() {
        let opts = ServeOptions { port: 0, workers: 0, queue_cap: 2, ..Default::default() };
        assert!(Server::bind(&opts).is_err());
        let opts = ServeOptions { cluster: Some(ClusterOptions::default()), ..opts };
        let server = Server::bind(&opts).unwrap();
        let (status, _) = server.inject("GET", "/healthz", None);
        assert_eq!(status, 200);
        let (status, _) = server.inject("POST", "/shutdown", None);
        assert_eq!(status, 200);
    }
}
