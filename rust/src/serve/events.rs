//! The live-telemetry event bus: every epoch and job state transition
//! the registry records is broadcast to in-process subscribers, which
//! the HTTP layer exposes as Server-Sent Events (`GET /events`,
//! `GET /jobs/{id}/events`) and `repro watch` consumes. This closes the
//! "streaming progress" ROADMAP item: operators observe a run as it
//! happens instead of polling `GET /jobs/<id>` — which, on the
//! edge-device deployments the paper targets, wastes the very
//! device/network budget the training method is built to conserve.
//!
//! # Design
//!
//! One [`EventBus`] lives inside the [`super::registry::JobRegistry`],
//! so every record point feeds it regardless of where the signal came
//! from: a local worker's `ProgressSink` callback, a remote agent's
//! `POST /cluster/agents/{a}/jobs/{j}/epoch`, a user cancel, a lease
//! -expiry requeue, a journal-replay requeue. Remote-agent jobs stream
//! exactly like local ones because both paths land in the same
//! registry methods.
//!
//! The bus never blocks a publisher:
//!
//! * each subscriber owns a **bounded** buffer ([`EventBus::subscribe`]
//!   takes the capacity); when a slow consumer overflows it, the
//!   oldest buffered events are dropped and the subscription is marked
//!   lagged — the next [`Subscriber::recv`] yields
//!   [`Poll::Lagged`] (an explicit resync marker, surfaced on the wire
//!   as an SSE `lagged` frame) before resuming with the newest events;
//! * a bounded ring of recent events (the last [`RING_CAP`]) backs the
//!   firehose's `?since_seq=` resume: a reconnecting consumer replays
//!   what the ring still holds and gets a lagged marker if its resume
//!   point has been evicted.
//!
//! Publishing happens while the registry's own lock is held (registry
//! lock → bus lock, the one global lock order), which is what makes
//! per-job streams **exactly-once**: the HTTP handler subscribes
//! first, then takes a registry snapshot that carries the bus's
//! sequence watermark ([`super::registry::JobRegistry::stream_snapshot`]);
//! replayed history covers everything at or below the watermark, the
//! live subscription everything after it, and no event can straddle
//! the boundary.

use super::protocol::JobState;
use crate::coordinator::metrics::EpochStats;
use crate::util::json::Value;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Events retained for `?since_seq=` resume on the firehose.
pub const RING_CAP: usize = 1024;

/// Default per-subscriber buffer (events pending delivery to one
/// consumer before it is marked lagged); `repro serve
/// --events-buffer N` overrides the server's value.
pub const DEFAULT_SUBSCRIBER_CAP: usize = 256;

/// One broadcast event. `data` is the full wire JSON (including
/// `seq`/`job`/`type`), so the HTTP layer serializes it verbatim.
#[derive(Debug, Clone)]
pub struct Event {
    /// Global, strictly increasing, starting at 1.
    pub seq: u64,
    pub job: u64,
    /// SSE event name: `"epoch"` or `"state"`.
    pub kind: &'static str,
    pub data: Value,
    /// The complete live SSE frame (`id:` + `event:` + `data:` lines
    /// and the blank-line terminator), rendered once at publish time:
    /// fanning an event out to N stream subscribers is N buffer
    /// copies, zero serializations and zero allocations.
    pub frame: String,
}

impl Event {
    /// For `state` events: the new state token (`"running"`, …).
    pub fn state(&self) -> Option<&str> {
        self.data.get("state").as_str()
    }
}

/// What one [`Subscriber::recv`] call yielded.
#[derive(Debug, Clone)]
pub enum Poll {
    /// The next event in order.
    Event(Arc<Event>),
    /// The subscriber's buffer overflowed and events were dropped;
    /// `next_seq` is the sequence number delivery resumes at (resync
    /// via `GET /jobs/<id>` or `GET /events?since_seq=`).
    Lagged { next_seq: u64 },
    /// Nothing arrived within the timeout (the HTTP layer's cue to
    /// write a keep-alive comment).
    Timeout,
    /// The bus shut down (server drain); no further events will come.
    Closed,
}

/// Callback a reactor registers to learn that a subscriber has
/// something to poll (called OUTSIDE the bus lock; must not block).
pub type Waker = Arc<dyn Fn() + Send + Sync>;

struct SubState {
    /// `Some(id)` = only this job's events; `None` = firehose.
    job: Option<u64>,
    buf: VecDeque<Arc<Event>>,
    cap: usize,
    lagged: bool,
    /// Poked (outside the lock) whenever this subscriber's buffer
    /// gains an event or the bus closes — how the serve reactor learns
    /// to `try_recv` without a blocking thread per stream.
    waker: Option<Waker>,
}

struct BusInner {
    next_seq: u64,
    ring: VecDeque<Arc<Event>>,
    subs: BTreeMap<u64, SubState>,
    next_sub: u64,
    closed: bool,
    /// Lifetime total of events shed from slow subscribers' buffers
    /// (each shed also marks the victim's `lagged` flag). Surfaced in
    /// `GET /stats` and mirrored into `repro_sse_lagged_total`.
    shed_total: u64,
}

/// Broadcast bus: publishers never block, slow consumers lose events
/// (and learn it), the ring answers short-horizon replays.
pub struct EventBus {
    inner: Mutex<BusInner>,
    cv: Condvar,
}

impl Default for EventBus {
    fn default() -> Self {
        EventBus::new()
    }
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus {
            inner: Mutex::new(BusInner {
                next_seq: 1,
                ring: VecDeque::new(),
                subs: BTreeMap::new(),
                next_sub: 1,
                closed: false,
                shed_total: 0,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, BusInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Sequence number of the most recently published event (0 before
    /// the first). Used as the replay/live watermark by
    /// [`super::registry::JobRegistry::stream_snapshot`].
    pub fn current_seq(&self) -> u64 {
        self.lock().next_seq - 1
    }

    /// Number of live subscriptions (SSE streams + in-process watchers).
    pub fn subscriber_count(&self) -> usize {
        self.lock().subs.len()
    }

    /// Lifetime total of events shed from slow subscribers (monotone).
    pub fn lagged_total(&self) -> u64 {
        self.lock().shed_total
    }

    fn publish(&self, job: u64, kind: &'static str, extra: Vec<(&str, Value)>) {
        let mut wakers: Vec<Waker> = Vec::new();
        {
            let mut st = self.lock();
            if st.closed {
                return;
            }
            let seq = st.next_seq;
            st.next_seq += 1;
            let mut pairs = vec![
                ("type", Value::str(kind)),
                ("seq", Value::num(seq as f64)),
                ("job", Value::num(job as f64)),
            ];
            pairs.extend(extra);
            let data = Value::obj(pairs);
            // render the wire frame ONCE here; every stream subscriber
            // copies these bytes instead of re-serializing the Value
            use std::fmt::Write as _;
            let mut frame = String::with_capacity(96);
            let _ = write!(frame, "id: {seq}\nevent: {kind}\ndata: ");
            crate::util::json::write_compact(&data, &mut frame);
            frame.push_str("\n\n");
            let ev = Arc::new(Event { seq, job, kind, data, frame });
            st.ring.push_back(ev.clone());
            while st.ring.len() > RING_CAP {
                st.ring.pop_front();
            }
            let mut shed = 0u64;
            for sub in st.subs.values_mut() {
                if sub.job.is_some_and(|j| j != job) {
                    continue;
                }
                // never block the publisher: a full buffer sheds its
                // oldest event and marks the subscription lagged
                if sub.buf.len() >= sub.cap {
                    sub.buf.pop_front();
                    sub.lagged = true;
                    shed += 1;
                }
                sub.buf.push_back(ev.clone());
                if let Some(w) = &sub.waker {
                    // one poke per reactor is enough: dedupe by pointer
                    if !wakers.iter().any(|x| Arc::ptr_eq(x, w)) {
                        wakers.push(w.clone());
                    }
                }
            }
            st.shed_total += shed;
        }
        // wakers and condvar both fire AFTER the lock drops: a reactor
        // woken here can immediately try_recv without contention
        for w in &wakers {
            w();
        }
        self.cv.notify_all();
    }

    /// One epoch completed on `job` (local worker sink or remote
    /// agent report — indistinguishable here on purpose).
    pub fn publish_epoch(&self, job: u64, stats: &EpochStats) {
        self.publish(job, "epoch", vec![("stats", stats.to_json())]);
    }

    /// `job` entered `state`; `error` rides along on failures.
    pub fn publish_state(&self, job: u64, state: &str, error: Option<&str>) {
        let mut extra = vec![("state", Value::str(state))];
        if let Some(e) = error {
            extra.push(("error", Value::str(e)));
        }
        self.publish(job, "state", extra);
    }

    /// Subscribe to live events — `job = Some(id)` for one job's
    /// stream, `None` for the firehose. `cap` bounds the pending
    /// buffer; overflow drops oldest events and yields a
    /// [`Poll::Lagged`] marker instead of ever blocking a publisher.
    pub fn subscribe(self: &Arc<Self>, job: Option<u64>, cap: usize) -> Subscriber {
        let id = {
            let mut st = self.lock();
            let id = st.next_sub;
            st.next_sub += 1;
            st.subs.insert(
                id,
                SubState {
                    job,
                    buf: VecDeque::new(),
                    cap: cap.max(1),
                    lagged: false,
                    waker: None,
                },
            );
            id
        };
        Subscriber { bus: self.clone(), id }
    }

    /// Firehose subscription with `?since_seq=` resume, atomically:
    /// returns the live [`Subscriber`], the ring-buffered backlog of
    /// events with `seq > since_seq`, whether a gap precedes the
    /// backlog — the resume point was evicted from the ring, or is
    /// beyond the current sequence (sequences restart at 1 on every
    /// boot, so that means a stale lineage from a previous process,
    /// not a caught-up consumer; detection is best-effort — a restart
    /// that has already published past the saved cursor is
    /// indistinguishable from a continuation) — and the sequence
    /// delivery actually resumes at (the first backlog seq, or the
    /// next live seq when there is nothing to replay). All four values
    /// are taken under one bus lock, so the resume seq the `lagged`
    /// frame reports can never trail an event the subscription later
    /// delivers.
    pub fn subscribe_since(
        self: &Arc<Self>,
        cap: usize,
        since_seq: u64,
    ) -> (Subscriber, Vec<Arc<Event>>, bool, u64) {
        let (id, backlog, gap, resume_seq) = {
            let mut st = self.lock();
            let backlog: Vec<Arc<Event>> =
                st.ring.iter().filter(|e| e.seq > since_seq).cloned().collect();
            let first_missed = since_seq + 1;
            let resume_seq = match backlog.first() {
                Some(e) => e.seq,
                // nothing to replay: delivery resumes at the next live
                // event; a gap exists iff events beyond the resume
                // point ever happened (or the point is a stale lineage)
                None => st.next_seq,
            };
            let gap = resume_seq > first_missed || since_seq >= st.next_seq;
            let id = st.next_sub;
            st.next_sub += 1;
            st.subs.insert(
                id,
                SubState {
                    job: None,
                    buf: VecDeque::new(),
                    cap: cap.max(1),
                    lagged: false,
                    waker: None,
                },
            );
            (id, backlog, gap, resume_seq)
        };
        (Subscriber { bus: self.clone(), id }, backlog, gap, resume_seq)
    }

    /// Server shutdown: every subscriber's next poll (after its buffer
    /// drains) yields [`Poll::Closed`] and further publishes are
    /// dropped. Registered wakers fire so reactors notice immediately.
    pub fn close(&self) {
        let mut wakers: Vec<Waker> = Vec::new();
        {
            let mut st = self.lock();
            st.closed = true;
            for sub in st.subs.values() {
                if let Some(w) = &sub.waker {
                    if !wakers.iter().any(|x| Arc::ptr_eq(x, w)) {
                        wakers.push(w.clone());
                    }
                }
            }
        }
        for w in &wakers {
            w();
        }
        self.cv.notify_all();
    }
}

/// A live subscription handle; dropping it unregisters from the bus.
pub struct Subscriber {
    bus: Arc<EventBus>,
    id: u64,
}

impl Subscriber {
    /// Next delivery, waiting up to `timeout`: buffered events first
    /// (preceded by a [`Poll::Lagged`] marker when the buffer
    /// overflowed since the last call), then [`Poll::Timeout`] /
    /// [`Poll::Closed`].
    pub fn recv(&self, timeout: Duration) -> Poll {
        let deadline = Instant::now() + timeout;
        let mut st = self.bus.lock();
        loop {
            {
                // deref the guard once so the subscriber entry and the
                // bus counters can be borrowed field-disjointly
                let inner: &mut BusInner = &mut st;
                let Some(sub) = inner.subs.get_mut(&self.id) else {
                    return Poll::Closed;
                };
                if sub.lagged {
                    sub.lagged = false;
                    let next_seq = match sub.buf.front() {
                        Some(e) => e.seq,
                        None => inner.next_seq,
                    };
                    return Poll::Lagged { next_seq };
                }
                if let Some(e) = sub.buf.pop_front() {
                    return Poll::Event(e);
                }
                if inner.closed {
                    return Poll::Closed;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return Poll::Timeout;
            }
            let (guard, _timed_out) = self
                .bus
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Non-blocking [`Subscriber::recv`]: the next buffered delivery,
    /// or [`Poll::Timeout`] immediately when nothing is pending. The
    /// serve reactor drives every SSE stream with this (one thread,
    /// thousands of subscribers) after a [`Subscriber::set_waker`]
    /// poke.
    pub fn try_recv(&self) -> Poll {
        let mut st = self.bus.lock();
        let inner: &mut BusInner = &mut st;
        let Some(sub) = inner.subs.get_mut(&self.id) else {
            return Poll::Closed;
        };
        if sub.lagged {
            sub.lagged = false;
            let next_seq = match sub.buf.front() {
                Some(e) => e.seq,
                None => inner.next_seq,
            };
            return Poll::Lagged { next_seq };
        }
        if let Some(e) = sub.buf.pop_front() {
            return Poll::Event(e);
        }
        if inner.closed {
            return Poll::Closed;
        }
        Poll::Timeout
    }

    /// Register (or replace) the callback poked — outside the bus lock
    /// — whenever this subscription gains a delivery or the bus
    /// closes. Several subscribers may share one waker; the publisher
    /// dedupes by pointer so a reactor is poked once per event.
    pub fn set_waker(&self, waker: Waker) {
        if let Some(sub) = self.bus.lock().subs.get_mut(&self.id) {
            sub.waker = Some(waker);
        }
    }
}

impl Drop for Subscriber {
    fn drop(&mut self) {
        self.bus.lock().subs.remove(&self.id);
    }
}

// ---------------------------------------------------------------------
// Client side: the SSE consumer behind `repro watch`.

/// One decoded frame as a watching client sees it.
#[derive(Debug, Clone)]
pub enum WatchFrame {
    /// An epoch record; `replay` marks history re-sent at connect
    /// time (no live sequence number).
    Epoch { replay: bool, stats: EpochStats },
    /// A job state transition (`queued`/`running`/…); `replay` marks
    /// the connect-time snapshot frame.
    State { replay: bool, state: String, error: Option<String> },
    /// The server dropped events for this consumer (it fell behind);
    /// delivery resumed at bus sequence `next_seq`.
    Lagged { next_seq: u64 },
}

/// One wire-level SSE frame (before [`WatchFrame`] classification).
pub struct SseFrame {
    pub event: String,
    pub id: Option<u64>,
    pub data: Option<Value>,
}

/// Incremental SSE decoder: feed it raw bytes as they arrive, get
/// complete frames back. Keep-alive comment frames are swallowed.
#[derive(Default)]
pub struct SseParser {
    buf: Vec<u8>,
}

impl SseParser {
    pub fn push(&mut self, chunk: &[u8]) -> Vec<SseFrame> {
        self.buf.extend_from_slice(chunk);
        let mut out = Vec::new();
        loop {
            let Some(pos) = self.buf.windows(2).position(|w| w == b"\n\n") else {
                return out;
            };
            let frame: Vec<u8> = self.buf.drain(..pos + 2).collect();
            // a frame is complete, so its bytes are whole UTF-8
            if let Ok(text) = std::str::from_utf8(&frame[..pos]) {
                if let Some(f) = parse_sse_frame(text) {
                    out.push(f);
                }
            }
        }
    }
}

/// `None` for comment-only frames (keep-alives).
fn parse_sse_frame(text: &str) -> Option<SseFrame> {
    let mut f = SseFrame { event: String::new(), id: None, data: None };
    let mut any_field = false;
    for line in text.lines() {
        if line.starts_with(':') {
            continue; // comment (keep-alive)
        }
        let Some((k, v)) = line.split_once(':') else { continue };
        let v = v.strip_prefix(' ').unwrap_or(v);
        any_field = true;
        match k {
            "event" => f.event = v.to_string(),
            "id" => f.id = v.parse().ok(),
            "data" => f.data = crate::util::json::parse(v).ok(),
            _ => {}
        }
    }
    any_field.then_some(f)
}

/// Decode a wire frame into the typed [`WatchFrame`]; unknown or
/// malformed frames are skipped (forward compatibility).
fn classify(f: &SseFrame) -> Option<WatchFrame> {
    let data = f.data.as_ref()?;
    let replay = data.get("replay").as_bool().unwrap_or(false);
    match f.event.as_str() {
        "epoch" => EpochStats::from_json(data.get("stats"))
            .ok()
            .map(|stats| WatchFrame::Epoch { replay, stats }),
        "state" => data.get("state").as_str().map(|s| WatchFrame::State {
            replay,
            state: s.to_string(),
            error: data.get("error").as_str().map(str::to_string),
        }),
        "lagged" => Some(WatchFrame::Lagged {
            next_seq: data.get("next_seq").as_f64().unwrap_or(0.0) as u64,
        }),
        _ => None,
    }
}

/// `repro watch`: connect to `GET /jobs/{job}/events` on `addr`,
/// hand every decoded frame to `on`, and return the job's final state
/// once the server closes the stream at a terminal transition. A
/// stream that ends any other way — server shutdown mid-run, network
/// drop — is an error, so the CLI exits nonzero unless the job
/// actually finished.
pub fn watch_job(
    addr: &str,
    job: u64,
    mut on: impl FnMut(&WatchFrame),
) -> Result<JobState> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    // keep-alives arrive every second; a generous read timeout makes a
    // dead server an error instead of a hang
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let req = format!(
        "GET /jobs/{job}/events HTTP/1.1\r\nHost: {addr}\r\nAccept: text/event-stream\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes())?;

    // response head first: non-200s carry a one-shot JSON error body
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut tmp).context("reading response header")?;
        anyhow::ensure!(n > 0, "server closed the connection before responding");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .context("malformed response status line")?
        .parse()
        .context("non-numeric status code")?;
    if status != 200 {
        let mut rest = buf[header_end + 4..].to_vec();
        let _ = stream.read_to_end(&mut rest);
        let body = String::from_utf8_lossy(&rest);
        let msg = crate::util::json::parse(body.trim())
            .ok()
            .and_then(|v| v.get("error").as_str().map(str::to_string))
            .unwrap_or_else(|| body.trim().to_string());
        anyhow::bail!("server returned {status}: {msg}");
    }

    let mut parser = SseParser::default();
    let mut pending = parser.push(&buf[header_end + 4..]);
    let mut last_state: Option<JobState> = None;
    loop {
        for frame in std::mem::take(&mut pending) {
            if let Some(wf) = classify(&frame) {
                if let WatchFrame::State { state, .. } = &wf {
                    // an unknown token (newer server version) must not
                    // clobber a terminal state already seen
                    if let Ok(s) = JobState::parse(state) {
                        last_state = Some(s);
                    }
                }
                on(&wf);
            }
        }
        if last_state.is_some_and(|s| s.is_terminal()) {
            // the server closes right after the terminal frame; no
            // need to wait for the FIN to land
            break;
        }
        let n = stream
            .read(&mut tmp)
            .context("reading event stream (no data or keep-alives for 30 s)")?;
        if n == 0 {
            break; // server closed the stream
        }
        pending = parser.push(&tmp[..n]);
    }
    match last_state {
        Some(s) if s.is_terminal() => Ok(s),
        other => anyhow::bail!(
            "event stream ended before the job reached a terminal state \
             (server shutdown or connection loss; last seen: {})",
            other.map(|s| s.as_str()).unwrap_or("nothing")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TICK: Duration = Duration::from_millis(10);
    const WAIT: Duration = Duration::from_secs(5);

    fn stats(epoch: usize) -> EpochStats {
        EpochStats { epoch, test_acc: 0.5, ..Default::default() }
    }

    fn expect_event(p: Poll) -> Arc<Event> {
        match p {
            Poll::Event(e) => e,
            other => panic!("expected an event, got {other:?}"),
        }
    }

    #[test]
    fn delivers_in_order_with_filter() {
        let bus = Arc::new(EventBus::new());
        let all = bus.subscribe(None, 16);
        let only7 = bus.subscribe(Some(7), 16);
        bus.publish_state(7, "running", None);
        bus.publish_epoch(9, &stats(0));
        bus.publish_epoch(7, &stats(0));

        let e = expect_event(all.recv(WAIT));
        assert_eq!((e.seq, e.job, e.kind), (1, 7, "state"));
        assert_eq!(e.state(), Some("running"));
        let e = expect_event(all.recv(WAIT));
        assert_eq!((e.seq, e.job, e.kind), (2, 9, "epoch"));
        assert_eq!(e.data.get("stats").get("epoch").as_usize(), Some(0));
        let e = expect_event(all.recv(WAIT));
        assert_eq!(e.seq, 3);

        // the filtered subscriber only saw job 7
        let e = expect_event(only7.recv(WAIT));
        assert_eq!((e.seq, e.job), (1, 7));
        let e = expect_event(only7.recv(WAIT));
        assert_eq!((e.seq, e.job), (3, 7));
        assert!(matches!(only7.recv(TICK), Poll::Timeout));
        assert_eq!(bus.current_seq(), 3);
    }

    #[test]
    fn overflow_drops_oldest_and_marks_lagged() {
        let bus = Arc::new(EventBus::new());
        let sub = bus.subscribe(None, 3);
        for i in 0..10 {
            bus.publish_epoch(1, &stats(i)); // never blocks
        }
        // first delivery is the explicit resync marker…
        match sub.recv(WAIT) {
            Poll::Lagged { next_seq } => assert_eq!(next_seq, 8),
            other => panic!("expected Lagged, got {other:?}"),
        }
        // …then the newest `cap` events, in order
        for seq in 8..=10 {
            assert_eq!(expect_event(sub.recv(WAIT)).seq, seq);
        }
        assert!(matches!(sub.recv(TICK), Poll::Timeout));
        // back to normal delivery afterwards
        bus.publish_epoch(1, &stats(10));
        assert_eq!(expect_event(sub.recv(WAIT)).seq, 11);
    }

    #[test]
    fn shed_total_and_subscriber_count_introspection() {
        let bus = Arc::new(EventBus::new());
        assert_eq!(bus.subscriber_count(), 0);
        assert_eq!(bus.lagged_total(), 0);
        let slow = bus.subscribe(None, 3);
        assert_eq!(bus.subscriber_count(), 1);
        for i in 0..10 {
            bus.publish_epoch(1, &stats(i));
        }
        // cap 3, 10 published: 7 shed from the slow subscriber
        assert_eq!(bus.lagged_total(), 7);
        drop(slow);
        assert_eq!(bus.subscriber_count(), 0);
        // the lifetime total survives the subscriber's departure
        assert_eq!(bus.lagged_total(), 7);
    }

    #[test]
    fn since_seq_resume_replays_ring_and_flags_gaps() {
        let bus = Arc::new(EventBus::new());
        for i in 0..5 {
            bus.publish_epoch(1, &stats(i)); // seqs 1..=5
        }
        // resume from 2: replay 3,4,5; no gap
        let (sub, backlog, gap, resume) = bus.subscribe_since(16, 2);
        assert!(!gap);
        assert_eq!(resume, 3, "delivery resumes at the first backlog seq");
        assert_eq!(backlog.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![3, 4, 5]);
        bus.publish_epoch(1, &stats(5));
        assert_eq!(expect_event(sub.recv(WAIT)).seq, 6);

        // resume from now (= current_seq): empty backlog, no gap
        let (_sub, backlog, gap, resume) = bus.subscribe_since(16, bus.current_seq());
        assert!(backlog.is_empty() && !gap);
        assert_eq!(resume, bus.current_seq() + 1, "caught up: next live seq");

        // a resume point beyond the current sequence is a stale
        // lineage (sequences restart at 1 on every server boot): the
        // consumer must get a lagged marker, not silent "caught up"
        let (_sub, backlog, gap, resume) = bus.subscribe_since(16, bus.current_seq() + 500);
        assert!(backlog.is_empty());
        assert!(gap, "a since_seq from a previous process must flag a gap");
        assert_eq!(resume, bus.current_seq() + 1, "delivery restarts at the live lineage");
    }

    #[test]
    fn evicted_resume_point_reports_a_gap() {
        let bus = Arc::new(EventBus::new());
        for i in 0..(RING_CAP + 10) {
            bus.publish_epoch(1, &stats(i));
        }
        // seq 1 left the ring long ago
        let (_sub, backlog, gap, resume) = bus.subscribe_since(16, 0);
        assert!(gap, "the evicted resume point must be reported");
        assert_eq!(backlog.len(), RING_CAP);
        assert_eq!(backlog[0].seq as usize, 11);
        assert_eq!(resume, backlog[0].seq, "the lagged frame names the first delivered seq");
    }

    #[test]
    fn close_wakes_and_finishes_subscribers() {
        let bus = Arc::new(EventBus::new());
        let sub = bus.subscribe(None, 4);
        bus.publish_epoch(1, &stats(0));
        let b2 = bus.clone();
        let h = std::thread::spawn(move || b2.close());
        // buffered events still drain before Closed
        assert!(matches!(sub.recv(WAIT), Poll::Event(_)));
        h.join().unwrap();
        assert!(matches!(sub.recv(WAIT), Poll::Closed));
        // publishing after close is a silent no-op
        bus.publish_epoch(1, &stats(1));
        assert_eq!(bus.current_seq(), 1);
    }

    #[test]
    fn sse_parser_decodes_split_frames_and_skips_keepalives() {
        let mut p = SseParser::default();
        // frames arrive in arbitrary chunks, including mid-line splits
        let wire = "id: 4\nevent: epoch\ndata: {\"type\":\"epoch\",\"job\":1,\"stats\":{\"epoch\":0}}\n\n\
                    : keep-alive\n\n\
                    event: state\ndata: {\"type\":\"state\",\"job\":1,\"state\":\"done\",\"replay\":true}\n\n";
        let (a, b) = wire.as_bytes().split_at(17);
        let mut frames = p.push(a);
        frames.extend(p.push(b));
        assert_eq!(frames.len(), 2, "keep-alive comments are not frames");
        assert_eq!(frames[0].event, "epoch");
        assert_eq!(frames[0].id, Some(4));
        match classify(&frames[0]) {
            Some(WatchFrame::Epoch { replay, stats }) => {
                assert!(!replay);
                assert_eq!(stats.epoch, 0);
            }
            other => panic!("bad classification: {other:?}"),
        }
        match classify(&frames[1]) {
            Some(WatchFrame::State { replay, state, error }) => {
                assert!(replay);
                assert_eq!(state, "done");
                assert!(error.is_none());
            }
            other => panic!("bad classification: {other:?}"),
        }
    }

    #[test]
    fn sse_parser_decodes_lagged_marker() {
        let mut p = SseParser::default();
        let frames =
            p.push(b"event: lagged\ndata: {\"type\":\"lagged\",\"next_seq\":42}\n\n");
        match classify(&frames[0]) {
            Some(WatchFrame::Lagged { next_seq }) => assert_eq!(next_seq, 42),
            other => panic!("bad classification: {other:?}"),
        }
    }

    #[test]
    fn try_recv_and_wakers_drive_a_pollless_consumer() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let bus = Arc::new(EventBus::new());
        let sub = bus.subscribe(None, 4);
        assert!(matches!(sub.try_recv(), Poll::Timeout), "empty bus: immediate Timeout");
        let pokes = Arc::new(AtomicUsize::new(0));
        let p = pokes.clone();
        sub.set_waker(Arc::new(move || {
            p.fetch_add(1, Ordering::SeqCst);
        }));
        bus.publish_epoch(1, &stats(0));
        assert_eq!(pokes.load(Ordering::SeqCst), 1, "publish pokes the waker");
        let e = expect_event(sub.try_recv());
        // the pre-rendered frame is the full wire format, and its data
        // line round-trips to exactly the event's Value
        assert!(e.frame.starts_with("id: 1\nevent: epoch\ndata: {"), "{}", e.frame);
        assert!(e.frame.ends_with("\n\n"));
        let data_line =
            e.frame.lines().nth(2).and_then(|l| l.strip_prefix("data: ")).unwrap();
        assert_eq!(crate::util::json::parse(data_line).unwrap(), e.data);
        assert!(matches!(sub.try_recv(), Poll::Timeout));
        bus.close();
        assert_eq!(pokes.load(Ordering::SeqCst), 2, "close pokes the waker too");
        assert!(matches!(sub.try_recv(), Poll::Closed));
    }

    #[test]
    fn dropped_subscriber_unregisters() {
        let bus = Arc::new(EventBus::new());
        let sub = bus.subscribe(None, 4);
        drop(sub);
        bus.publish_epoch(1, &stats(0));
        assert_eq!(bus.lock().subs.len(), 0);
    }
}
