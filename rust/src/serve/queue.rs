//! Bounded MPMC job queue: priority + FIFO ordering on
//! `std::sync::{Mutex, Condvar}`. `push` never blocks — a full queue is
//! backpressure, reported to the submitter as a structured 429, and a
//! closed queue (shutdown) is a distinct 503 — while `pop` parks worker
//! threads until work arrives or the queue closes. The cluster
//! dispatcher uses the non-blocking `try_pop`, and journal-replay /
//! lease-expiry requeues re-enter through the capacity-bypassing
//! `push_admitted` (jobs already admitted once are never destroyed by
//! a smaller `queue_cap`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Heap entry: max-priority first, then FIFO (lowest sequence) within a
/// priority level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    priority: i64,
    seq: u64,
    job_id: u64,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Rejection on `push`. The two cases are different truths and map to
/// different HTTP statuses: `Full` is backpressure (429 — retry later),
/// `Closed` means the server is shutting down (503 — this instance
/// will never accept the job, resubmit elsewhere/after restart).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// At capacity — transient backpressure.
    Full { capacity: usize },
    /// The queue is closed (shutdown in progress) and rejects forever.
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PushError::Full { capacity } => {
                write!(f, "job queue full (capacity {capacity})")
            }
            PushError::Closed => write!(f, "job queue closed (server shutting down)"),
        }
    }
}

impl std::error::Error for PushError {}

struct State {
    heap: BinaryHeap<Entry>,
    seq: u64,
    closed: bool,
}

pub struct JobQueue {
    capacity: usize,
    state: Mutex<State>,
    cv: Condvar,
}

impl JobQueue {
    pub fn new(capacity: usize) -> JobQueue {
        assert!(capacity > 0, "queue capacity must be positive");
        JobQueue {
            capacity,
            state: Mutex::new(State { heap: BinaryHeap::new(), seq: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current queue depth (jobs waiting, not counting running ones).
    pub fn len(&self) -> usize {
        self.lock().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue without blocking; [`PushError::Full`] is the
    /// backpressure signal when at capacity, [`PushError::Closed`]
    /// the truthful rejection once shutdown has begun.
    pub fn push(&self, job_id: u64, priority: i64) -> Result<(), PushError> {
        let mut st = self.lock();
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.heap.len() >= self.capacity {
            return Err(PushError::Full { capacity: self.capacity });
        }
        let seq = st.seq;
        st.seq += 1;
        st.heap.push(Entry { priority, seq, job_id });
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Enqueue a job that was already admitted in a previous life —
    /// journal-replay requeue at boot and lease-expiry requeue of a
    /// lost agent's jobs. Bypasses the capacity check on purpose:
    /// replaying a durable backlog must never destroy jobs just
    /// because it is larger than `queue_cap` (fresh submissions still
    /// see backpressure, so the overshoot is bounded by the replayed
    /// set). Returns `false` only when the queue is closed.
    pub fn push_admitted(&self, job_id: u64, priority: i64) -> bool {
        let mut st = self.lock();
        if st.closed {
            return false;
        }
        let seq = st.seq;
        st.seq += 1;
        st.heap.push(Entry { priority, seq, job_id });
        drop(st);
        self.cv.notify_one();
        true
    }

    /// Block until a job is available (highest priority, FIFO within) or
    /// the queue is closed. `None` means "closed: worker should exit";
    /// jobs still queued at close time are abandoned to the registry's
    /// terminal bookkeeping.
    pub fn pop(&self) -> Option<u64> {
        let mut st = self.lock();
        loop {
            if st.closed {
                return None;
            }
            if let Some(e) = st.heap.pop() {
                return Some(e.job_id);
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking pop — the cluster dispatcher hands work to polling
    /// agents from a request handler and must never park there. Like
    /// [`JobQueue::pop`], a closed queue yields nothing: an agent poll
    /// racing the shutdown must not walk off with a job the restart
    /// replay is about to requeue (it would end terminally Cancelled
    /// instead of Interrupted).
    pub fn try_pop(&self) -> Option<u64> {
        let mut st = self.lock();
        if st.closed {
            return None;
        }
        st.heap.pop().map(|e| e.job_id)
    }

    /// Drop a queued job (cancellation before a worker claimed it).
    /// Returns true if it was still queued.
    pub fn remove(&self, job_id: u64) -> bool {
        let mut st = self.lock();
        let before = st.heap.len();
        let kept: Vec<Entry> = st.heap.drain().filter(|e| e.job_id != job_id).collect();
        st.heap = kept.into();
        st.heap.len() != before
    }

    /// Close the queue: wake every parked worker so the pool can exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_priority_and_priority_first() {
        let q = JobQueue::new(8);
        q.push(1, 0).unwrap();
        q.push(2, 0).unwrap();
        q.push(3, 5).unwrap();
        q.push(4, 5).unwrap();
        assert_eq!(q.pop(), Some(3)); // higher priority first
        assert_eq!(q.pop(), Some(4)); // FIFO within priority 5
        assert_eq!(q.pop(), Some(1)); // then FIFO at priority 0
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        let q = JobQueue::new(2);
        q.push(1, 0).unwrap();
        q.push(2, 0).unwrap();
        let err = q.push(3, 99).unwrap_err();
        assert_eq!(err, PushError::Full { capacity: 2 });
        assert!(err.to_string().contains("capacity 2"));
        // draining makes room again
        assert_eq!(q.pop(), Some(1));
        q.push(3, 0).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = Arc::new(JobQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        // give the worker a moment to park, then close
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
        // a closed queue reports Closed, never the misleading Full
        assert_eq!(q.push(9, 0), Err(PushError::Closed));
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = JobQueue::new(2);
        assert_eq!(q.try_pop(), None);
        q.push(5, 0).unwrap();
        assert_eq!(q.try_pop(), Some(5));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn admitted_push_bypasses_capacity_but_not_close() {
        let q = JobQueue::new(1);
        q.push(1, 0).unwrap();
        assert_eq!(q.push(2, 0), Err(PushError::Full { capacity: 1 }));
        // replay/requeue path: over-capacity but admitted
        assert!(q.push_admitted(2, 5));
        assert_eq!(q.len(), 2);
        // ordering rules still apply to admitted entries
        assert_eq!(q.try_pop(), Some(2));
        q.close();
        assert!(!q.push_admitted(3, 0), "a closed queue admits nothing");
        assert_eq!(q.try_pop(), None, "a closed queue hands out nothing");
    }

    #[test]
    fn remove_cancels_queued_entry() {
        let q = JobQueue::new(4);
        q.push(1, 0).unwrap();
        q.push(2, 1).unwrap();
        assert!(q.remove(2));
        assert!(!q.remove(2));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(1));
    }
}
