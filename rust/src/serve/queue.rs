//! Bounded MPMC job queue: priority + FIFO ordering on
//! `std::sync::{Mutex, Condvar}`. `push` never blocks — a full queue is
//! backpressure, reported to the submitter as a structured 429 — while
//! `pop` parks worker threads until work arrives or the queue closes.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Heap entry: max-priority first, then FIFO (lowest sequence) within a
/// priority level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    priority: i64,
    seq: u64,
    job_id: u64,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Rejection on `push` when the queue is at capacity (or closed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull {
    pub capacity: usize,
}

impl fmt::Display for QueueFull {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job queue full (capacity {})", self.capacity)
    }
}

impl std::error::Error for QueueFull {}

struct State {
    heap: BinaryHeap<Entry>,
    seq: u64,
    closed: bool,
}

pub struct JobQueue {
    capacity: usize,
    state: Mutex<State>,
    cv: Condvar,
}

impl JobQueue {
    pub fn new(capacity: usize) -> JobQueue {
        assert!(capacity > 0, "queue capacity must be positive");
        JobQueue {
            capacity,
            state: Mutex::new(State { heap: BinaryHeap::new(), seq: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current queue depth (jobs waiting, not counting running ones).
    pub fn len(&self) -> usize {
        self.lock().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue without blocking; `Err(QueueFull)` is the backpressure
    /// signal when at capacity (a closed queue also rejects).
    pub fn push(&self, job_id: u64, priority: i64) -> Result<(), QueueFull> {
        let mut st = self.lock();
        if st.closed || st.heap.len() >= self.capacity {
            return Err(QueueFull { capacity: self.capacity });
        }
        let seq = st.seq;
        st.seq += 1;
        st.heap.push(Entry { priority, seq, job_id });
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Block until a job is available (highest priority, FIFO within) or
    /// the queue is closed. `None` means "closed: worker should exit";
    /// jobs still queued at close time are abandoned to the registry's
    /// terminal bookkeeping.
    pub fn pop(&self) -> Option<u64> {
        let mut st = self.lock();
        loop {
            if st.closed {
                return None;
            }
            if let Some(e) = st.heap.pop() {
                return Some(e.job_id);
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Drop a queued job (cancellation before a worker claimed it).
    /// Returns true if it was still queued.
    pub fn remove(&self, job_id: u64) -> bool {
        let mut st = self.lock();
        let before = st.heap.len();
        let kept: Vec<Entry> = st.heap.drain().filter(|e| e.job_id != job_id).collect();
        st.heap = kept.into();
        st.heap.len() != before
    }

    /// Close the queue: wake every parked worker so the pool can exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_priority_and_priority_first() {
        let q = JobQueue::new(8);
        q.push(1, 0).unwrap();
        q.push(2, 0).unwrap();
        q.push(3, 5).unwrap();
        q.push(4, 5).unwrap();
        assert_eq!(q.pop(), Some(3)); // higher priority first
        assert_eq!(q.pop(), Some(4)); // FIFO within priority 5
        assert_eq!(q.pop(), Some(1)); // then FIFO at priority 0
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn full_queue_rejects_with_backpressure() {
        let q = JobQueue::new(2);
        q.push(1, 0).unwrap();
        q.push(2, 0).unwrap();
        let err = q.push(3, 99).unwrap_err();
        assert_eq!(err.capacity, 2);
        assert!(err.to_string().contains("capacity 2"));
        // draining makes room again
        assert_eq!(q.pop(), Some(1));
        q.push(3, 0).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = Arc::new(JobQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        // give the worker a moment to park, then close
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
        assert!(q.push(9, 0).is_err(), "closed queue must reject");
    }

    #[test]
    fn remove_cancels_queued_entry() {
        let q = JobQueue::new(4);
        q.push(1, 0).unwrap();
        q.push(2, 1).unwrap();
        assert!(q.remove(2));
        assert!(!q.remove(2));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(1));
    }
}
