//! The remote worker agent (`repro agent`): the device side of
//! multi-node sharding. An agent registers with a cluster-enabled
//! coordinator (`repro serve --cluster`), then pulls work over the
//! same std-only HTTP/JSON stack the local CLI clients use:
//!
//! 1. `POST /cluster/register` → agent id + lease duration;
//! 2. poll loop (`POST /cluster/agents/{id}/poll`, the heartbeat):
//!    each answer carries job assignments — a serialized
//!    [`JobSpec`](super::protocol::JobSpec), i.e. exactly the
//!    `TrainSpec` + data/backend keys `repro train` accepts — and
//!    stop requests for running jobs;
//! 3. every assignment runs on its own thread through the very same
//!    [`launch::run`] path as `repro train` and the coordinator's
//!    local workers, with a `ProgressSink` that POSTs each epoch back
//!    and a terminal `done` report at the end.
//!
//! Pull-based on purpose: edge devices rarely accept inbound
//! connections, so the coordinator never needs to reach an agent —
//! a dead agent is simply one that stops polling, and the
//! coordinator's lease reaper requeues its jobs from their last
//! checkpoint. Checkpoint paths in job specs are interpreted on the
//! machine that runs the job; failover-with-resume therefore assumes
//! agents share the checkpoint filesystem (or accepts a from-scratch
//! rerun when they do not).
//!
//! If a poll answers 404 the agent knows its lease expired (a long
//! network partition): its jobs were requeued elsewhere, so it stops
//! them locally — double-writing their checkpoints would corrupt the
//! resumed lineage — and re-registers as a fresh agent. If the
//! coordinator stays unreachable for `max_poll_failures` consecutive
//! polls, the agent stops its jobs and exits.
//!
//! An idle agent does not hammer the coordinator at `--poll-ms`:
//! consecutive workless polls back off exponentially (jittered,
//! capped at [`IDLE_BACKOFF_CAP_MS`] and at a third of the lease the
//! coordinator advertised at registration, so even a short-leased
//! cluster never reaps an agent for idling), and the first assignment
//! or running job snaps the cadence back to `poll_ms`.
//!
//! # Data-parallel replicas
//!
//! An assignment carrying a `"dp": {"shard": S}` object is not a whole
//! job but one replica's share of a [data-parallel run](super::dp):
//! the agent builds the same deterministic world every replica (and
//! the single-node reference) builds, catches up on the commit log via
//! `POST /cluster/dp/{job}/join`, then per step forward-evaluates its
//! shards of the globally-assembled batch, reports scalar loss deltas,
//! and applies the committed projected gradient from its local RNG
//! stream — parameters never cross the wire, yet stay bit-identical
//! across every replica.

use super::http::request_with_timeout;
use crate::coordinator::checkpoint::{self, TrainState};
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::coordinator::dp_session::{DpWorld, ShardEval};
use crate::data::loader::Loader;
use crate::launch;
use crate::telemetry::{Phase, PhaseTimer};
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Agent-side HTTP timeout: polls and reports are small; a coordinator
/// that cannot answer within this is treated as a failed poll.
const HTTP_TIMEOUT: Duration = Duration::from_secs(5);

/// Knobs of `repro agent`.
#[derive(Debug, Clone)]
pub struct AgentOptions {
    /// Coordinator address (`host:port`).
    pub coordinator: String,
    /// Concurrent jobs this device can run.
    pub capacity: usize,
    /// Optional human label, echoed in `GET /cluster/agents`.
    pub name: String,
    /// Poll (= heartbeat) interval. Must be comfortably below the
    /// coordinator's lease.
    pub poll_ms: u64,
    /// Exit after this many consecutive failed polls.
    pub max_poll_failures: u32,
    /// Training-memory budget (bytes) reported at registration. The
    /// coordinator uses the paper's memory model to pin the deepest BP
    /// tail that fits when it assigns an elastic-boundary job here.
    /// `None` = unconstrained.
    pub mem_budget: Option<usize>,
}

impl Default for AgentOptions {
    fn default() -> Self {
        AgentOptions {
            coordinator: format!("127.0.0.1:{}", super::protocol::DEFAULT_PORT),
            capacity: 1,
            name: String::new(),
            poll_ms: 500,
            max_poll_failures: 20,
            mem_budget: None,
        }
    }
}

struct AgentShared {
    coordinator: String,
    /// Current registration id (re-registration after a lost lease
    /// installs a fresh one).
    agent_id: AtomicU64,
    /// The lease the coordinator advertised at registration (0 until
    /// known): the idle backoff must stay well inside it, or a
    /// long-idle agent would be reaped between its own heartbeats.
    lease_ms: AtomicU64,
    /// Simulated crash: vanish without a trace (tests).
    dead: AtomicBool,
    /// Graceful drain: deregister, stop jobs, exit.
    draining: AtomicBool,
    /// Stop flags of the jobs currently running here.
    jobs: Mutex<HashMap<u64, StopFlag>>,
    active: AtomicUsize,
}

impl AgentShared {
    fn post(&self, path: &str, body: Option<&Value>) -> Result<(u16, Value)> {
        request_with_timeout(&self.coordinator, "POST", path, body, HTTP_TIMEOUT)
    }

    fn silent(&self) -> bool {
        self.dead.load(Ordering::SeqCst) || self.draining.load(Ordering::SeqCst)
    }

    fn stop_all_jobs(&self) {
        for stop in self.jobs.lock().unwrap_or_else(PoisonError::into_inner).values() {
            stop.request_stop();
        }
    }

    fn wait_jobs_done(&self) {
        let t0 = Instant::now();
        while self.active.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(60)
        {
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

/// A running agent. Dropping the handle does NOT stop the agent; use
/// [`AgentHandle::stop`] (graceful) or [`AgentHandle::join`] (run
/// until the coordinator goes away).
pub struct AgentHandle {
    shared: Arc<AgentShared>,
    thread: JoinHandle<()>,
    id: u64,
}

impl AgentHandle {
    /// The id the coordinator assigned at registration.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Graceful drain: deregister with the coordinator (which requeues
    /// whatever this agent was running, from its last checkpoint),
    /// stop local jobs, and exit.
    pub fn stop(self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        let _ = self.thread.join();
    }

    /// Simulated crash (tests / chaos): vanish without deregistering —
    /// no further polls or terminal reports, and running jobs are
    /// stop-flagged so they quit touching their checkpoints within a
    /// batch. (An epoch that was already completing may still publish
    /// its report and cadence snapshot — the pair lands atomically
    /// from the coordinator's perspective, and a post-expiry report is
    /// rejected as stale.) The coordinator only finds out when the
    /// lease expires.
    pub fn kill(self) {
        self.shared.dead.store(true, Ordering::SeqCst);
        self.shared.stop_all_jobs();
        let _ = self.thread.join();
    }

    /// Block until the agent exits on its own (coordinator gone for
    /// `max_poll_failures` consecutive polls).
    pub fn join(self) -> Result<()> {
        self.thread
            .join()
            .map_err(|_| anyhow::anyhow!("agent thread panicked"))
    }
}

/// Entry point: `Agent::spawn(opts)` registers and starts polling.
pub struct Agent;

impl Agent {
    /// Register with the coordinator (synchronously, so a missing or
    /// non-cluster coordinator fails loudly here) and start the poll
    /// loop on a background thread.
    pub fn spawn(opts: AgentOptions) -> Result<AgentHandle> {
        let shared = Arc::new(AgentShared {
            coordinator: opts.coordinator.clone(),
            agent_id: AtomicU64::new(0),
            lease_ms: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            jobs: Mutex::new(HashMap::new()),
            active: AtomicUsize::new(0),
        });
        let id = register(&shared, &opts)
            .with_context(|| format!("registering with coordinator {}", opts.coordinator))?;
        let sh = shared.clone();
        let thread = std::thread::Builder::new()
            .name(format!("cluster-agent-{id}"))
            .spawn(move || poll_loop(&sh, &opts))
            .expect("spawning agent thread");
        Ok(AgentHandle { shared, thread, id })
    }
}

fn register(sh: &Arc<AgentShared>, opts: &AgentOptions) -> Result<u64> {
    let mut pairs = vec![
        ("name", Value::str(opts.name.clone())),
        ("capacity", Value::num(opts.capacity as f64)),
    ];
    if let Some(b) = opts.mem_budget {
        pairs.push(("mem_budget", Value::num(b as f64)));
    }
    let body = Value::obj(pairs);
    let (status, v) = sh.post("/cluster/register", Some(&body))?;
    anyhow::ensure!(
        status == 200,
        "registration rejected ({status}): {}",
        json::to_string(&v)
    );
    let id = v
        .get("agent")
        .as_f64()
        .context("register response missing agent id")? as u64;
    sh.agent_id.store(id, Ordering::SeqCst);
    // the advertised lease bounds the idle backoff; a coordinator too
    // old to advertise one leaves it 0 (backoff falls back to the
    // static cap alone)
    let lease = v.get("lease_ms").as_f64().unwrap_or(0.0).max(0.0) as u64;
    sh.lease_ms.store(lease, Ordering::SeqCst);
    Ok(id)
}

/// Static ceiling of the idle poll backoff: even a long-idle agent
/// heartbeats at least this often.
pub const IDLE_BACKOFF_CAP_MS: u64 = 2_000;

/// Sleep before the next poll after `idle_streak` consecutive polls
/// that neither carried an assignment nor found a job running here.
/// Exponential from `poll_ms` up to [`IDLE_BACKOFF_CAP_MS`] — further
/// clamped to a third of the coordinator-advertised `lease_ms` (0 =
/// unknown), since a backoff past the lease would get an idle agent
/// reaped, re-registered and reaped again forever — with a
/// deterministic ±25% jitter (salted per agent) so a fleet registered
/// in the same second does not heartbeat in lockstep forever. A
/// `poll_ms` above the cap is the operator's explicit cadence and is
/// never shortened.
fn idle_backoff(poll_ms: u64, idle_streak: u32, salt: u64, lease_ms: u64) -> u64 {
    let base = poll_ms.max(1);
    if idle_streak == 0 {
        return base;
    }
    let cap = if lease_ms > 0 {
        IDLE_BACKOFF_CAP_MS.min((lease_ms / 3).max(1))
    } else {
        IDLE_BACKOFF_CAP_MS
    };
    let raw = base
        .saturating_mul(1u64 << idle_streak.min(12))
        .clamp(base, cap.max(base));
    // splitmix-style hash of (salt, streak) → stable, well-spread bits
    let mut h = salt
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(idle_streak as u64);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    let spread = raw / 2 + 1; // jitter ∈ [-raw/4, raw/4]
    let jittered = raw as i64 + (h % spread) as i64 - (raw / 4) as i64;
    (jittered.max(base as i64)) as u64
}

fn poll_loop(sh: &Arc<AgentShared>, opts: &AgentOptions) {
    let mut failures: u32 = 0;
    let mut idle_streak: u32 = 0;
    loop {
        if sh.dead.load(Ordering::SeqCst) {
            return;
        }
        if sh.draining.load(Ordering::SeqCst) {
            // stop local jobs and wait them out BEFORE deregistering:
            // the coordinator requeues our assignments the moment we
            // deregister, and a survivor must never start resuming a
            // checkpoint this agent is still writing to
            sh.stop_all_jobs();
            sh.wait_jobs_done();
            let id = sh.agent_id.load(Ordering::SeqCst);
            let _ = sh.post(&format!("/cluster/agents/{id}/deregister"), None);
            return;
        }
        let id = sh.agent_id.load(Ordering::SeqCst);
        // the poll doubles as the assignment ack: report what is
        // actually running here, so the coordinator can detect (and
        // requeue) an assignment whose response never reached us
        let running: Vec<Value> = sh
            .jobs
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .keys()
            .map(|&j| Value::num(j as f64))
            .collect();
        let body = Value::obj(vec![("running", Value::Arr(running))]);
        let mut got_work = false;
        match sh.post(&format!("/cluster/agents/{id}/poll"), Some(&body)) {
            Ok((200, v)) => {
                failures = 0;
                for j in v.get("stop").as_arr().unwrap_or(&[]) {
                    if let Some(job) = j.as_f64().map(|n| n as u64) {
                        if let Some(stop) =
                            sh.jobs.lock().unwrap_or_else(PoisonError::into_inner).get(&job)
                        {
                            stop.request_stop();
                        }
                    }
                }
                for a in v.get("assign").as_arr().unwrap_or(&[]) {
                    got_work = true;
                    start_job(sh, id, a);
                }
            }
            // lease lost (e.g. a long partition): our jobs were
            // requeued elsewhere — stop them before their checkpoint
            // writes can collide with the resumed lineage, then come
            // back as a fresh agent
            Ok((404, _)) => {
                sh.stop_all_jobs();
                match register(sh, opts) {
                    Ok(_) => failures = 0,
                    Err(_) => failures += 1,
                }
            }
            Ok((_, _)) | Err(_) => failures += 1,
        }
        if failures >= opts.max_poll_failures {
            eprintln!(
                "agent: coordinator {} unreachable after {failures} polls; stopping",
                sh.coordinator
            );
            sh.stop_all_jobs();
            sh.wait_jobs_done();
            return;
        }
        // a running job (or fresh assignment) keeps the heartbeat at
        // poll_ms — stops must fan out promptly; only a truly idle
        // agent backs off
        if got_work || sh.active.load(Ordering::SeqCst) > 0 {
            idle_streak = 0;
        } else {
            idle_streak = idle_streak.saturating_add(1);
        }
        std::thread::sleep(Duration::from_millis(idle_backoff(
            opts.poll_ms,
            idle_streak,
            id,
            sh.lease_ms.load(Ordering::SeqCst),
        )));
    }
}

/// Run one assignment on its own thread: the exact `repro train` path
/// (`launch::run`), epochs POSTed back as they complete, terminal
/// outcome reported at the end. Reports are best-effort — the poll
/// loop, not the job, is the heartbeat. The terminal report is
/// suppressed when the agent is dead or draining (the job belongs to
/// someone else by then, and reporting it stopped would wrongly
/// cancel it); epoch reports are never suppressed (see the sink
/// comment below).
fn start_job(sh: &Arc<AgentShared>, agent_id: u64, assignment: &Value) {
    let done_path = move |job: u64| format!("/cluster/agents/{agent_id}/jobs/{job}/done");
    let (job_id, spec) = match super::dispatch::assignment_spec(assignment) {
        Ok(x) => x,
        Err(e) => {
            // report the unparseable spec if the assignment at least
            // carried a job id, so the job fails instead of leasing out
            if let Some(id) = assignment.get("id").as_f64() {
                let body = Value::obj(vec![(
                    "error",
                    Value::str(format!("agent could not parse job spec: {e:#}")),
                )]);
                let _ = sh.post(&done_path(id as u64), Some(&body));
            }
            return;
        }
    };
    // a `"dp": {...}` rider marks this assignment as one replica's
    // membership in a data-parallel run, not a whole job
    let is_dp = assignment.get("dp").get("shard").as_f64().is_some();
    let stop = StopFlag::new();
    sh.jobs
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .insert(job_id, stop.clone());
    sh.active.fetch_add(1, Ordering::SeqCst);
    let sh2 = sh.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("agent-job-{job_id}"))
        .spawn(move || {
            if is_dp {
                let sh3 = sh2.clone();
                let dp_stop = stop.clone();
                let out = catch_unwind(AssertUnwindSafe(|| {
                    run_dp_replica(&sh3, agent_id, job_id, &spec.config, dp_stop)
                }));
                match out {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        eprintln!("agent: dp replica for job {job_id} exited early: {e:#}")
                    }
                    Err(_) => eprintln!("agent: dp replica for job {job_id} panicked"),
                }
                // no done report: dp runs complete through the dp wire;
                // if this replica errored out, the poll loop's
                // running-ack lets the coordinator free its shards for
                // the surviving quorum
                {
                    let mut jobs = sh2.jobs.lock().unwrap_or_else(PoisonError::into_inner);
                    if jobs.get(&job_id).is_some_and(|f| f.shares_state(&stop)) {
                        jobs.remove(&job_id);
                    }
                }
                sh2.active.fetch_sub(1, Ordering::SeqCst);
                return;
            }
            let sink_sh = sh2.clone();
            let epoch_path = format!("/cluster/agents/{agent_id}/jobs/{job_id}/epoch");
            // The sink posts synchronously from the training thread,
            // strictly before the epoch's cadence snapshot is written,
            // and is NEVER suppressed — not even when dead/draining: a
            // stop that lands at an epoch tail still completes that
            // epoch's publish + snapshot, and suppressing the publish
            // would leave the coordinator's history one epoch short of
            // what the checkpoint claims (a permanent gap after a
            // requeue-trim). Stale posts are rejected server-side
            // (409) and cannot renew the lease, so letting them
            // through is always safe. One retry covers a transient
            // connection failure; beyond that the gap is cosmetic —
            // resume correctness comes from the checkpoint, not the
            // reported history.
            let sink = ProgressSink::new(move |e| {
                let body = e.to_json();
                if sink_sh.post(&epoch_path, Some(&body)).is_err() {
                    std::thread::sleep(Duration::from_millis(100));
                    let _ = sink_sh.post(&epoch_path, Some(&body));
                }
            });
            let cleanup_flag = stop.clone();
            let out = catch_unwind(AssertUnwindSafe(|| launch::run(&spec.config, stop, sink)));
            // report done BEFORE evicting the map entry: the poll
            // loop's running-set must keep listing this job until its
            // assignment is released server-side, or a concurrent poll
            // would read "assigned but not running" and requeue a job
            // that actually finished
            if !sh2.silent() {
                let body = match out {
                    Ok(Ok(l)) => Value::obj(vec![
                        ("stopped", Value::Bool(l.result.stopped)),
                        (
                            "best_test_acc",
                            Value::num(l.result.history.best_test_acc() as f64),
                        ),
                    ]),
                    Ok(Err(e)) => Value::obj(vec![("error", Value::str(format!("{e:#}")))]),
                    Err(_) => Value::obj(vec![(
                        "error",
                        Value::str("agent job panicked during training"),
                    )]),
                };
                let _ = sh2.post(&done_path(job_id), Some(&body));
            }
            {
                // guarded eviction: after a lost-lease re-registration
                // the same job can be re-assigned here while this old
                // run winds down — its map entry then holds the NEW
                // run's stop flag, which must survive this cleanup or
                // later cancels would be silently dropped
                let mut jobs = sh2.jobs.lock().unwrap_or_else(PoisonError::into_inner);
                if jobs.get(&job_id).is_some_and(|f| f.shares_state(&cleanup_flag)) {
                    jobs.remove(&job_id);
                }
            }
            sh2.active.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        sh.jobs
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .remove(&job_id);
        sh.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One dp response's sync payload, parsed (see [`super::dp`] for the
/// field semantics).
struct DpSync {
    step: u64,
    watermark: u64,
    commits_from: u64,
    commits: Vec<f32>,
    shards: Vec<usize>,
    pending: Vec<usize>,
    primary: bool,
    report_epochs: Vec<usize>,
    stop: bool,
    done: bool,
}

fn parse_sync(v: &Value) -> DpSync {
    let nums = |key: &str| -> Vec<usize> {
        v.get(key)
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64().map(|n| n as usize))
            .collect()
    };
    DpSync {
        step: v.get("step").as_f64().unwrap_or(0.0) as u64,
        watermark: v.get("watermark").as_f64().unwrap_or(0.0) as u64,
        commits_from: v.get("commits_from").as_f64().unwrap_or(0.0) as u64,
        commits: v
            .get("commits")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64().map(|n| n as f32))
            .collect(),
        shards: nums("shards"),
        pending: nums("pending"),
        primary: v.get("primary").as_bool().unwrap_or(false),
        report_epochs: nums("report_epochs"),
        stop: v.get("stop").as_bool().unwrap_or(false),
        done: v.get("done").as_bool().unwrap_or(false),
    }
}

/// Apply any commits in `s` this replica has not applied yet. The
/// replica always requests `have = applied`, so the slice normally
/// starts exactly at `applied`; the guards keep a malformed payload
/// from corrupting the trajectory.
///
/// `cycled` names the step whose ±ε eval cycle already ran in THIS
/// process (the main loop's in-flight step): its three perturbs — and
/// their f32 rounding residue, which is part of the trajectory — are
/// already in the params, so that step gets only the commit. Replaying
/// the cycle for it (via `catch_up`) would stack a second residue and
/// fork this replica bitwise from the single-process reference, which
/// performs exactly ONE cycle per step. Every other step (join-time
/// backlog, or steps the fleet committed without us) gets the full
/// cycle-replay so the residue lands exactly once there too.
fn apply_dp_commits(
    world: &mut DpWorld,
    timer: &mut PhaseTimer,
    applied: &mut u64,
    s: &DpSync,
    cycled: Option<u64>,
) {
    if s.watermark <= *applied || s.commits_from > *applied {
        return;
    }
    let skip = (*applied - s.commits_from) as usize;
    if skip >= s.commits.len() {
        return;
    }
    for &g in &s.commits[skip..] {
        let step = *applied;
        if cycled == Some(step) {
            world.apply_commit(step, g, timer);
        } else {
            world.catch_up(step, std::slice::from_ref(&g), timer);
        }
        *applied += 1;
    }
}

/// Run one replica of a data-parallel job (see the module docs). The
/// trajectory-bearing state never leaves this process: each step is
/// eval-cycle → scalar report → barrier on the commit → identical
/// local update. Epoch test metrics are computed by EVERY replica
/// (parameters are bit-identical, so the numbers are too) and posted
/// idempotently; only the final epoch's report — and the final
/// checkpoint that must exist before it — are gated on being the
/// primary, a duty that migrates if the primary is lost.
fn run_dp_replica(
    sh: &Arc<AgentShared>,
    agent_id: u64,
    job: u64,
    cfg: &crate::config::Config,
    stop: StopFlag,
) -> Result<()> {
    let dp = cfg.dp_spec().context("dp assignment for a non-dp job spec")?;
    let (train_d, test_d) =
        crate::data::generate(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed, cfg.npoints);
    let spec = cfg.train_spec();
    let mut world = DpWorld::new(cfg.model_enum(), spec.clone(), dp, train_d.len())?;
    let mut timer = PhaseTimer::new();
    let me = agent_id as f64;
    let base = format!("/cluster/dp/{job}");
    let post = |path: &str, body: &Value| -> Result<Value> {
        let (status, v) = sh.post(&format!("{base}/{path}"), Some(body))?;
        anyhow::ensure!(
            status == 200,
            "dp {path} rejected ({status}): {}",
            json::to_string(&v)
        );
        Ok(v)
    };
    let post_epoch = |e: usize, tl: f32, ta: f32, lr: f32, secs: f64| -> Result<()> {
        post(
            "epoch",
            &Value::obj(vec![
                ("agent", Value::num(me)),
                ("epoch", Value::num(e as f64)),
                ("test_loss", Value::num(tl as f64)),
                ("test_acc", Value::num(ta as f64)),
                ("lr", Value::num(lr as f64)),
                ("seconds", Value::num(secs)),
            ]),
        )?;
        Ok(())
    };

    // join: the full commit log catches a late joiner up bit-exactly
    let mut sync = parse_sync(&post(
        "join",
        &Value::obj(vec![("agent", Value::num(me)), ("have", Value::num(0))]),
    )?);
    let mut applied: u64 = 0;
    apply_dp_commits(&mut world, &mut timer, &mut applied, &sync, None);

    let spe = world.steps_per_epoch;
    let total = world.total_steps();
    let epochs = spec.epochs;
    // per-epoch (test_loss, test_acc, lr), cadence-carried like the
    // single-node loop; kept on every replica so the primary duty can
    // migrate without losing history
    let mut epoch_metrics: Vec<Option<(f32, f32, f32)>> = vec![None; epochs];
    let mut carry = (f32::NAN, 0.0f32);
    let mut best = 0.0f32;
    let mut epoch_t0 = Instant::now();
    let mut saved_final = false;

    let mut loader: Option<Loader> = None;
    let mut loader_epoch = usize::MAX;

    'steps: while applied < total {
        if stop.should_stop() || sh.silent() || sync.stop {
            break 'steps;
        }
        let t = applied;
        let epoch = (t / spe) as usize;
        if loader_epoch != epoch {
            let mut l = Loader::new(&train_d, spec.batch, spec.seed ^ 0xDA7A, epoch as u64);
            for _ in 0..(t % spe) {
                l.next(); // a catch-up landed mid-epoch: skip into place
            }
            loader = Some(l);
            loader_epoch = epoch;
        }
        let b = loader
            .as_mut()
            .and_then(|l| l.next())
            .context("dp loader exhausted before the epoch's steps")?;

        let shards = sync.shards.clone();
        anyhow::ensure!(!shards.is_empty(), "dp replica owns no shards (lease lost?)");
        let evals = world.eval_cycle(&b, t, &shards, &mut timer)?;
        let report_body = |evals: &[ShardEval]| {
            Value::obj(vec![
                ("agent", Value::num(me)),
                ("step", Value::num(t as f64)),
                // reports are only posted before commit t lands, so the
                // replica's applied watermark is exactly t here
                ("have", Value::num(t as f64)),
                ("reports", Value::Arr(evals.iter().map(|e| e.to_json()).collect())),
            ])
        };
        sync = parse_sync(&post("step", &report_body(&evals))?);

        // barrier: wait for step t to commit, evaluating any shards
        // absorbed from a lost replica along the way
        let mut wait_ms = 1u64;
        loop {
            if sync.step == t && !sync.pending.is_empty() {
                let extra = world.eval_extra(&b, t, &sync.pending, &mut timer)?;
                sync = parse_sync(&post("step", &report_body(&extra))?);
                continue;
            }
            // step t's cycle ran above (eval_cycle / eval_extra): its
            // commit applies bare; anything past t replays in full
            apply_dp_commits(&mut world, &mut timer, &mut applied, &sync, Some(t));
            if applied > t || sync.done || sync.stop {
                break;
            }
            if stop.should_stop() || sh.silent() {
                break 'steps;
            }
            std::thread::sleep(Duration::from_millis(wait_ms));
            wait_ms = (wait_ms * 2).min(50);
            sync = parse_sync(&post(
                "commits",
                &Value::obj(vec![("agent", Value::num(me)), ("have", Value::num(applied as f64))]),
            )?);
        }

        // epoch boundary: mirror the single-node eval cadence exactly
        if applied > t && applied % spe == 0 {
            let e = (applied / spe - 1) as usize;
            let is_last = e + 1 == epochs;
            let lr = world.lr_for_epoch(e);
            let (tl, ta) = if e % spec.eval_every == 0 || is_last {
                let t0 = Instant::now();
                let r = world.evaluate(&test_d)?;
                timer.add(Phase::Eval, t0.elapsed());
                r
            } else {
                carry
            };
            carry = (tl, ta);
            best = best.max(ta);
            epoch_metrics[e] = Some((tl, ta, lr));
            let secs = epoch_t0.elapsed().as_secs_f64();
            epoch_t0 = Instant::now();
            if !is_last {
                // idempotent: the coordinator keeps the first report
                post_epoch(e, tl, ta, lr, secs)?;
            }
        }
    }

    // end game: the primary saves the final checkpoint, then posts the
    // final (and any never-reported) epochs, which completes the run;
    // everyone else waits for `done` — and inherits the duty if the
    // primary is lost before reporting
    let mut wait_ms = 2u64;
    while applied >= total && !sync.done && !sync.stop && !stop.should_stop() && !sh.silent() {
        if sync.primary && !sync.report_epochs.is_empty() {
            if !saved_final {
                if let Some(path) = &cfg.save_checkpoint {
                    let last = epoch_metrics[epochs - 1];
                    let state = TrainState {
                        epochs_done: epochs,
                        step: total,
                        best_test_acc: best,
                        last_test_loss: last.map_or(f32::NAN, |m| m.0),
                        last_test_acc: last.map_or(0.0, |m| m.1),
                        spec: spec.to_json(),
                        elastic: None,
                    };
                    checkpoint::save_with_state(path, &world.snapshot(), Some(&state))
                        .with_context(|| format!("writing dp final checkpoint {path}"))?;
                }
                saved_final = true;
            }
            for &e in &sync.report_epochs {
                if e >= epochs {
                    continue;
                }
                let (tl, ta, lr) = match epoch_metrics[e] {
                    Some(m) => m,
                    None => {
                        // joined after this epoch's boundary: evaluate
                        // with the final params (exact for the last
                        // epoch, best-effort for a migration backlog)
                        let r = world.evaluate(&test_d)?;
                        (r.0, r.1, world.lr_for_epoch(e))
                    }
                };
                best = best.max(ta);
                post_epoch(e, tl, ta, lr, epoch_t0.elapsed().as_secs_f64())?;
            }
        }
        std::thread::sleep(Duration::from_millis(wait_ms));
        wait_ms = (wait_ms * 2).min(100);
        sync = parse_sync(&post(
            "commits",
            &Value::obj(vec![("agent", Value::num(me)), ("have", Value::num(applied as f64))]),
        )?);
    }

    // graceful exit frees our shards right away; a crash (silent) skips
    // it and lets the lease machinery reclaim them
    if !sh.silent() {
        let _ = post("leave", &Value::obj(vec![("agent", Value::num(me))]));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_backoff_grows_caps_and_resets() {
        // streak 0 = active: exactly the configured cadence
        assert_eq!(idle_backoff(500, 0, 7, 0), 500);
        // grows with the streak, never below base, never above cap+25%
        let mut prev = 500;
        for streak in 1..10 {
            let d = idle_backoff(500, streak, 7, 0);
            assert!(d >= 500, "below base at streak {streak}: {d}");
            assert!(
                d <= IDLE_BACKOFF_CAP_MS + IDLE_BACKOFF_CAP_MS / 4,
                "above jittered cap at streak {streak}: {d}"
            );
            if streak <= 2 {
                assert!(d >= prev / 2, "collapsed at streak {streak}");
            }
            prev = d;
        }
        // deterministic for a given (salt, streak)
        assert_eq!(idle_backoff(500, 5, 42, 0), idle_backoff(500, 5, 42, 0));
        // different salts jitter differently somewhere in the ladder
        let a: Vec<u64> = (1..8).map(|s| idle_backoff(500, s, 1, 0)).collect();
        let b: Vec<u64> = (1..8).map(|s| idle_backoff(500, s, 2, 0)).collect();
        assert_ne!(a, b, "jitter must depend on the salt");
    }

    #[test]
    fn idle_backoff_handles_tiny_and_huge_poll_ms() {
        assert_eq!(idle_backoff(0, 0, 1, 0), 1);
        assert!(idle_backoff(1, 30, 1, 0) >= 1);
        // a poll_ms above the cap is respected (never sleep less than
        // the configured cadence)
        assert!(idle_backoff(5_000, 3, 1, 0) >= 5_000);
    }

    #[test]
    fn idle_backoff_stays_inside_a_short_lease() {
        // a 120 ms lease (the shortest the tests use) must bound the
        // backoff: deep idle streaks may never sleep past the lease,
        // or the idle agent would be reaped between heartbeats
        for lease in [120u64, 300, 900, 1_500] {
            for streak in 1..16 {
                let d = idle_backoff(10, streak, 3, lease);
                assert!(
                    d < lease,
                    "backoff {d} ms >= lease {lease} ms at streak {streak}"
                );
            }
        }
        // an unknown lease (0) falls back to the static cap alone
        assert!(idle_backoff(10, 12, 3, 0) > IDLE_BACKOFF_CAP_MS / 2);
        // a lease longer than 3x the static cap changes nothing
        assert_eq!(idle_backoff(10, 12, 3, 60_000), idle_backoff(10, 12, 3, 0));
    }

    /// Regression: a replica that ran `eval_cycle` for step `t` must
    /// apply the incoming commit for `t` BARE — replaying the ±ε cycle
    /// (the old `catch_up`-always path) stacks a second f32 rounding
    /// residue on the step and forks the replica bitwise from the
    /// single-process reference, which performs exactly one cycle per
    /// step. A join-time backlog (no local cycle) still replays fully.
    #[test]
    fn commit_after_local_cycle_stays_bit_identical() {
        use crate::coordinator::dp_session::{aggregate, DpAggregate, DpSpec};
        use crate::coordinator::engine::Method;
        use crate::coordinator::params::Model;
        use crate::coordinator::session::TrainSpec;
        use crate::coordinator::zo;
        use crate::data::synth_mnist;

        let data = synth_mnist::generate(32, 3);
        let spec = TrainSpec {
            method: Method::FULL_ZO,
            epochs: 1,
            batch: 16,
            seed: 5,
            ..TrainSpec::default()
        };
        let dp = DpSpec { replicas: 2, aggregate: DpAggregate::Mean, min_replicas: 1 };
        let mut reference = DpWorld::new(Model::LeNet, spec.clone(), dp, data.len()).unwrap();
        let mut replica = DpWorld::new(Model::LeNet, spec.clone(), dp, data.len()).unwrap();
        let mut timer = PhaseTimer::new();
        let mut commits = Vec::new();

        for (i, b) in Loader::new(&data, 16, spec.seed ^ 0xDA7A, 0).enumerate() {
            let t = i as u64;
            // reference: one cycle + bare commit (the DpLocalSession path)
            let evals = reference.eval_cycle(&b, t, &[0, 1], &mut timer).unwrap();
            let agg = aggregate(&evals, dp.aggregate);
            let g = zo::projected_gradient_from_delta(agg.delta, spec.eps, spec.g_clip);
            reference.apply_commit(t, g, &mut timer);
            commits.push(g);

            // replica: cycle runs locally, then the commit arrives in a
            // sync payload — exactly the trained-through barrier path
            replica.eval_cycle(&b, t, &[0, 1], &mut timer).unwrap();
            let sync = DpSync {
                step: t + 1,
                watermark: t + 1,
                commits_from: t,
                commits: vec![g],
                shards: vec![0, 1],
                pending: Vec::new(),
                primary: false,
                report_epochs: Vec::new(),
                stop: false,
                done: false,
            };
            let mut applied = t;
            apply_dp_commits(&mut replica, &mut timer, &mut applied, &sync, Some(t));
            assert_eq!(applied, t + 1);
        }
        assert_eq!(
            reference.params.data, replica.params.data,
            "trained-through replica forked from the reference (double cycle residue?)"
        );

        // late joiner: no local cycles ran, so every step replays fully
        let mut joiner = DpWorld::new(Model::LeNet, spec, dp, data.len()).unwrap();
        let total = commits.len() as u64;
        let sync = DpSync {
            step: total,
            watermark: total,
            commits_from: 0,
            commits,
            shards: vec![0, 1],
            pending: Vec::new(),
            primary: false,
            report_epochs: Vec::new(),
            stop: false,
            done: false,
        };
        let mut applied = 0u64;
        apply_dp_commits(&mut joiner, &mut timer, &mut applied, &sync, None);
        assert_eq!(applied, total);
        assert_eq!(reference.params.data, joiner.params.data, "join catch-up diverged");
    }

    #[test]
    fn sync_payload_parses_losslessly() {
        let v = json::parse(
            r#"{"step": 3, "watermark": 3, "commits_from": 1,
                "commits": [0.5, -0.25], "shards": [0, 2], "pending": [2],
                "primary": true, "report_epochs": [1], "stop": false, "done": false}"#,
        )
        .unwrap();
        let s = parse_sync(&v);
        assert_eq!((s.step, s.watermark, s.commits_from), (3, 3, 1));
        assert_eq!(s.commits, vec![0.5, -0.25]);
        assert_eq!(s.shards, vec![0, 2]);
        assert_eq!(s.pending, vec![2]);
        assert!(s.primary && !s.stop && !s.done);
        assert_eq!(s.report_epochs, vec![1]);
        // defaults for a missing field
        let s = parse_sync(&json::parse("{}").unwrap());
        assert_eq!(s.watermark, 0);
        assert!(s.shards.is_empty() && !s.primary);
    }
}
