//! The remote worker agent (`repro agent`): the device side of
//! multi-node sharding. An agent registers with a cluster-enabled
//! coordinator (`repro serve --cluster`), then pulls work over the
//! same std-only HTTP/JSON stack the local CLI clients use:
//!
//! 1. `POST /cluster/register` → agent id + lease duration;
//! 2. poll loop (`POST /cluster/agents/{id}/poll`, the heartbeat):
//!    each answer carries job assignments — a serialized
//!    [`JobSpec`](super::protocol::JobSpec), i.e. exactly the
//!    `TrainSpec` + data/backend keys `repro train` accepts — and
//!    stop requests for running jobs;
//! 3. every assignment runs on its own thread through the very same
//!    [`launch::run`] path as `repro train` and the coordinator's
//!    local workers, with a `ProgressSink` that POSTs each epoch back
//!    and a terminal `done` report at the end.
//!
//! Pull-based on purpose: edge devices rarely accept inbound
//! connections, so the coordinator never needs to reach an agent —
//! a dead agent is simply one that stops polling, and the
//! coordinator's lease reaper requeues its jobs from their last
//! checkpoint. Checkpoint paths in job specs are interpreted on the
//! machine that runs the job; failover-with-resume therefore assumes
//! agents share the checkpoint filesystem (or accepts a from-scratch
//! rerun when they do not).
//!
//! If a poll answers 404 the agent knows its lease expired (a long
//! network partition): its jobs were requeued elsewhere, so it stops
//! them locally — double-writing their checkpoints would corrupt the
//! resumed lineage — and re-registers as a fresh agent. If the
//! coordinator stays unreachable for `max_poll_failures` consecutive
//! polls, the agent stops its jobs and exits.

use super::http::request_with_timeout;
use crate::coordinator::control::{ProgressSink, StopFlag};
use crate::launch;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Agent-side HTTP timeout: polls and reports are small; a coordinator
/// that cannot answer within this is treated as a failed poll.
const HTTP_TIMEOUT: Duration = Duration::from_secs(5);

/// Knobs of `repro agent`.
#[derive(Debug, Clone)]
pub struct AgentOptions {
    /// Coordinator address (`host:port`).
    pub coordinator: String,
    /// Concurrent jobs this device can run.
    pub capacity: usize,
    /// Optional human label, echoed in `GET /cluster/agents`.
    pub name: String,
    /// Poll (= heartbeat) interval. Must be comfortably below the
    /// coordinator's lease.
    pub poll_ms: u64,
    /// Exit after this many consecutive failed polls.
    pub max_poll_failures: u32,
}

impl Default for AgentOptions {
    fn default() -> Self {
        AgentOptions {
            coordinator: format!("127.0.0.1:{}", super::protocol::DEFAULT_PORT),
            capacity: 1,
            name: String::new(),
            poll_ms: 500,
            max_poll_failures: 20,
        }
    }
}

struct AgentShared {
    coordinator: String,
    /// Current registration id (re-registration after a lost lease
    /// installs a fresh one).
    agent_id: AtomicU64,
    /// Simulated crash: vanish without a trace (tests).
    dead: AtomicBool,
    /// Graceful drain: deregister, stop jobs, exit.
    draining: AtomicBool,
    /// Stop flags of the jobs currently running here.
    jobs: Mutex<HashMap<u64, StopFlag>>,
    active: AtomicUsize,
}

impl AgentShared {
    fn post(&self, path: &str, body: Option<&Value>) -> Result<(u16, Value)> {
        request_with_timeout(&self.coordinator, "POST", path, body, HTTP_TIMEOUT)
    }

    fn silent(&self) -> bool {
        self.dead.load(Ordering::SeqCst) || self.draining.load(Ordering::SeqCst)
    }

    fn stop_all_jobs(&self) {
        for stop in self.jobs.lock().unwrap_or_else(PoisonError::into_inner).values() {
            stop.request_stop();
        }
    }

    fn wait_jobs_done(&self) {
        let t0 = Instant::now();
        while self.active.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(60)
        {
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

/// A running agent. Dropping the handle does NOT stop the agent; use
/// [`AgentHandle::stop`] (graceful) or [`AgentHandle::join`] (run
/// until the coordinator goes away).
pub struct AgentHandle {
    shared: Arc<AgentShared>,
    thread: JoinHandle<()>,
    id: u64,
}

impl AgentHandle {
    /// The id the coordinator assigned at registration.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Graceful drain: deregister with the coordinator (which requeues
    /// whatever this agent was running, from its last checkpoint),
    /// stop local jobs, and exit.
    pub fn stop(self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        let _ = self.thread.join();
    }

    /// Simulated crash (tests / chaos): vanish without deregistering —
    /// no further polls or terminal reports, and running jobs are
    /// stop-flagged so they quit touching their checkpoints within a
    /// batch. (An epoch that was already completing may still publish
    /// its report and cadence snapshot — the pair lands atomically
    /// from the coordinator's perspective, and a post-expiry report is
    /// rejected as stale.) The coordinator only finds out when the
    /// lease expires.
    pub fn kill(self) {
        self.shared.dead.store(true, Ordering::SeqCst);
        self.shared.stop_all_jobs();
        let _ = self.thread.join();
    }

    /// Block until the agent exits on its own (coordinator gone for
    /// `max_poll_failures` consecutive polls).
    pub fn join(self) -> Result<()> {
        self.thread
            .join()
            .map_err(|_| anyhow::anyhow!("agent thread panicked"))
    }
}

/// Entry point: `Agent::spawn(opts)` registers and starts polling.
pub struct Agent;

impl Agent {
    /// Register with the coordinator (synchronously, so a missing or
    /// non-cluster coordinator fails loudly here) and start the poll
    /// loop on a background thread.
    pub fn spawn(opts: AgentOptions) -> Result<AgentHandle> {
        let shared = Arc::new(AgentShared {
            coordinator: opts.coordinator.clone(),
            agent_id: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            jobs: Mutex::new(HashMap::new()),
            active: AtomicUsize::new(0),
        });
        let id = register(&shared, &opts)
            .with_context(|| format!("registering with coordinator {}", opts.coordinator))?;
        let sh = shared.clone();
        let thread = std::thread::Builder::new()
            .name(format!("cluster-agent-{id}"))
            .spawn(move || poll_loop(&sh, &opts))
            .expect("spawning agent thread");
        Ok(AgentHandle { shared, thread, id })
    }
}

fn register(sh: &Arc<AgentShared>, opts: &AgentOptions) -> Result<u64> {
    let body = Value::obj(vec![
        ("name", Value::str(opts.name.clone())),
        ("capacity", Value::num(opts.capacity as f64)),
    ]);
    let (status, v) = sh.post("/cluster/register", Some(&body))?;
    anyhow::ensure!(
        status == 200,
        "registration rejected ({status}): {}",
        json::to_string(&v)
    );
    let id = v
        .get("agent")
        .as_f64()
        .context("register response missing agent id")? as u64;
    sh.agent_id.store(id, Ordering::SeqCst);
    Ok(id)
}

fn poll_loop(sh: &Arc<AgentShared>, opts: &AgentOptions) {
    let mut failures: u32 = 0;
    loop {
        if sh.dead.load(Ordering::SeqCst) {
            return;
        }
        if sh.draining.load(Ordering::SeqCst) {
            // stop local jobs and wait them out BEFORE deregistering:
            // the coordinator requeues our assignments the moment we
            // deregister, and a survivor must never start resuming a
            // checkpoint this agent is still writing to
            sh.stop_all_jobs();
            sh.wait_jobs_done();
            let id = sh.agent_id.load(Ordering::SeqCst);
            let _ = sh.post(&format!("/cluster/agents/{id}/deregister"), None);
            return;
        }
        let id = sh.agent_id.load(Ordering::SeqCst);
        // the poll doubles as the assignment ack: report what is
        // actually running here, so the coordinator can detect (and
        // requeue) an assignment whose response never reached us
        let running: Vec<Value> = sh
            .jobs
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .keys()
            .map(|&j| Value::num(j as f64))
            .collect();
        let body = Value::obj(vec![("running", Value::Arr(running))]);
        match sh.post(&format!("/cluster/agents/{id}/poll"), Some(&body)) {
            Ok((200, v)) => {
                failures = 0;
                for j in v.get("stop").as_arr().unwrap_or(&[]) {
                    if let Some(job) = j.as_f64().map(|n| n as u64) {
                        if let Some(stop) =
                            sh.jobs.lock().unwrap_or_else(PoisonError::into_inner).get(&job)
                        {
                            stop.request_stop();
                        }
                    }
                }
                for a in v.get("assign").as_arr().unwrap_or(&[]) {
                    start_job(sh, id, a);
                }
            }
            // lease lost (e.g. a long partition): our jobs were
            // requeued elsewhere — stop them before their checkpoint
            // writes can collide with the resumed lineage, then come
            // back as a fresh agent
            Ok((404, _)) => {
                sh.stop_all_jobs();
                match register(sh, opts) {
                    Ok(_) => failures = 0,
                    Err(_) => failures += 1,
                }
            }
            Ok((_, _)) | Err(_) => failures += 1,
        }
        if failures >= opts.max_poll_failures {
            eprintln!(
                "agent: coordinator {} unreachable after {failures} polls; stopping",
                sh.coordinator
            );
            sh.stop_all_jobs();
            sh.wait_jobs_done();
            return;
        }
        std::thread::sleep(Duration::from_millis(opts.poll_ms));
    }
}

/// Run one assignment on its own thread: the exact `repro train` path
/// (`launch::run`), epochs POSTed back as they complete, terminal
/// outcome reported at the end. Reports are best-effort — the poll
/// loop, not the job, is the heartbeat. The terminal report is
/// suppressed when the agent is dead or draining (the job belongs to
/// someone else by then, and reporting it stopped would wrongly
/// cancel it); epoch reports are never suppressed (see the sink
/// comment below).
fn start_job(sh: &Arc<AgentShared>, agent_id: u64, assignment: &Value) {
    let done_path = move |job: u64| format!("/cluster/agents/{agent_id}/jobs/{job}/done");
    let (job_id, spec) = match super::dispatch::assignment_spec(assignment) {
        Ok(x) => x,
        Err(e) => {
            // report the unparseable spec if the assignment at least
            // carried a job id, so the job fails instead of leasing out
            if let Some(id) = assignment.get("id").as_f64() {
                let body = Value::obj(vec![(
                    "error",
                    Value::str(format!("agent could not parse job spec: {e:#}")),
                )]);
                let _ = sh.post(&done_path(id as u64), Some(&body));
            }
            return;
        }
    };
    let stop = StopFlag::new();
    sh.jobs
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .insert(job_id, stop.clone());
    sh.active.fetch_add(1, Ordering::SeqCst);
    let sh2 = sh.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("agent-job-{job_id}"))
        .spawn(move || {
            let sink_sh = sh2.clone();
            let epoch_path = format!("/cluster/agents/{agent_id}/jobs/{job_id}/epoch");
            // The sink posts synchronously from the training thread,
            // strictly before the epoch's cadence snapshot is written,
            // and is NEVER suppressed — not even when dead/draining: a
            // stop that lands at an epoch tail still completes that
            // epoch's publish + snapshot, and suppressing the publish
            // would leave the coordinator's history one epoch short of
            // what the checkpoint claims (a permanent gap after a
            // requeue-trim). Stale posts are rejected server-side
            // (409) and cannot renew the lease, so letting them
            // through is always safe. One retry covers a transient
            // connection failure; beyond that the gap is cosmetic —
            // resume correctness comes from the checkpoint, not the
            // reported history.
            let sink = ProgressSink::new(move |e| {
                let body = e.to_json();
                if sink_sh.post(&epoch_path, Some(&body)).is_err() {
                    std::thread::sleep(Duration::from_millis(100));
                    let _ = sink_sh.post(&epoch_path, Some(&body));
                }
            });
            let cleanup_flag = stop.clone();
            let out = catch_unwind(AssertUnwindSafe(|| launch::run(&spec.config, stop, sink)));
            // report done BEFORE evicting the map entry: the poll
            // loop's running-set must keep listing this job until its
            // assignment is released server-side, or a concurrent poll
            // would read "assigned but not running" and requeue a job
            // that actually finished
            if !sh2.silent() {
                let body = match out {
                    Ok(Ok(l)) => Value::obj(vec![
                        ("stopped", Value::Bool(l.result.stopped)),
                        (
                            "best_test_acc",
                            Value::num(l.result.history.best_test_acc() as f64),
                        ),
                    ]),
                    Ok(Err(e)) => Value::obj(vec![("error", Value::str(format!("{e:#}")))]),
                    Err(_) => Value::obj(vec![(
                        "error",
                        Value::str("agent job panicked during training"),
                    )]),
                };
                let _ = sh2.post(&done_path(job_id), Some(&body));
            }
            {
                // guarded eviction: after a lost-lease re-registration
                // the same job can be re-assigned here while this old
                // run winds down — its map entry then holds the NEW
                // run's stop flag, which must survive this cleanup or
                // later cancels would be silently dropped
                let mut jobs = sh2.jobs.lock().unwrap_or_else(PoisonError::into_inner);
                if jobs.get(&job_id).is_some_and(|f| f.shares_state(&cleanup_flag)) {
                    jobs.remove(&job_id);
                }
            }
            sh2.active.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        sh.jobs
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .remove(&job_id);
        sh.active.fetch_sub(1, Ordering::SeqCst);
    }
}
