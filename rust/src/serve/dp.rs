//! Coordinator-side bookkeeping for seed-compressed data-parallel ZO:
//! shard leases, the per-step commit barrier and the `/cluster/dp/*`
//! wire.
//!
//! One [`DpRun`] exists per adopted dp job. Its `replicas` shards are
//! leased to agents through the regular poll hand-out (the assignment
//! gains a `"dp": {"shard": S}` object); each replica then speaks the
//! dp wire directly:
//!
//! * `join`   — sync up: the full commit log so far (catch-up replay)
//! * `step`   — report `ShardEval`s for the current step; when all
//!              shards are in, the coordinator aggregates the deltas,
//!              projects the gradient and appends it to the commit log
//! * `commits`— poll for new commits past a watermark (the barrier
//!              wait of replicas that already reported)
//! * `epoch`  — the primary replica's test metrics for a finished
//!              epoch; merged with the coordinator's train-side
//!              aggregate into one [`EpochStats`] record
//! * `leave`  — graceful exit (run finished, stop, or agent shutdown)
//!
//! Every response carries the same sync payload: current step, commit
//! watermark + new commits, the caller's shard set, which of those
//! still owe a report (`pending`), a `primary` flag and `stop`/`done`.
//!
//! # Stragglers, loss and quorum
//!
//! The commit barrier waits for ALL shards, but shard ownership moves:
//! when an agent is reaped (lease expiry, deregister, lost-ack
//! reconcile) its shards are freed, and any surviving member that
//! calls in absorbs them — provided the surviving membership is at
//! least `min_replicas`. The absorber learns its new shards from the
//! sync payload, re-evaluates them for the in-flight step (bit-exactly
//! restoring its params around the extra forwards) and the barrier
//! completes from the surviving quorum. Shards never owned by anyone
//! are absorbable once a short grace window after adoption passes, so
//! a cluster smaller than `replicas` still completes the job.
//! Membership changes are journaled (`dp_member` events) as an audit
//! trail; a dp job interrupted by coordinator restart reruns from
//! scratch (dp forbids resume).

use super::protocol::{error_json, JobSpec};
use super::registry::{JobOutcome, JobRegistry};
use crate::coordinator::dp_session::{aggregate, DpSpec, ShardEval};
use crate::coordinator::metrics::EpochStats;
use crate::coordinator::zo;
use crate::telemetry::PhaseTimer;
use crate::util::json::Value;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// One live data-parallel run.
struct DpRun {
    spec: JobSpec,
    dp: DpSpec,
    eps: f32,
    g_clip: f32,
    epochs: usize,
    steps_per_epoch: u64,
    total_steps: u64,
    created: Instant,
    /// Shard → owning agent (`None` = free / offerable).
    owner: Vec<Option<u64>>,
    /// Shards that have had an owner at least once are absorbable
    /// immediately when freed (the lease already burned the wait);
    /// never-owned shards wait out the post-adoption grace window.
    ever_owned: Vec<bool>,
    /// Reports for the CURRENT (uncommitted) step, indexed by shard.
    reports: Vec<Option<ShardEval>>,
    /// The commit log: projected gradient per committed step.
    commits: Vec<f32>,
    // train-side aggregation of the in-flight epoch
    ep_loss: f64,
    ep_correct: u64,
    ep_seen: u64,
    ep_steps: u64,
    /// Per-epoch `(train_loss, train_acc)` once all its steps committed.
    epoch_train: Vec<Option<(f32, f32)>>,
    /// Epochs already recorded in the registry.
    recorded: Vec<bool>,
    best_test_acc: f32,
    stopping: bool,
    done: bool,
}

impl DpRun {
    fn step(&self) -> u64 {
        self.commits.len() as u64
    }

    fn owned(&self, agent: u64) -> Vec<usize> {
        (0..self.dp.replicas).filter(|&s| self.owner[s] == Some(agent)).collect()
    }

    fn member_count(&self) -> usize {
        let mut seen: Vec<u64> = self.owner.iter().filter_map(|o| *o).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    /// The primary is the owner of the lowest owned shard — it posts
    /// epoch metrics and writes the final checkpoint. Primacy migrates
    /// with the shard, so losing the primary only moves the duty.
    fn primary(&self) -> Option<u64> {
        self.owner.iter().find_map(|o| *o)
    }

    /// Commit the current step if every shard has reported: aggregate
    /// in fixed shard order, project the gradient, append to the log
    /// and roll the train-side epoch accumulators.
    fn try_commit(&mut self) -> bool {
        if self.done || self.step() >= self.total_steps {
            return false;
        }
        if self.reports.iter().any(Option::is_none) {
            return false;
        }
        let evals: Vec<ShardEval> = self.reports.iter().map(|r| r.unwrap()).collect();
        let agg = aggregate(&evals, self.dp.aggregate);
        let g = zo::projected_gradient_from_delta(agg.delta, self.eps, self.g_clip);
        let step = self.step();
        self.commits.push(g);
        self.ep_loss += agg.loss as f64;
        self.ep_correct += agg.correct as u64;
        self.ep_seen += agg.seen as u64;
        self.ep_steps += 1;
        if (step + 1) % self.steps_per_epoch == 0 {
            let e = (step / self.steps_per_epoch) as usize;
            let loss = (self.ep_loss / self.ep_steps.max(1) as f64) as f32;
            let acc = if self.ep_seen > 0 {
                self.ep_correct as f32 / self.ep_seen as f32
            } else {
                0.0
            };
            self.epoch_train[e] = Some((loss, acc));
            self.ep_loss = 0.0;
            self.ep_correct = 0;
            self.ep_seen = 0;
            self.ep_steps = 0;
        }
        for r in &mut self.reports {
            *r = None;
        }
        true
    }

    /// The sync payload every dp response carries, from `agent`'s view.
    fn sync_json(&self, agent: u64, have: usize) -> Value {
        let shards = self.owned(agent);
        let from = have.min(self.commits.len());
        let pending: Vec<Value> = if self.done || self.stopping || self.step() >= self.total_steps
        {
            Vec::new()
        } else {
            shards
                .iter()
                .filter(|&&s| self.reports[s].is_none())
                .map(|&s| Value::num(s as f64))
                .collect()
        };
        let primary = self.primary() == Some(agent);
        let report_epochs: Vec<Value> = if primary {
            (0..self.epochs)
                .filter(|&e| self.epoch_train[e].is_some() && !self.recorded[e])
                .map(|e| Value::num(e as f64))
                .collect()
        } else {
            Vec::new()
        };
        Value::obj(vec![
            ("step", Value::num(self.step() as f64)),
            ("watermark", Value::num(self.commits.len() as f64)),
            ("commits_from", Value::num(from as f64)),
            (
                "commits",
                Value::Arr(self.commits[from..].iter().map(|&g| Value::num(g as f64)).collect()),
            ),
            (
                "shards",
                Value::Arr(shards.iter().map(|&s| Value::num(s as f64)).collect()),
            ),
            ("pending", Value::Arr(pending)),
            ("primary", Value::Bool(primary)),
            ("report_epochs", Value::Arr(report_epochs)),
            ("stop", Value::Bool(self.stopping)),
            ("done", Value::Bool(self.done)),
        ])
    }
}

/// Shard leases + step barriers for every live dp run. Owned by the
/// [`super::dispatch::Dispatcher`]; lock order is `runs` before any
/// registry lock (never the reverse).
pub struct DpCoordinator {
    registry: Arc<JobRegistry>,
    /// How long after adoption never-owned shards stay reserved for
    /// fresh (non-member) agents before members may absorb them.
    grace: Duration,
    runs: Mutex<HashMap<u64, DpRun>>,
}

impl DpCoordinator {
    pub fn new(registry: Arc<JobRegistry>, grace: Duration) -> DpCoordinator {
        DpCoordinator { registry, grace, runs: Mutex::new(HashMap::new()) }
    }

    fn lock(&self) -> MutexGuard<'_, HashMap<u64, DpRun>> {
        self.runs.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn runs_active(&self) -> usize {
        self.lock().len()
    }

    /// Adopt a freshly-claimed dp job: build its run state. Shards all
    /// start free and are leased out through [`DpCoordinator::offer`].
    pub fn adopt(&self, id: u64, spec: JobSpec, dp: DpSpec) {
        let c = &spec.config;
        let steps_per_epoch = c.train_n.div_ceil(c.batch) as u64;
        let run = DpRun {
            eps: c.eps,
            g_clip: c.g_clip,
            epochs: c.epochs,
            steps_per_epoch,
            total_steps: c.epochs as u64 * steps_per_epoch,
            created: Instant::now(),
            owner: vec![None; dp.replicas],
            ever_owned: vec![false; dp.replicas],
            reports: vec![None; dp.replicas],
            commits: Vec::new(),
            ep_loss: 0.0,
            ep_correct: 0,
            ep_seen: 0,
            ep_steps: 0,
            epoch_train: vec![None; c.epochs],
            recorded: vec![false; c.epochs],
            best_test_acc: 0.0,
            stopping: false,
            done: false,
            spec,
            dp,
        };
        self.lock().insert(id, run);
        self.gauge_runs();
    }

    /// Offer free shards of non-member runs to a polling agent, up to
    /// `slots` (each offer is a new job assignment and consumes one
    /// capacity slot). Returns `(job, shard, spec)` triples; the
    /// dispatcher serializes them into poll assignments.
    pub fn offer(&self, agent: u64, slots: usize) -> Vec<(u64, usize, JobSpec)> {
        let mut out = Vec::new();
        if slots == 0 {
            return out;
        }
        let mut runs = self.lock();
        for (&id, run) in runs.iter_mut() {
            if out.len() >= slots || run.stopping || run.done {
                if out.len() >= slots {
                    break;
                }
                continue;
            }
            if run.owned(agent).is_empty() {
                if let Some(s) = (0..run.dp.replicas).find(|&s| run.owner[s].is_none()) {
                    run.owner[s] = Some(agent);
                    run.ever_owned[s] = true;
                    out.push((id, s, run.spec.clone()));
                }
            }
        }
        drop(runs);
        for (id, s, _) in &out {
            self.registry.journal_dp(*id, "join", agent, &[*s]);
        }
        if !out.is_empty() {
            self.gauge_members();
        }
        out
    }

    /// Free shards owned by members at least `min_replicas` strong may
    /// absorb: freed-by-loss shards immediately, never-owned shards
    /// after the post-adoption grace window. The caller is a provably
    /// live member (it is mid-request), so it takes them all.
    fn absorb_free(&self, run: &mut DpRun, agent: u64) -> Vec<usize> {
        if run.stopping || run.done || run.owned(agent).is_empty() {
            return Vec::new();
        }
        if run.member_count() < run.dp.min_replicas {
            return Vec::new();
        }
        let mut took = Vec::new();
        for s in 0..run.dp.replicas {
            if run.owner[s].is_none()
                && (run.ever_owned[s] || run.created.elapsed() >= self.grace)
            {
                run.owner[s] = Some(agent);
                run.ever_owned[s] = true;
                took.push(s);
            }
        }
        took
    }

    fn post_absorb(&self, id: u64, agent: u64, took: &[usize]) {
        if took.is_empty() {
            return;
        }
        self.registry.journal_dp(id, "absorb", agent, took);
        crate::metrics::global()
            .counter(
                "repro_dp_shard_moves_total",
                "dp shards re-leased to a surviving member after agent loss (or a small cluster absorbing unclaimed shards)",
                &[],
            )
            .add(took.len() as u64);
    }

    /// `POST /cluster/dp/{job}/join` — body `{"agent": A, "have": H?}`.
    /// Answers the sync payload with the commit log from `H` (default
    /// 0), i.e. everything a fresh replica needs to catch up.
    pub fn join(&self, job: u64, body: &[u8]) -> (u16, Value) {
        self.sync_request(job, body, "join")
    }

    /// `POST /cluster/dp/{job}/commits` — body `{"agent": A, "have": H}`.
    /// The barrier wait: replicas that already reported poll here until
    /// the watermark passes their step (absorbing freed shards while
    /// they wait, so a lost replica cannot stall the barrier).
    pub fn commits(&self, job: u64, body: &[u8]) -> (u16, Value) {
        self.sync_request(job, body, "commits")
    }

    fn sync_request(&self, job: u64, body: &[u8], what: &str) -> (u16, Value) {
        let v = match super::dispatch::parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let Some(agent) = v.get("agent").as_i64().map(|a| a as u64) else {
            return (400, error_json(&format!("dp {what} needs an agent id")));
        };
        let have = v.get("have").as_i64().unwrap_or(0).max(0) as usize;
        let stop = self.registry.stop_requested(job);
        let mut runs = self.lock();
        let Some(run) = runs.get_mut(&job) else {
            return unknown_run();
        };
        if stop {
            run.stopping = true;
        }
        if run.owned(agent).is_empty() && !run.done && !run.stopping {
            return (409, error_json("agent owns no shard of this dp run"));
        }
        let took = self.absorb_free(run, agent);
        let sync = run.sync_json(agent, have);
        drop(runs);
        self.post_absorb(job, agent, &took);
        (200, sync)
    }

    /// `POST /cluster/dp/{job}/step` — body
    /// `{"agent": A, "step": T, "have": H, "reports": [ShardEval…]}`.
    /// First report per shard wins (replicas are deterministic, so
    /// duplicates are identical); a report for an already-committed
    /// step is counted as stale and answered with the sync payload so
    /// the straggler fast-forwards.
    pub fn step(&self, job: u64, body: &[u8]) -> (u16, Value) {
        let v = match super::dispatch::parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let Some(agent) = v.get("agent").as_i64().map(|a| a as u64) else {
            return (400, error_json("dp step needs an agent id"));
        };
        let step = v.get("step").as_i64().unwrap_or(-1);
        let have = v.get("have").as_i64().unwrap_or(0).max(0) as usize;
        let stop = self.registry.stop_requested(job);
        let m = crate::metrics::global();
        let mut runs = self.lock();
        let Some(run) = runs.get_mut(&job) else {
            return unknown_run();
        };
        if stop {
            run.stopping = true;
        }
        if run.owned(agent).is_empty() && !run.done && !run.stopping {
            return (409, error_json("agent owns no shard of this dp run"));
        }
        if step >= 0 && step as u64 == run.step() {
            let mut fresh = 0u64;
            if let Some(arr) = v.get("reports").as_arr() {
                for r in arr {
                    let Ok(e) = ShardEval::from_json(r) else { continue };
                    if e.shard < run.dp.replicas && run.reports[e.shard].is_none() {
                        run.reports[e.shard] = Some(e);
                        fresh += 1;
                    }
                }
            }
            m.counter(
                "repro_dp_steps_total",
                "dp shard step-reports accepted by the coordinator",
                &[],
            )
            .add(fresh);
            if run.try_commit() {
                m.counter(
                    "repro_dp_commits_total",
                    "dp steps committed (all shards aggregated, gradient projected)",
                    &[],
                )
                .inc();
            }
        } else {
            m.counter(
                "repro_dp_stale_reports_total",
                "dp step-reports for an already-committed step (stragglers fast-forwarded)",
                &[],
            )
            .inc();
        }
        let took = self.absorb_free(run, agent);
        let sync = run.sync_json(agent, have);
        drop(runs);
        self.post_absorb(job, agent, &took);
        (200, sync)
    }

    /// `POST /cluster/dp/{job}/epoch` — the primary's test metrics for
    /// a fully-committed epoch: `{"agent": A, "epoch": E, "test_loss":
    /// L, "test_acc": C, "lr": R, "seconds": S}`. Merged with the
    /// coordinator's train-side aggregate into one registry epoch
    /// record; recording the final epoch completes the job.
    pub fn epoch(&self, job: u64, body: &[u8]) -> (u16, Value) {
        let v = match super::dispatch::parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let Some(agent) = v.get("agent").as_i64().map(|a| a as u64) else {
            return (400, error_json("dp epoch needs an agent id"));
        };
        let epoch = v.get("epoch").as_i64().unwrap_or(-1);
        let (stats, final_epoch, best) = {
            let mut runs = self.lock();
            let Some(run) = runs.get_mut(&job) else {
                return unknown_run();
            };
            // same membership gate as sync_request: epoch metrics (and,
            // on the final epoch, job completion itself) must come from
            // a replica that actually holds a shard lease — not from an
            // arbitrary poster fabricating best_test_acc
            if run.owned(agent).is_empty() && !run.done && !run.stopping {
                return (409, error_json("agent owns no shard of this dp run"));
            }
            if epoch < 0 || epoch as usize >= run.epochs {
                return (400, error_json("epoch out of range"));
            }
            let e = epoch as usize;
            let Some((train_loss, train_acc)) = run.epoch_train[e] else {
                return (409, error_json("epoch not fully committed yet"));
            };
            if run.recorded[e] {
                return (200, Value::obj(vec![("ok", Value::Bool(true)), ("dup", Value::Bool(true))]));
            }
            run.recorded[e] = true;
            let test_acc = v.get("test_acc").as_f64().unwrap_or(0.0) as f32;
            run.best_test_acc = run.best_test_acc.max(test_acc);
            let final_epoch = e + 1 == run.epochs;
            if final_epoch {
                run.done = true;
            }
            (
                EpochStats {
                    epoch: e,
                    train_loss,
                    train_acc,
                    test_loss: v.get("test_loss").as_f64().unwrap_or(f64::NAN) as f32,
                    test_acc,
                    lr: v.get("lr").as_f64().unwrap_or(0.0) as f32,
                    seconds: v.get("seconds").as_f64().unwrap_or(0.0),
                    phases: Vec::new(),
                },
                final_epoch,
                run.best_test_acc,
            )
        };
        self.registry.record_epoch(job, stats);
        if final_epoch {
            self.registry.complete(
                job,
                JobOutcome { best_test_acc: best, timer: PhaseTimer::new(), stopped: false },
            );
        }
        (
            200,
            Value::obj(vec![("ok", Value::Bool(true)), ("done", Value::Bool(final_epoch))]),
        )
    }

    /// `POST /cluster/dp/{job}/leave` — body `{"agent": A}`. Frees the
    /// agent's shards. When the last member leaves a finished (or
    /// stopping) run, the run state is dropped — and a stopping run
    /// that never finished is completed as stopped.
    pub fn leave(&self, job: u64, body: &[u8]) -> (u16, Value) {
        let v = match super::dispatch::parse_body(body) {
            Ok(v) => v,
            Err(resp) => return resp,
        };
        let Some(agent) = v.get("agent").as_i64().map(|a| a as u64) else {
            return (400, error_json("dp leave needs an agent id"));
        };
        let freed = self.release(job, agent, "leave");
        if freed.is_none() {
            return unknown_run();
        }
        (200, Value::obj(vec![("ok", Value::Bool(true))]))
    }

    /// The dispatcher's hook for reaped / deregistered / lost-ack
    /// agents: frees the agent's shards instead of requeueing the whole
    /// job. Returns false when `job` is not a live dp run (the caller
    /// falls back to the regular requeue path).
    pub fn agent_lost(&self, job: u64, agent: u64) -> bool {
        self.release(job, agent, "lost").is_some()
    }

    /// Shared leave/lost path. Returns the freed shards, or None if
    /// the job has no live dp run.
    fn release(&self, job: u64, agent: u64, action: &str) -> Option<Vec<usize>> {
        let (freed, finalize) = {
            let mut runs = self.lock();
            let run = runs.get_mut(&job)?;
            let freed = run.owned(agent);
            for &s in &freed {
                run.owner[s] = None;
            }
            let stranded = run.member_count() == 0;
            let mut finalize = false;
            if stranded && (run.done || run.stopping) {
                finalize = !run.done && run.stopping;
                runs.remove(&job);
            }
            (freed, finalize)
        };
        if !freed.is_empty() {
            self.registry.journal_dp(job, action, agent, &freed);
        }
        if finalize {
            let best = 0.0; // complete() maxes with the recorded epochs' best
            self.registry.complete(
                job,
                JobOutcome { best_test_acc: best, timer: PhaseTimer::new(), stopped: true },
            );
        }
        self.gauge_members();
        self.gauge_runs();
        Some(freed)
    }

    /// Reaper-tick hook: propagate stop requests into runs whose
    /// members may all be gone (so a cancelled, fully-stranded run
    /// still reaches a terminal state) and drop finished husks.
    pub fn tick(&self) {
        let mut finalize = Vec::new();
        {
            let mut runs = self.lock();
            let ids: Vec<u64> = runs.keys().copied().collect();
            for id in ids {
                let stop = self.registry.stop_requested(id);
                let run = runs.get_mut(&id).unwrap();
                if stop {
                    run.stopping = true;
                }
                if run.member_count() == 0 && (run.done || run.stopping) {
                    if !run.done && run.stopping {
                        finalize.push(id);
                    }
                    runs.remove(&id);
                }
            }
        }
        for id in &finalize {
            let best = 0.0;
            self.registry.complete(
                *id,
                JobOutcome { best_test_acc: best, timer: PhaseTimer::new(), stopped: true },
            );
        }
        if !finalize.is_empty() {
            self.gauge_runs();
        }
    }

    /// Server shutdown: complete every unfinished run as stopped (the
    /// registry already marked running jobs interrupted) and drop all
    /// run state. Returns the ids that were live, so the dispatcher
    /// skips its own completion pass for them.
    pub fn shutdown(&self) -> Vec<u64> {
        let drained: Vec<(u64, bool)> = {
            let mut runs = self.lock();
            runs.drain().map(|(id, run)| (id, run.done)).collect()
        };
        let mut ids = Vec::new();
        for (id, done) in drained {
            if !done {
                let best = 0.0;
                self.registry.complete(
                    id,
                    JobOutcome { best_test_acc: best, timer: PhaseTimer::new(), stopped: true },
                );
            }
            ids.push(id);
        }
        self.gauge_runs();
        ids
    }

    fn gauge_runs(&self) {
        crate::metrics::global()
            .gauge("repro_dp_runs", "Live data-parallel runs on this coordinator", &[])
            .set(self.lock().len() as f64);
    }

    fn gauge_members(&self) {
        let members: usize = self.lock().values().map(|r| r.member_count()).sum();
        crate::metrics::global()
            .gauge(
                "repro_dp_members",
                "Agents currently holding dp shards (summed over runs)",
                &[],
            )
            .set(members as f64);
    }
}

fn unknown_run() -> (u16, Value) {
    (404, error_json("no live dp run for this job"))
}
