//! `serve` — the multi-job on-device-learning server (fleet
//! coordinator). Turns the one-shot trainers into a service: many
//! concurrent jobs, queued with priority + backpressure, scheduled onto
//! a pool of worker threads — and, with `--cluster`, fanned out to
//! remote worker agents on other machines — observable over a
//! dependency-free HTTP/1.1 + JSON control plane, cancellable mid-run,
//! checkpointed, and — with `--journal` — durable across server
//! restarts.
//!
//! Layering (std-only; JSON via the in-tree `util::json`):
//!
//! * [`protocol`] — `JobSpec` / `JobState` / `AgentState` / error
//!   bodies; a job spec covers every scenario `repro train` supports
//!   (both models, all three datasets, all four methods,
//!   FP32/INT8/INT8*, checkpoints, checkpoint-resume).
//! * [`queue`]    — bounded MPMC priority+FIFO queue on `Mutex`+`Condvar`;
//!   a full queue rejects fresh submissions (HTTP 429), a closed one
//!   rejects them for good (HTTP 503); replay/lease requeues bypass
//!   capacity.
//! * [`registry`] — job table (Queued→Running→Done/Failed/Cancelled/
//!   Interrupted), per-epoch history snapshots, aggregate `ServerStats`
//!   rolled up from each job's `telemetry::PhaseTimer`; doubles as the
//!   journal's event source when one is configured.
//! * [`events`]   — the live-telemetry broadcast bus: every epoch and
//!   state transition the registry records (local worker or remote
//!   agent alike) fans out to bounded per-subscriber buffers — slow
//!   consumers shed events and get an explicit `lagged` resync marker,
//!   the trainers never block — exposed over HTTP as Server-Sent
//!   Events (`GET /events`, `GET /jobs/{id}/events`) and consumed by
//!   `repro watch`.
//! * [`journal`]  — append-only JSONL job log: replayed at startup so
//!   `GET /jobs` survives restarts, requeues interrupted jobs from
//!   their last checkpoint, compacted on clean shutdown.
//! * [`worker`]   — N OS threads running the exact `repro train` path
//!   (`launch::run` into the unified `coordinator::session` loop) with a
//!   cooperative [`crate::coordinator::StopFlag`] and a registry-backed
//!   progress sink armed on each job's `TrainSpec`.
//! * [`dispatch`] — the cluster dispatcher: agent registration, lease
//!   heartbeats, queued-job fan-out to polling agents, and the reaper
//!   that requeues a lost agent's jobs from their last checkpoint.
//! * [`dp`]       — seed-compressed data-parallel ZO: one job trained
//!   by N agents at once. Each replica forward-evaluates a
//!   deterministic shard of every batch; the coordinator aggregates
//!   per-step loss deltas over `/cluster/dp/*`, commits the projected
//!   gradient, and every replica applies the identical update from its
//!   local RNG stream — only `(step, seed, scalar)` tuples cross the
//!   wire. Lost replicas' shards are re-leased to the surviving quorum.
//! * [`cluster`]  — the remote worker agent (`repro agent`): registers
//!   with a coordinator, pulls serialized `TrainSpec`s, runs them
//!   through the same `launch::run`, POSTs epochs + outcomes back.
//! * [`http`]     — `TcpListener` front end (GET /jobs, GET /jobs/{id},
//!   POST /jobs, POST /jobs/{id}/cancel, GET /stats, GET /healthz,
//!   POST /shutdown, POST/GET /cluster/*, plus the long-lived SSE
//!   streams GET /events and GET /jobs/{id}/events): routing, options
//!   and shutdown/drain orchestration, plus the tiny client used by
//!   `repro submit|jobs|job|watch` and the agent.
//! * [`reactor`]  — the nonblocking connection plane behind [`http`]:
//!   a small pool of `poll(2)` event-loop threads owns every accepted
//!   socket, serves HTTP/1.1 keep-alive (pipelining bounded, idle
//!   connections reaped) and multiplexes thousands of SSE streams off
//!   the event bus without a thread per connection.
//!
//! Entry points: `repro serve --port P --workers N --queue-cap C
//! [--journal F] [--cluster [--lease-ms L]]` boots [`http::Server`];
//! `repro agent --coordinator ADDR --capacity N` joins the fleet;
//! `repro submit|jobs|job|watch|stats` talk to the coordinator. Local
//! workers remain the degenerate one-node case — a cluster server with
//! no registered agents behaves exactly like a single-node one. The
//! HTTP surface is documented with request/response examples in
//! `rust/docs/SERVE_API.md`.

pub mod cluster;
pub mod dispatch;
pub mod dp;
pub mod events;
pub mod http;
pub mod journal;
pub mod protocol;
pub mod queue;
pub mod reactor;
pub mod registry;
pub mod worker;

pub use cluster::{Agent, AgentHandle, AgentOptions};
pub use dispatch::{ClusterOptions, Dispatcher};
pub use dp::DpCoordinator;
pub use events::{watch_job, EventBus, Poll, Subscriber, WatchFrame};
pub use http::{request, request_with_timeout, ServeOptions, Server};
pub use journal::Journal;
pub use protocol::{AgentState, JobSpec, JobState, DEFAULT_PORT};
pub use queue::{JobQueue, PushError};
pub use registry::{CancelOutcome, JobOutcome, JobRegistry};
pub use worker::WorkerPool;
