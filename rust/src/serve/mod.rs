//! `serve` — the multi-job on-device-learning server (fleet
//! coordinator). Turns the one-shot trainers into a service: many
//! concurrent jobs, queued with priority + backpressure, scheduled onto
//! a pool of worker threads, observable over a dependency-free HTTP/1.1
//! + JSON control plane, cancellable mid-run, checkpointed, and — with
//! `--journal` — durable across server restarts.
//!
//! Layering (std-only; JSON via the in-tree `util::json`):
//!
//! * [`protocol`] — `JobSpec` / `JobState` / error bodies; a job spec
//!   covers every scenario `repro train` supports (both models, all
//!   three datasets, all four methods, FP32/INT8/INT8*, checkpoints,
//!   checkpoint-resume).
//! * [`queue`]    — bounded MPMC priority+FIFO queue on `Mutex`+`Condvar`;
//!   a full queue rejects submissions (HTTP 429) instead of blocking.
//! * [`registry`] — job table (Queued→Running→Done/Failed/Cancelled/
//!   Interrupted), per-epoch history snapshots, aggregate `ServerStats`
//!   rolled up from each job's `telemetry::PhaseTimer`; doubles as the
//!   journal's event source when one is configured.
//! * [`journal`]  — append-only JSONL job log: replayed at startup so
//!   `GET /jobs` survives restarts, requeues interrupted jobs from
//!   their last checkpoint, compacted on clean shutdown.
//! * [`worker`]   — N OS threads running the exact `repro train` path
//!   (`launch::run` into the unified `coordinator::session` loop) with a
//!   cooperative [`crate::coordinator::StopFlag`] and a registry-backed
//!   progress sink armed on each job's `TrainSpec`.
//! * [`http`]     — `TcpListener` front end (GET /jobs, GET /jobs/{id},
//!   POST /jobs, POST /jobs/{id}/cancel, GET /stats, GET /healthz,
//!   POST /shutdown) plus the tiny client used by `repro submit|jobs|job`.
//!
//! Entry points: `repro serve --port P --workers N --queue-cap C
//! [--journal F]` boots [`http::Server`]; `repro submit|jobs|job|stats`
//! talk to it. The HTTP surface is documented with request/response
//! examples in `rust/docs/SERVE_API.md`.

pub mod http;
pub mod journal;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod worker;

pub use http::{request, ServeOptions, Server};
pub use journal::Journal;
pub use protocol::{JobSpec, JobState, DEFAULT_PORT};
pub use queue::{JobQueue, QueueFull};
pub use registry::{CancelOutcome, JobOutcome, JobRegistry};
pub use worker::WorkerPool;
