//! NITI INT8 layer ops: int8 GEMM/conv with int32 accumulation, ReLU,
//! max-pool, and the int8 error/gradient machinery for BP-tail layers.

use super::qtensor::{requantize, QTensor};
use super::rounding::{bitwidth, clamp_i8, pseudo_stochastic_round};

/// FC forward: x (B,K) int8 @ w (K,N) int8 -> int32 accumulator.
///
/// Inner loop is contiguous over the weight row and the accumulator
/// row; post-ReLU int8 activations are sparse, so zero rows are
/// skipped (same structure as the f32 GEMM).
pub fn fc_acc(x: &QTensor, w: &QTensor, bsz: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(x.data.len(), bsz * k);
    assert_eq!(w.data.len(), k * n);
    let mut acc = vec![0i32; bsz * n];
    for row in 0..bsz {
        let xr = &x.data[row * k..(row + 1) * k];
        let ar = &mut acc[row * n..(row + 1) * n];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let wrow = &w.data[kk * n..(kk + 1) * n];
            for (av, &wv) in ar.iter_mut().zip(wrow) {
                *av += xv * wv as i32;
            }
        }
    }
    acc
}

/// FC layer: forward + requantize. Output exponent = x.exp + w.exp + shift.
pub fn fc(x: &QTensor, w: &QTensor, bsz: usize, k: usize, n: usize) -> QTensor {
    let acc = fc_acc(x, w, bsz, k, n);
    requantize(&acc, &[bsz, n], x.exp + w.exp)
}

/// int8 im2col (same layout as the f32 engine / Pallas kernel).
#[allow(clippy::too_many_arguments)]
pub fn im2col_i8(
    x: &[i8],
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    pad: usize,
) -> (Vec<i8>, usize, usize) {
    let oh = h + 2 * pad - k + 1;
    let ow = w + 2 * pad - k + 1;
    let ckk = c * k * k;
    let mut cols = vec![0i8; bsz * oh * ow * ckk];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * ckk;
                for cc in 0..c {
                    for i in 0..k {
                        let iy = oy + i;
                        if iy < pad || iy >= h + pad {
                            continue;
                        }
                        for j in 0..k {
                            let ix = ox + j;
                            if ix < pad || ix >= w + pad {
                                continue;
                            }
                            cols[row + (cc * k + i) * k + j] =
                                x[((b * c + cc) * h + (iy - pad)) * w + (ix - pad)];
                        }
                    }
                }
            }
        }
    }
    (cols, oh, ow)
}

/// Conv layer (no bias, as NITI): int8 conv -> int32 -> requantize.
/// Weights (OC,C,K,K) row-major. Output (B,OC,OH,OW).
///
/// Hot path: im2col + GEMM with the weight matrix pre-transposed to
/// (CKK, OC) so the inner loop runs contiguously over one weight row
/// and the accumulator row — the layout LLVM auto-vectorizes with
/// widening i8→i32 multiplies (the NEON SDOT shape of the paper's C++
/// engine). See EXPERIMENTS.md §Perf for the before/after.
#[allow(clippy::too_many_arguments)]
pub fn conv(
    x: &QTensor,
    wt: &QTensor,
    bsz: usize,
    cin: usize,
    h: usize,
    w: usize,
    cout: usize,
    k: usize,
    pad: usize,
) -> QTensor {
    let (cols, oh, ow) = im2col_i8(&x.data, bsz, cin, h, w, k, pad);
    let ckk = cin * k * k;
    let rows = bsz * oh * ow;
    // widen weights to i16 once; each output cell is then one long
    // contiguous i16·i16→i32 dot product (pmaddwd-shaped)
    let wt16: Vec<i16> = wt.data.iter().map(|&v| v as i16).collect();
    let cols16: Vec<i16> = cols.iter().map(|&v| v as i16).collect();
    let mut acc_mat = vec![0i32; rows * cout];
    for r in 0..rows {
        let cr = &cols16[r * ckk..(r + 1) * ckk];
        let ar = &mut acc_mat[r * cout..(r + 1) * cout];
        for (oc, av) in ar.iter_mut().enumerate() {
            let wrow = &wt16[oc * ckk..(oc + 1) * ckk];
            let mut acc = 0i32;
            for (&cv, &wv) in cr.iter().zip(wrow) {
                acc += cv as i32 * wv as i32;
            }
            *av = acc;
        }
    }
    // (rows, OC) -> (B, OC, OH, OW)
    let mut acc = vec![0i32; bsz * cout * oh * ow];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let r = ((b * oh + oy) * ow + ox) * cout;
                for oc in 0..cout {
                    acc[((b * cout + oc) * oh + oy) * ow + ox] = acc_mat[r + oc];
                }
            }
        }
    }
    requantize(&acc, &[bsz, cout, oh, ow], x.exp + wt.exp)
}

/// ReLU in place on the int8 mantissa.
pub fn relu(x: &mut QTensor) {
    for v in &mut x.data {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// 2×2 stride-2 max pool on (B,C,H,W) int8.
pub fn maxpool2(x: &QTensor, bsz: usize, c: usize, h: usize, w: usize) -> QTensor {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0i8; bsz * c * oh * ow];
    for b in 0..bsz {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = i8::MIN;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = x.data
                                [((b * c + ch) * h + oy * 2 + dy) * w + ox * 2 + dx];
                            best = best.max(v);
                        }
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = best;
                }
            }
        }
    }
    QTensor::from_vec(&[bsz, c, oh, ow], out, x.exp)
}

/// Round an int32 gradient accumulator down to `bits` significant bits
/// with pseudo-stochastic rounding — NITI's update quantization. The
/// result is the int8 update applied directly to the weight mantissa.
pub fn round_update(acc: &[i32], bits: u32) -> Vec<i8> {
    let mut out = Vec::with_capacity(acc.len());
    round_update_into(acc, bits, &mut out);
    out
}

/// [`round_update`] into a caller-owned buffer — the allocation-free
/// form the per-step ZO update kernel reuses across tensors.
pub fn round_update_into(acc: &[i32], bits: u32, out: &mut Vec<i8>) {
    let maxabs = acc.iter().fold(0i32, |m, &v| m.max(v.wrapping_abs()));
    let b = bitwidth(maxabs);
    let shift = b.saturating_sub(bits);
    out.clear();
    out.extend(acc.iter().map(|&v| clamp_i8(pseudo_stochastic_round(v, shift))));
}

/// Int8 FC backward for the BP tail:
/// gw_acc (K,N) = xᵀ (K,B) @ e (B,N) in int32,
/// e_in_acc (B,K) = e @ wᵀ in int32 (for propagating one more layer).
pub fn fc_backward_acc(
    x: &QTensor,
    w: &QTensor,
    e: &QTensor,
    bsz: usize,
    k: usize,
    n: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut gw = vec![0i32; k * n];
    for row in 0..bsz {
        let xr = &x.data[row * k..(row + 1) * k];
        let er = &e.data[row * n..(row + 1) * n];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let grow = &mut gw[kk * n..(kk + 1) * n];
            for (gv, &ev) in grow.iter_mut().zip(er) {
                *gv += xv * ev as i32;
            }
        }
    }
    let mut e_in = vec![0i32; bsz * k];
    for row in 0..bsz {
        let er = &e.data[row * n..(row + 1) * n];
        let ei = &mut e_in[row * k..(row + 1) * k];
        for (kk, eiv) in ei.iter_mut().enumerate() {
            let wrow = &w.data[kk * n..(kk + 1) * n];
            let mut acc = 0i32;
            for (&ev, &wv) in er.iter().zip(wrow) {
                acc += ev as i32 * wv as i32;
            }
            *eiv = acc;
        }
    }
    (gw, e_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn q(dims: &[usize], vals: Vec<i8>, exp: i32) -> QTensor {
        QTensor::from_vec(dims, vals, exp)
    }

    #[test]
    fn fc_exact_small() {
        let x = q(&[1, 2], vec![2, 3], -1);
        let w = q(&[2, 2], vec![1, 0, 0, 1], 0);
        let out = fc(&x, &w, 1, 2, 2);
        assert_eq!(out.data, vec![2, 3]);
        assert_eq!(out.exp, -1); // no shift needed
    }

    #[test]
    fn fc_requantizes_large_acc() {
        let x = q(&[1, 64], vec![127; 64], 0);
        let w = q(&[64, 1], vec![127; 64], 0);
        let out = fc(&x, &w, 1, 64, 1);
        // acc = 64 * 127 * 127 = 1,032,256 (b=20) -> shift 13
        assert_eq!(out.exp, 13);
        assert!(out.data[0] > 0); // clamp keeps |v| <= 127 by construction
        // value preserved within rounding: data*2^13 ≈ acc
        let approx = (out.data[0] as i64) << 13;
        assert!((approx - 1_032_256i64).abs() <= 1 << 12);
    }

    #[test]
    fn conv_matches_fc_on_1x1() {
        // 1x1 conv == per-pixel FC
        prop::cases(10, |rng, _| {
            let (b, c, h, w, oc) = (1usize, 3usize, 4usize, 4usize, 2usize);
            let x = q(
                &[b, c, h, w],
                (0..b * c * h * w).map(|_| rng.uniform_i32(-127, 127) as i8).collect(),
                -3,
            );
            let wt = q(
                &[oc, c, 1, 1],
                (0..oc * c).map(|_| rng.uniform_i32(-127, 127) as i8).collect(),
                -4,
            );
            let out = conv(&x, &wt, b, c, h, w, oc, 1, 0);
            assert_eq!(out.dims, vec![b, oc, h, w]);
            assert!(out.exp >= -7);
            // exact check on one pixel vs scalar dot product
            let (py, px) = (1usize, 2usize);
            let mut acc = 0i32;
            for cc in 0..c {
                acc += x.data[((0 * c + cc) * h + py) * w + px] as i32
                    * wt.data[cc] as i32; // oc = 0
            }
            let shift = (out.exp - (x.exp + wt.exp)) as u32;
            let expect = super::super::rounding::clamp_i8(
                super::super::rounding::rshift_round(acc, shift),
            );
            assert_eq!(out.data[((0 * oc) * h + py) * w + px], expect);
        });
    }

    #[test]
    fn relu_and_maxpool() {
        let mut x = q(&[1, 1, 2, 2], vec![-5, 3, 7, -1], -2);
        relu(&mut x);
        assert_eq!(x.data, vec![0, 3, 7, 0]);
        let p = maxpool2(&x, 1, 1, 2, 2);
        assert_eq!(p.data, vec![7]);
        assert_eq!(p.exp, -2);
    }

    #[test]
    fn round_update_bits_bound() {
        prop::cases(20, |rng, _| {
            let acc: Vec<i32> = (0..32).map(|_| rng.uniform_i32(-1_000_000, 1_000_000)).collect();
            for bits in [1u32, 3, 5] {
                let u = round_update(&acc, bits);
                let bound = (1i32 << bits) - 1;
                // after shifting to `bits` significant bits plus rounding,
                // magnitudes stay within 2^bits (clamped to 127 anyway)
                assert!(u.iter().all(|&v| (v as i32).abs() <= bound.min(127) + 1));
            }
        });
    }

    #[test]
    fn fc_backward_acc_exact() {
        // x (1,2) = [1,2], e (1,2) = [3,4], w = I
        let x = q(&[1, 2], vec![1, 2], 0);
        let w = q(&[2, 2], vec![1, 0, 0, 1], 0);
        let e = q(&[1, 2], vec![3, 4], 0);
        let (gw, e_in) = fc_backward_acc(&x, &w, &e, 1, 2, 2);
        assert_eq!(gw, vec![3, 4, 6, 8]); // xᵀe
        assert_eq!(e_in, vec![3, 4]); // e wᵀ = e
    }
}
