//! Native NITI INT8 training engine — the pure-integer counterpart of
//! the paper's C++ implementation (Raspberry Pi Zero 2 target).
//!
//! Tensors are `int8 · 2^s` pairs ([`qtensor::QTensor`]); contractions
//! accumulate in int32 and are requantized with exact bit-counting
//! ([`rounding`]); gradient updates use NITI's pseudo-stochastic
//! rounding; and the ZO gradient sign is computed from the **integer
//! cross-entropy** (paper §4.3, Eqs. 7–12) in [`intce`] — no FPU on the
//! entire INT8* path.

pub mod intce;
pub mod layers;
pub mod lenet8;
pub mod qtensor;
pub mod rounding;
