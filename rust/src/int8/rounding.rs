//! Integer rounding primitives shared by every NITI op.
//!
//! `bitwidth` / `rshift_round` are bit-for-bit identical to
//! python/compile/int8_model.py (the XLA INT8 artifact), which is what
//! makes the two INT8 engines agree exactly. `pseudo_stochastic_round`
//! is NITI's RNG-free stochastic rounding used for gradient updates.

/// Minimum bitwidth to represent `v >= 0`: `floor(log2(v)) + 1`, 0 for 0.
#[inline]
pub fn bitwidth(v: i32) -> u32 {
    debug_assert!(v >= 0);
    32 - (v as u32).leading_zeros()
}

/// Arithmetic right shift with round-to-nearest, ties away from zero.
/// Sign-symmetric; `k == 0` is the identity. Matches
/// `int8_model.rshift_round` exactly.
#[inline]
pub fn rshift_round(v: i32, k: u32) -> i32 {
    if k == 0 {
        return v;
    }
    let a = (v as i64).abs();
    let r = ((a + (1i64 << (k - 1))) >> k) as i32;
    if v < 0 {
        -r
    } else {
        r
    }
}

/// NITI pseudo-stochastic rounding: right-shift by `k`, rounding up with
/// probability ≈ fraction, using the discarded bits themselves as the
/// entropy source (deterministic, no RNG state).
///
/// The `k` discarded bits split into a top half `f` (the fraction) and a
/// bottom half `u` (the pseudo-random draw); round the magnitude up iff
/// `u < f`. For `k == 1` this degenerates to round-half-up.
#[inline]
pub fn pseudo_stochastic_round(v: i32, k: u32) -> i32 {
    if k == 0 {
        return v;
    }
    let neg = v < 0;
    let a = (v as i64).abs() as u64;
    let base = (a >> k) as i32;
    let d = a & ((1u64 << k) - 1);
    let up = if k == 1 {
        d == 1
    } else {
        let half = k / 2;
        let f = d >> (k - half); // top `half` bits: the fraction
        let u = d & ((1u64 << (k - half)) - 1); // low `k-half` bits: the draw
        // Align f to u's width, then round up iff u < f
        // (P[up] ≈ f / 2^half ≈ the true fraction).
        let f_scaled = if k - half >= half {
            f << ((k - half) - half)
        } else {
            f >> (half - (k - half))
        };
        u < f_scaled
    };
    let r = base + if up { 1 } else { 0 };
    if neg {
        -r
    } else {
        r
    }
}

/// Clamp an i32 to the symmetric int8 range used by NITI.
#[inline]
pub fn clamp_i8(v: i32) -> i8 {
    v.clamp(-127, 127) as i8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn bitwidth_matches_bit_length() {
        for v in [0i32, 1, 2, 3, 127, 128, 255, 256, 1 << 30] {
            let expect = if v == 0 { 0 } else { 64 - (v as u64).leading_zeros() };
            assert_eq!(bitwidth(v), expect, "v={v}");
        }
        prop::cases(100, |rng, _| {
            let v = (rng.next_u64() % (1 << 31)) as i32;
            let expect = if v == 0 { 0 } else { 64 - (v as u64).leading_zeros() };
            assert_eq!(bitwidth(v), expect);
        });
    }

    #[test]
    fn rshift_round_reference() {
        // same model as python tests: (|v| + 2^(k-1)) >> k, sign restored
        assert_eq!(rshift_round(5, 1), 3); // 2.5 -> 3
        assert_eq!(rshift_round(-5, 1), -3);
        assert_eq!(rshift_round(4, 2), 1);
        assert_eq!(rshift_round(6, 2), 2); // 1.5 -> 2 (ties away)
        assert_eq!(rshift_round(7, 0), 7);
        assert_eq!(rshift_round(i32::MAX, 3), (i32::MAX as i64 + 4 >> 3) as i32);
    }

    #[test]
    fn rshift_round_sign_symmetric_and_bounded() {
        prop::cases(200, |rng, _| {
            let v = rng.uniform_i32(-(1 << 24), 1 << 24);
            let k = (rng.next_u64() % 20) as u32;
            assert_eq!(rshift_round(-v, k), -rshift_round(v, k));
            let err = (rshift_round(v, k) as f64 - v as f64 / (1u64 << k) as f64).abs();
            assert!(err <= 0.5 + 1e-9, "v={v} k={k} err={err}");
        });
    }

    #[test]
    fn pseudo_stochastic_round_deterministic_and_close() {
        prop::cases(200, |rng, _| {
            let v = rng.uniform_i32(-(1 << 24), 1 << 24);
            let k = (rng.next_u64() % 16) as u32;
            let a = pseudo_stochastic_round(v, k);
            let b = pseudo_stochastic_round(v, k);
            assert_eq!(a, b); // deterministic
            assert_eq!(pseudo_stochastic_round(-v, k), -a); // symmetric
            let exact = v as f64 / (1u64 << k) as f64;
            assert!((a as f64 - exact).abs() <= 1.0 + 1e-9, "v={v} k={k}");
        });
    }

    #[test]
    fn pseudo_stochastic_round_unbiased_in_aggregate() {
        // Over many uniformly-distributed values the mean rounding error
        // must be near zero (the property NITI relies on for SGD).
        let k = 8u32;
        let mut err_sum = 0.0f64;
        let n = 100_000;
        let mut rng = crate::rng::Rng64::new(99);
        for _ in 0..n {
            let v = rng.uniform_i32(0, 1 << 20);
            let r = pseudo_stochastic_round(v, k);
            err_sum += r as f64 - v as f64 / 256.0;
        }
        let bias = err_sum / n as f64;
        assert!(bias.abs() < 0.05, "bias {bias}");
    }

    #[test]
    fn clamp_bounds() {
        assert_eq!(clamp_i8(300), 127);
        assert_eq!(clamp_i8(-300), -127);
        assert_eq!(clamp_i8(-128), -127);
        assert_eq!(clamp_i8(50), 50);
    }
}
