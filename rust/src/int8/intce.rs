//! Integer cross-entropy ZO gradient sign — the paper's §4.3 novelty
//! (Eqs. 7–12): decide `sgn(L(α) − L(β))` for two int8 logit sets using
//! only integer add/multiply/shift/compare and leading-zero counts.
//!
//! Pipeline per sample `b` with label `i`:
//!   1. rescale both logit sets to the common exponent `s = min(s_α,s_β)`
//!   2. `x̂_j = (47274 · (x̄_j − x̄_i)) ≫ (15 − s)`   (exp→pow2, Eq. 9)
//!   3. `p = p_max − 10`, `x̃_j = clamp(x̂_j − p, 0, 10)` (overflow guard)
//!   4. per-sample `⌊log₂ Σ_j 2^x̃_j⌋` via bit length  (Eq. 12)
//!   5. batch-sum each side, compare.
//!
//! The floor in step 4 loses information, so ~5% of decisions flip vs
//! the exact float sign (paper reports the same); `tests` measure the
//! agreement rate.

/// log2(e) ≈ 47274 / 2^15 (the NITI constant).
const LOG2E_Q15: i64 = 47274;

/// One side's per-sample floor-log2 terms: `⌊log₂ Σ_j 2^x̃_j⌋`.
///
/// `logits` is `(bsz, n)` int8 row-major, `rel_shift = s_x − s` (≥ 0),
/// `s` the common exponent, `labels[b]` the target class.
fn side_terms(
    logits: &[i8],
    rel_shift: u32,
    s: i32,
    labels: &[u8],
    bsz: usize,
    n: usize,
    other: &[i8],
    other_rel: u32,
) -> Vec<i64> {
    let mut out = Vec::with_capacity(bsz);
    for b in 0..bsz {
        let row = &logits[b * n..(b + 1) * n];
        let orow = &other[b * n..(b + 1) * n];
        let li = labels[b] as usize;
        // x̂ for both sides share a per-sample offset p computed from the
        // joint max (Eq. 9–10); compute own hats and the joint max here.
        let hat = |v: i8, target: i8, rel: u32| -> i64 {
            let d = ((v as i64) << rel) - ((target as i64) << rel);
            let prod = LOG2E_Q15 * d; // ≤ 47274*510*2^rel — fits i64
            if s >= 15 {
                prod << (s - 15)
            } else {
                prod >> (15 - s)
            }
        };
        let own: Vec<i64> = row.iter().map(|&v| hat(v, row[li], rel_shift)).collect();
        let oth: Vec<i64> = orow.iter().map(|&v| hat(v, orow[li], other_rel)).collect();
        let pmax = own.iter().chain(oth.iter()).copied().max().unwrap();
        let p = pmax - 10;
        let sum: i64 = own
            .iter()
            .map(|&h| {
                let t = (h - p).clamp(0, 10);
                1i64 << t
            })
            .sum();
        // ⌊log₂ sum⌋ via bit length (sum ≥ 1 always: the j == i term)
        out.push(63 - sum.leading_zeros() as i64);
    }
    out
}

/// `sgn(L(α;labels) − L(β;labels))` with integer arithmetic only.
///
/// Returns −1, 0 or +1. `(s_a, s_b)` are the logits' scaling exponents.
#[allow(clippy::too_many_arguments)]
pub fn loss_diff_sign_int(
    alpha: &[i8],
    s_a: i32,
    beta: &[i8],
    s_b: i32,
    labels: &[u8],
    bsz: usize,
    n: usize,
) -> i32 {
    assert_eq!(alpha.len(), bsz * n);
    assert_eq!(beta.len(), bsz * n);
    let s = s_a.min(s_b);
    let rel_a = (s_a - s) as u32;
    let rel_b = (s_b - s) as u32;
    let ta = side_terms(alpha, rel_a, s, labels, bsz, n, beta, rel_b);
    let tb = side_terms(beta, rel_b, s, labels, bsz, n, alpha, rel_a);
    let total: i64 = ta.iter().sum::<i64>() - tb.iter().sum::<i64>();
    total.signum() as i32
}

/// Float reference: exact CE difference from dequantized int8 logits
/// (the paper's "INT8" column computes `g` this way; also the test
/// oracle for the integer path).
pub fn loss_diff_f32(
    alpha: &[i8],
    s_a: i32,
    beta: &[i8],
    s_b: i32,
    labels: &[u8],
    bsz: usize,
    n: usize,
) -> f64 {
    let ce = |logits: &[i8], s: i32| -> f64 {
        let scale = (s as f64).exp2();
        let mut total = 0.0;
        for b in 0..bsz {
            let row = &logits[b * n..(b + 1) * n];
            let li = labels[b] as usize;
            let m = row.iter().map(|&v| v as f64 * scale).fold(f64::MIN, f64::max);
            let lse: f64 = m
                + row
                    .iter()
                    .map(|&v| (v as f64 * scale - m).exp())
                    .sum::<f64>()
                    .ln();
            total += lse - row[li] as f64 * scale;
        }
        total
    };
    ce(alpha, s_a) - ce(beta, s_b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng64;

    fn random_case(
        rng: &mut Rng64,
        bsz: usize,
        n: usize,
    ) -> (Vec<i8>, i32, Vec<i8>, i32, Vec<u8>) {
        // realistic post-requantization exponents: logits·2^s of O(1..30)
        let s_a = rng.uniform_i32(-4, -1);
        let s_b = s_a + rng.uniform_i32(0, 2);
        let alpha: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
        // beta = alpha + small perturbation response (realistic ZO pair)
        let beta: Vec<i8> = alpha
            .iter()
            .map(|&v| (v as i32 + rng.uniform_i32(-12, 12)).clamp(-127, 127) as i8)
            .collect();
        let labels: Vec<u8> = (0..bsz).map(|_| (rng.next_u64() % n as u64) as u8).collect();
        (alpha, s_a, beta, s_b, labels)
    }

    #[test]
    fn identical_logits_give_zero() {
        let mut rng = Rng64::new(1);
        for _ in 0..20 {
            let (a, s_a, _, _, labels) = random_case(&mut rng, 4, 10);
            let g = loss_diff_sign_int(&a, s_a, &a, s_a, &labels, 4, 10);
            assert_eq!(g, 0);
        }
    }

    #[test]
    fn obvious_cases_correct() {
        // alpha puts all mass on the label (low loss), beta is uniform:
        // L(alpha) < L(beta) -> sign must be -1.
        let n = 10;
        let bsz = 4;
        let mut alpha = vec![-60i8; bsz * n];
        let labels: Vec<u8> = vec![3; bsz];
        for b in 0..bsz {
            alpha[b * n + 3] = 120;
        }
        let beta = vec![0i8; bsz * n];
        let g = loss_diff_sign_int(&alpha, -4, &beta, -4, &labels, bsz, n);
        assert_eq!(g, -1);
        let g2 = loss_diff_sign_int(&beta, -4, &alpha, -4, &labels, bsz, n);
        assert_eq!(g2, 1);
    }

    #[test]
    fn sign_agreement_rate_above_90pct() {
        // paper: "correct signs can be obtained at a high probability (~95%)"
        let mut rng = Rng64::new(42);
        let mut agree = 0usize;
        let mut total = 0usize;
        for _ in 0..2000 {
            let (a, s_a, b, s_b, labels) = random_case(&mut rng, 8, 10);
            let exact = loss_diff_f32(&a, s_a, &b, s_b, &labels, 8, 10);
            if exact.abs() < 0.2 {
                continue; // near-tie: either answer acceptable
            }
            let g = loss_diff_sign_int(&a, s_a, &b, s_b, &labels, 8, 10);
            if g == exact.signum() as i32 {
                agree += 1;
            }
            total += 1;
        }
        let rate = agree as f64 / total as f64;
        assert!(rate > 0.90, "sign agreement {rate:.3} over {total} cases");
    }

    #[test]
    fn antisymmetric() {
        let mut rng = Rng64::new(7);
        for _ in 0..50 {
            let (a, s_a, b, s_b, labels) = random_case(&mut rng, 4, 10);
            let g1 = loss_diff_sign_int(&a, s_a, &b, s_b, &labels, 4, 10);
            let g2 = loss_diff_sign_int(&b, s_b, &a, s_a, &labels, 4, 10);
            assert_eq!(g1, -g2);
        }
    }

    #[test]
    fn exponent_rescaling_consistent() {
        // doubling the mantissas while decrementing the exponent must not
        // change the decision (same represented values)
        let mut rng = Rng64::new(11);
        for _ in 0..50 {
            let n = 10;
            let bsz = 4;
            let alpha: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-60, 60) as i8).collect();
            let beta: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-60, 60) as i8).collect();
            let labels: Vec<u8> = (0..bsz).map(|_| (rng.next_u64() % 10) as u8).collect();
            let alpha2: Vec<i8> = alpha.iter().map(|&v| v * 2).collect();
            let g1 = loss_diff_sign_int(&alpha, -4, &beta, -4, &labels, bsz, n);
            let g2 = loss_diff_sign_int(&alpha2, -5, &beta, -4, &labels, bsz, n);
            assert_eq!(g1, g2, "rescaling changed the sign");
        }
    }

    #[test]
    fn batch_sum_matches_singles_mostly() {
        // Eq. 12: batch decision = sum of per-sample floor-log2 terms.
        // For a batch where every sample individually says "+", the batch
        // must say "+".
        let n = 10;
        let mut rng = Rng64::new(13);
        let labels: Vec<u8> = vec![0; 4];
        let mut alpha = vec![0i8; 4 * n];
        let mut beta = vec![0i8; 4 * n];
        for b in 0..4 {
            beta[b * n] = 100; // beta very confident on the label
            alpha[b * n] = -100; // alpha very wrong
            for j in 1..n {
                alpha[b * n + j] = rng.uniform_i32(-5, 5) as i8;
                beta[b * n + j] = rng.uniform_i32(-5, 5) as i8;
            }
        }
        let g = loss_diff_sign_int(&alpha, -4, &beta, -4, &labels, 4, n);
        assert_eq!(g, 1); // L(alpha) > L(beta)
    }
}
