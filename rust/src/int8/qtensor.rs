//! NITI quantized tensor: an int8 mantissa tensor with one shared
//! power-of-two scaling exponent — value = `data · 2^exp`.

use super::rounding::{bitwidth, clamp_i8, rshift_round};

#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub data: Vec<i8>,
    pub dims: Vec<usize>,
    /// Scaling exponent `s`: represented value is `data[i] * 2^exp`.
    pub exp: i32,
}

impl QTensor {
    pub fn zeros(dims: &[usize], exp: i32) -> QTensor {
        let n: usize = dims.iter().product();
        QTensor { data: vec![0; n], dims: dims.to_vec(), exp }
    }

    pub fn from_vec(dims: &[usize], data: Vec<i8>, exp: i32) -> QTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        QTensor { data, dims: dims.to_vec(), exp }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Dequantize to f32 (test/inspection only — never on the INT8* path).
    pub fn to_f32(&self) -> Vec<f32> {
        let scale = (self.exp as f32).exp2();
        self.data.iter().map(|&v| v as f32 * scale).collect()
    }

    /// θ ← clamp(θ + k·z) over the whole tensor — the replay form of the
    /// Alg. 2 perturbation leg over a cached `z`, integer-only and
    /// per-element identical to `perturb_int8`'s inline loop.
    pub fn clamp_add_scaled(&mut self, z: &[i8], k: i32) {
        assert_eq!(self.data.len(), z.len());
        for (v, &zv) in self.data.iter_mut().zip(z) {
            *v = clamp_i8(*v as i32 + k * zv as i32);
        }
    }

    /// Quantize an f32 slice: pick the exponent so max|v| maps near 127.
    pub fn quantize(dims: &[usize], values: &[f32]) -> QTensor {
        assert_eq!(dims.iter().product::<usize>(), values.len());
        let maxabs = values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if maxabs == 0.0 {
            return QTensor::zeros(dims, 0);
        }
        // exp = ceil(log2(maxabs / 127))
        let exp = (maxabs / 127.0).log2().ceil() as i32;
        let scale = (-exp as f32).exp2();
        let data = values
            .iter()
            .map(|&v| clamp_i8((v * scale).round() as i32))
            .collect();
        QTensor { data, dims: dims.to_vec(), exp }
    }
}

/// Requantize an int32 accumulator (value `acc · 2^acc_exp`) to int8:
/// shift so the max magnitude fits 7 bits. Matches
/// `int8_model.requantize` exactly. Returns `(tensor, shift_applied)`.
pub fn requantize(acc: &[i32], dims: &[usize], acc_exp: i32) -> QTensor {
    let maxabs = acc.iter().fold(0i32, |m, &v| m.max(v.wrapping_abs()));
    let b = bitwidth(maxabs);
    let shift = b.saturating_sub(7);
    let data = acc
        .iter()
        .map(|&v| clamp_i8(rshift_round(v, shift)))
        .collect();
    QTensor {
        data,
        dims: dims.to_vec(),
        exp: acc_exp + shift as i32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn quantize_dequantize_roundtrip_error() {
        prop::cases(20, |rng, _| {
            let vals: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
            let q = QTensor::quantize(&[64], &vals);
            let deq = q.to_f32();
            let maxabs = vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            for (a, b) in vals.iter().zip(&deq) {
                // one quantum = maxabs/127 roughly
                assert!((a - b).abs() <= maxabs / 127.0 + 1e-6);
            }
        });
    }

    #[test]
    fn quantize_zero() {
        let q = QTensor::quantize(&[4], &[0.0; 4]);
        assert!(q.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn quantize_uses_full_range() {
        let q = QTensor::quantize(&[2], &[1.0, -2.0]);
        assert!(q.data.iter().any(|&v| v.abs() >= 64), "{:?}", q.data);
    }

    #[test]
    fn requantize_small_is_identity() {
        let acc: Vec<i32> = (-127..=127).collect();
        let q = requantize(&acc, &[255], -7);
        assert_eq!(q.exp, -7);
        for (a, b) in acc.iter().zip(&q.data) {
            assert_eq!(*a as i8, *b);
        }
    }

    #[test]
    fn requantize_preserves_value_within_rounding() {
        prop::cases(30, |rng, _| {
            let scale = 1 << (rng.next_u64() % 20);
            let acc: Vec<i32> = (0..32)
                .map(|_| rng.uniform_i32(-scale, scale))
                .collect();
            let q = requantize(&acc, &[32], 0);
            let shift = q.exp;
            assert!(shift >= 0);
            for (&a, &d) in acc.iter().zip(&q.data) {
                let approx = (d as i64) << shift;
                let tol = if shift > 0 { 1i64 << (shift - 1) } else { 0 } + 1;
                assert!(
                    (approx - a as i64).abs() <= tol,
                    "acc {a} -> {d}·2^{shift}"
                );
            }
        });
    }

    #[test]
    fn requantize_range_bound() {
        let acc = vec![i32::MAX / 2, -(i32::MAX / 2), 12345, -9];
        let q = requantize(&acc, &[4], 0);
        assert!(q.data.iter().all(|&v| (-127..=127).contains(&v)));
    }
}
